//! Steady-state subsystem integration tests (E7): write amplification and
//! GC-attributed tail inflation under sustained random writes at low
//! over-provisioning, the PROPOSED-shrinks-the-GC-tax headline, the golden
//! guarantee that the steady machinery leaves no trace when disabled, and
//! determinism of the whole pipeline across thread-pool sizes and
//! workspace reuse.

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimReport, SimWorkspace};
use ddrnand::coordinator::experiments::{run_steady_state, SteadySweepSpec};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;

fn steady_cfg(iface: InterfaceKind, ways: u16, over_provision: f64) -> SsdConfig {
    let mut cfg = SsdConfig {
        iface,
        channels: 1,
        ways,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.steady.enabled = true;
    cfg.steady.over_provision = over_provision;
    cfg.steady.wear_level_spread = 16;
    cfg
}

/// The E7 acceptance property: a sustained-random-write run at ~7%
/// over-provisioning reports WAF > 1.0 with GC-attributed p99 inflation
/// (GC-hit requests' p99 strictly above the clean requests' p99).
#[test]
fn e7_at_7pct_op_reports_waf_and_gc_p99_inflation() {
    let cfg = steady_cfg(InterfaceKind::Proposed, 2, 0.07);
    // Physical 1x2x64x64x2KiB = 16 MiB, logical ~14.9 MiB (~238 requests'
    // worth): 500 requests rewrite the volume ~2.1x past preconditioning.
    let r = Campaign::new(cfg, RequestKind::Write, 500).run();
    assert_eq!(r.requests, 500);
    assert!(r.waf > 1.0, "7% OP must amplify: waf={}", r.waf);
    assert!(r.waf < 20.0, "waf={} is implausible", r.waf);
    assert!(r.gc_pages_programmed > 0 && r.gc_pages_read > 0);
    assert!(r.blocks_erased > 0);
    assert!(r.gc_requests > 0, "some host writes must hit GC in-plan");
    assert!(
        r.latency_p99_gc_us > r.latency_p99_clean_us,
        "GC-hit requests must pay a visible p99 tax: gc {} vs clean {} us",
        r.latency_p99_gc_us,
        r.latency_p99_clean_us
    );
    assert!(r.gc_energy_share > 0.0 && r.gc_energy_share < 1.0);
    // More over-provisioning buys the amplification back down.
    let roomy = Campaign::new(steady_cfg(InterfaceKind::Proposed, 2, 0.30), RequestKind::Write, 500)
        .run();
    assert!(
        roomy.waf < r.waf,
        "30% OP must amplify less than 7%: {} vs {}",
        roomy.waf,
        r.waf
    );
}

/// The E7 headline: under the PR 2 open-loop load machinery, PROPOSED's
/// doubled transfer rate shrinks the GC tax on p99 latency — at an offered
/// load a GC-taxed CONV drive cannot sustain, PROPOSED still can.
#[test]
fn proposed_shrinks_gc_tax_on_p99_under_offered_load() {
    let run = |iface| {
        let mut cfg = steady_cfg(iface, 4, 0.07);
        cfg.load.offered_mbps = Some(20.0);
        cfg.seed = 0xE7;
        Campaign::new(cfg, RequestKind::Write, 250).run()
    };
    let conv = run(InterfaceKind::Conv);
    let prop = run(InterfaceKind::Proposed);
    assert!(conv.waf > 1.0 && prop.waf > 1.0, "both drives must be in GC");
    assert!(
        prop.latency_p99_us < conv.latency_p99_us,
        "PROPOSED must shrink the GC tax on p99: {} vs {} us",
        prop.latency_p99_us,
        conv.latency_p99_us
    );
    assert!(
        prop.bandwidth_mbps > conv.bandwidth_mbps,
        "and sustain more of the offered load: {} vs {}",
        prop.bandwidth_mbps,
        conv.bandwidth_mbps
    );
}

/// Golden guarantee: with `[steady]` disabled nothing changes — a
/// workspace dirtied by a steady-state run (same geometry fingerprint, so
/// the simulator is *reused*, not rebuilt) reproduces the fresh-drive
/// closed-loop results bit-identically, GC columns included.
#[test]
fn gc_disabled_run_bit_identical_after_steady_reuse() {
    // over_provision 0.10 and utilization 0.90 size the FTL identically,
    // so the reuse fingerprint matches across the regime switch.
    let mut plain = SsdConfig {
        channels: 1,
        ways: 2,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    plain.utilization = 0.90;
    let steady = {
        let mut c = steady_cfg(InterfaceKind::Proposed, 2, 0.10);
        c.load.offered_mbps = Some(15.0);
        c
    };
    let fresh = Campaign::new(plain.clone(), RequestKind::Write, 60).run();
    let mut ws = SimWorkspace::new();
    let dirty = Campaign::new(steady, RequestKind::Write, 200).run_in(&mut ws);
    assert!(dirty.waf > 1.0, "the dirtying run must actually GC");
    let reused = Campaign::new(plain, RequestKind::Write, 60).run_in(&mut ws);
    assert!(ws.reuses >= 1, "the regime switch must reuse the simulator");
    assert_eq!(fresh.events, reused.events);
    assert_eq!(fresh.sim_time, reused.sim_time);
    assert_eq!(fresh.bandwidth_mbps, reused.bandwidth_mbps);
    assert_eq!(fresh.energy_nj_per_byte, reused.energy_nj_per_byte);
    assert_eq!(fresh.latency_mean_us, reused.latency_mean_us);
    assert_eq!(fresh.latency_p99_us, reused.latency_p99_us);
    assert_eq!(fresh.pages_programmed, reused.pages_programmed);
    // The steady columns must read fresh-drive: no amplification residue.
    assert_eq!(reused.waf, 1.0);
    assert_eq!(reused.gc_pages_programmed, 0);
    assert_eq!(reused.wl_pages_programmed, 0);
    assert_eq!(reused.gc_requests, 0);
    assert_eq!(reused.wear_spread, 0);
    assert!(reused.latency_p99_gc_us.is_nan());
}

/// Exact fingerprint of everything a steady-state report measures.
fn fingerprint(r: &SimReport) -> (u64, i64, u64, u64, u64, u64, u32, [u64; 7]) {
    (
        r.events,
        r.sim_time.as_ps(),
        r.pages_programmed,
        r.gc_pages_programmed,
        r.wl_pages_programmed,
        r.gc_requests,
        r.wear_spread,
        [
            r.bandwidth_mbps.to_bits(),
            r.energy_nj_per_byte.to_bits(),
            r.waf.to_bits(),
            r.latency_p50_us.to_bits(),
            r.latency_p99_us.to_bits(),
            r.latency_p99_gc_us.to_bits(),
            r.latency_p99_clean_us.to_bits(),
        ],
    )
}

/// Determinism (same seed -> identical `SimReport`) across worker-pool
/// sizes 1/2/8 and after `SimWorkspace` reuse: latencies, energy and WAF
/// must agree to the bit, no matter how jobs land on workers.
#[test]
fn identical_reports_across_pool_sizes_and_workspace_reuse() {
    let jobs = || {
        let mut out = Vec::new();
        for iface in [InterfaceKind::Conv, InterfaceKind::Proposed] {
            for ways in [1u16, 2] {
                let mut cfg = steady_cfg(iface, ways, 0.07);
                cfg.load.offered_mbps = Some(10.0);
                out.push(move |ws: &mut SimWorkspace| {
                    Campaign::new(cfg, RequestKind::Write, 120).run_in(ws)
                });
            }
        }
        out
    };
    let run = |threads| {
        ThreadPool::new(threads)
            .run_all_with(jobs(), SimWorkspace::new)
            .iter()
            .map(fingerprint)
            .collect::<Vec<_>>()
    };
    let p1 = run(1);
    let p2 = run(2);
    let p8 = run(8);
    assert_eq!(p1, p2, "pool size 1 vs 2 must not change any report");
    assert_eq!(p1, p8, "pool size 1 vs 8 must not change any report");
    assert!(
        p1.iter().any(|f| f.3 > 0),
        "the grid must include GC-active points for the comparison to bite"
    );
    // Workspace reuse: running the same steady campaign twice through one
    // workspace reproduces the fresh report exactly.
    let campaign = || {
        let mut cfg = steady_cfg(InterfaceKind::Proposed, 2, 0.07);
        cfg.load.offered_mbps = Some(10.0);
        Campaign::new(cfg, RequestKind::Write, 120)
    };
    let mut ws = SimWorkspace::new();
    let first = campaign().run_in(&mut ws);
    let second = campaign().run_in(&mut ws);
    assert!(ws.reuses >= 1);
    assert_eq!(fingerprint(&first), fingerprint(&second));
    assert_eq!(fingerprint(&first), fingerprint(&campaign().run()));
}

/// The E7 driver is itself deterministic and orders interfaces correctly
/// on the WAF-free axis: at equal over-provisioning PROPOSED never loses
/// to CONV on achieved throughput.
#[test]
fn e7_driver_deterministic_and_ordered() {
    let spec = SteadySweepSpec {
        ways: vec![2],
        over_provision: vec![0.07],
        requests: 100,
        offered_mbps: Some(10.0),
        ..SteadySweepSpec::default()
    };
    let a = run_steady_state(&spec, &ThreadPool::new(4));
    let b = run_steady_state(&spec, &ThreadPool::new(1));
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(fingerprint(&x.report), fingerprint(&y.report));
    }
    let bw = |iface| {
        a.iter()
            .find(|c| c.iface == iface)
            .map(|c| c.report.bandwidth_mbps)
            .unwrap()
    };
    assert!(bw(InterfaceKind::Proposed) >= bw(InterfaceKind::Conv));
}
