//! Conformance suite for the bottleneck observer (`ddrnand::observe`):
//!
//! 1. **Zero-perturbation goldens** — every shipped scenario class (fresh
//!    write/read, steady-state GC, tiered SLC/MLC, multi-tenant QoS)
//!    produces a bit-identical `SimReport` with observation off, on, and
//!    on-with-timeline. Observation is read-only over the DES by
//!    construction; these tests make that a contract.
//! 2. **Randomized occupancy oracle** — for random configs/workloads the
//!    four occupancy states partition each resource's wall clock *exactly*
//!    (integer picoseconds), and the stall-cause attribution totals tie
//!    out to the way-level blocked/idle accumulators.
//! 3. **E2 headline** — on the paper's 4-way grid, PROPOSED's DDR bus
//!    relieves way blocking: its busy-but-blocked share is strictly below
//!    CONV's (the Fig. 8 saturation story, now measured not inferred).
//! 4. **Timeline schema** — the Chrome trace-event JSON validates against
//!    the pinned schema, and a property test ties span durations back to
//!    the occupancy counters (Σ bus spans == bus busy time, exactly).

use std::collections::HashMap;

use ddrnand::bench::json::{self, Value};
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimReport};
use ddrnand::coordinator::experiments::{qos_point_config, QosSweepSpec};
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::observe::{validate_trace_json, ObserveReport, ResourceKind, ResourceUsage};
use ddrnand::proptest::check;

/// Everything deterministic in a [`SimReport`] (wall clock and the
/// `observe` block excluded) — the same digest `tests/sharded_engine.rs`
/// uses for engine bit-identity, reused here for observer transparency.
fn fingerprint(r: &SimReport) -> Vec<u64> {
    let mut f = vec![
        r.events,
        r.requests,
        r.bytes,
        r.pages_programmed,
        r.pages_read,
        r.blocks_erased,
        r.sim_time.as_ps() as u64,
        r.bandwidth_mbps.to_bits(),
        r.energy_nj_per_byte.to_bits(),
        r.latency_mean_us.to_bits(),
        r.latency_p50_us.to_bits(),
        r.latency_p99_us.to_bits(),
        r.waf.to_bits(),
        r.fairness.to_bits(),
    ];
    for s in &r.streams {
        f.push(s.requests);
        f.push(s.bandwidth_mbps.to_bits());
        f.push(s.latency_p99_us.to_bits());
    }
    f
}

fn observed(mut cfg: SsdConfig, timeline: bool) -> SsdConfig {
    cfg.observe.enabled = true;
    cfg.observe.timeline = timeline;
    cfg
}

fn row(o: &ObserveReport, ch: u16, kind: ResourceKind, idx: u16) -> &ResourceUsage {
    o.resources
        .iter()
        .find(|r| r.channel == ch && r.kind == kind && r.index == idx)
        .unwrap_or_else(|| panic!("missing {} row ch={ch} idx={idx}", kind.name()))
}

/// The observer's accounting identities, integer-exact:
///
/// * one bus row + `ways` way rows + `ways` chip rows per channel;
/// * per resource, busy + blocked + idle_queued + idle == wall clock;
/// * bus contention + GC barrier == Σ way blocked time;
/// * queue starvation + link backpressure == Σ way idle time.
fn occupancy_invariants(o: &ObserveReport, channels: usize, ways: usize) -> Result<(), String> {
    if o.wall_ps == 0 {
        return Err("wall_ps is zero".to_string());
    }
    let want_rows = channels * (1 + 2 * ways);
    if o.resources.len() != want_rows {
        return Err(format!(
            "expected {want_rows} resource rows, got {}",
            o.resources.len()
        ));
    }
    for r in &o.resources {
        if r.total_ps() != o.wall_ps {
            return Err(format!(
                "{} ch={} idx={}: busy {} + blocked {} + queued {} + idle {} = {} != wall {}",
                r.kind.name(),
                r.channel,
                r.index,
                r.busy_ps,
                r.blocked_ps,
                r.idle_queued_ps,
                r.idle_ps,
                r.total_ps(),
                o.wall_ps
            ));
        }
        if r.kind == ResourceKind::Bus && r.blocked_ps != 0 {
            return Err("the bus never blocks (it is the thing blocked *on*)".to_string());
        }
        if r.kind == ResourceKind::Chip && r.blocked_ps != 0 {
            return Err("chips never block (the array waits on nothing)".to_string());
        }
    }
    let way = o.totals(ResourceKind::Way);
    let blocked_sum = o.stalls.bus_contention_ps + o.stalls.gc_barrier_ps + o.stalls.map_fill_ps;
    if blocked_sum != way[1] {
        return Err(format!(
            "stall attribution leak: contention {} + barrier {} + map fill {} != Σ way blocked {}",
            o.stalls.bus_contention_ps, o.stalls.gc_barrier_ps, o.stalls.map_fill_ps, way[1]
        ));
    }
    let idle_sum = o.stalls.queue_starvation_ps + o.stalls.link_backpressure_ps;
    if idle_sum != way[3] {
        return Err(format!(
            "idle attribution leak: starvation {} + backpressure {} != Σ way idle {}",
            o.stalls.queue_starvation_ps, o.stalls.link_backpressure_ps, way[3]
        ));
    }
    Ok(())
}

/// Run `scenario` three times — observe off, on, on+timeline — and assert
/// the simulation outcome is bit-identical throughout while the observe
/// block appears exactly when asked for (and passes the accounting
/// identities when it does).
fn assert_observation_transparent<F>(label: &str, cfg: SsdConfig, scenario: F)
where
    F: Fn(SsdConfig) -> SimReport,
{
    assert!(
        cfg.validate().is_empty(),
        "{label}: config invalid: {:?}",
        cfg.validate()
    );
    let base = scenario(cfg.clone());
    assert!(
        base.observe.is_none(),
        "{label}: observation off must not attach an observe block"
    );
    let want = fingerprint(&base);
    for timeline in [false, true] {
        let r = scenario(observed(cfg.clone(), timeline));
        assert_eq!(
            fingerprint(&r),
            want,
            "{label}: observation (timeline={timeline}) perturbed the simulation"
        );
        let o = r
            .observe
            .as_ref()
            .unwrap_or_else(|| panic!("{label}: observation on but no observe block"));
        assert_eq!(
            o.trace_json.is_some(),
            timeline,
            "{label}: timeline buffer should exist iff requested"
        );
        occupancy_invariants(o, r.channels as usize, r.ways as usize)
            .unwrap_or_else(|e| panic!("{label} (timeline={timeline}): {e}"));
        assert!(
            o.wall_ps >= r.sim_time.as_ps() as u64,
            "{label}: observed wall clock ends before the last host completion"
        );
    }
}

// ---------------------------------------------------------------------------
// 1. Zero-perturbation goldens over every shipped scenario class.
// ---------------------------------------------------------------------------

#[test]
fn fresh_write_golden_is_observation_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_observation_transparent("fresh write", cfg, |c| {
        Campaign::new(c, RequestKind::Write, 120).run()
    });
}

#[test]
fn fresh_read_golden_is_observation_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Conv,
        ways: 2,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_observation_transparent("fresh read", cfg, |c| {
        Campaign::new(c, RequestKind::Read, 100).run()
    });
}

#[test]
fn steady_state_gc_golden_is_observation_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.steady.enabled = true;
    cfg.steady.over_provision = 0.15;
    cfg.steady.wear_level_spread = 16;
    assert_observation_transparent("steady-state GC", cfg, |c| {
        Campaign::new(c, RequestKind::Write, 150).run()
    });
}

#[test]
fn tiered_flash_golden_is_observation_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        cell: CellType::Mlc,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.tiering.enabled = true;
    cfg.tiering.slc_fraction = 0.5;
    assert_observation_transparent("tiered", cfg, |c| {
        Campaign::new(c, RequestKind::Write, 120).run()
    });
}

#[test]
fn multi_tenant_qos_golden_is_observation_invariant() {
    let spec = QosSweepSpec {
        requests: 80,
        ..QosSweepSpec::default()
    };
    let cfg = qos_point_config(
        &spec,
        InterfaceKind::Proposed,
        4,
        ddrnand::controller::sched::SchedKind::WeightedQos,
    )
    .expect("qos point config");
    assert_observation_transparent("multi-tenant qos", cfg, |c| {
        Campaign::multi_tenant(c, spec.tenants()).run()
    });
}

#[test]
fn observation_is_engine_invariant() {
    // Channel-sharded runs give each shard its own single-channel
    // observer slice, merged deterministically at end of run — so the
    // *entire* observe block (occupancy, stalls, and the trace-event
    // timeline byte for byte) must be identical at every thread count for
    // a fixed window width. (Against the classic serial engine only the
    // thread count is compared away: window width is a fidelity knob.)
    let mut cfg = observed(
        SsdConfig {
            iface: InterfaceKind::Proposed,
            ways: 4,
            blocks_per_chip: 512,
            ..SsdConfig::default()
        },
        true,
    );
    cfg.engine.window_ps = 1_000_000;
    let run_at = |threads: u16| {
        let mut c = cfg.clone();
        c.engine.threads = threads;
        Campaign::new(c, RequestKind::Write, 120).run()
    };
    let base = run_at(1);
    let a = base.observe.as_ref().expect("baseline observe block");
    for threads in [2u16, 4] {
        let got = run_at(threads);
        let b = got.observe.as_ref().expect("observe block");
        assert_eq!(a, b, "observe block diverged at {threads} threads");
    }
}

// ---------------------------------------------------------------------------
// 2. Randomized occupancy oracle.
// ---------------------------------------------------------------------------

#[test]
fn occupancy_oracle_partitions_wall_clock_exactly() {
    check(
        "occupancy states partition wall clock",
        14,
        0x0B5E_4E55,
        |rng| {
            let iface = rng.next_bounded(3) as usize;
            let channels = 1 + rng.next_bounded(2) as u16;
            let ways = [1u16, 2, 4][rng.next_bounded(3) as usize];
            let write = rng.next_bounded(2) == 0;
            let steady = rng.next_bounded(3) == 0;
            let requests = 10 + rng.next_bounded(40) as usize;
            (iface, channels, ways, write, steady, requests)
        },
        |&(iface, channels, ways, write, steady, requests)| {
            let mut cfg = SsdConfig {
                iface: InterfaceKind::ALL[iface],
                channels,
                ways,
                blocks_per_chip: if steady { 64 } else { 128 },
                ..SsdConfig::default()
            };
            if steady {
                cfg.steady.enabled = true;
                cfg.steady.over_provision = 0.15;
            }
            let cfg = observed(cfg, false);
            let errs = cfg.validate();
            if !errs.is_empty() {
                return Err(format!("config invalid: {errs:?}"));
            }
            let mode = if write { RequestKind::Write } else { RequestKind::Read };
            let r = Campaign::new(cfg, mode, requests).run();
            let o = r.observe.as_ref().ok_or("missing observe block")?;
            occupancy_invariants(o, channels as usize, ways as usize)
        },
        |&(iface, channels, ways, write, steady, requests)| {
            let mut out = Vec::new();
            if requests > 10 {
                out.push((iface, channels, ways, write, steady, requests / 2));
            }
            if ways > 1 {
                out.push((iface, channels, ways / 2, write, steady, requests));
            }
            if channels > 1 {
                out.push((iface, 1, ways, write, steady, requests));
            }
            if steady {
                out.push((iface, channels, ways, write, false, requests));
            }
            out
        },
    );
}

// ---------------------------------------------------------------------------
// 3. E2 headline: the DDR bus relieves way blocking.
// ---------------------------------------------------------------------------

#[test]
fn proposed_blocks_ways_less_than_conv_on_the_4way_grid() {
    // Fig. 8's mechanism, measured: with four ways sharing one bus, CONV's
    // slow SDR transfers keep ready ways waiting on the bus; PROPOSED's
    // DDR interface drains transfers fast enough that the blocked share
    // drops. The observer turns that story into one comparable number.
    let point = |iface| {
        let cfg = observed(
            SsdConfig {
                iface,
                ways: 4,
                blocks_per_chip: 512,
                ..SsdConfig::default()
            },
            false,
        );
        Campaign::new(cfg, RequestKind::Write, 120).run()
    };
    let conv = point(InterfaceKind::Conv);
    let prop = point(InterfaceKind::Proposed);
    let conv_blocked = conv.observe.as_ref().expect("conv observe").blocked_share(ResourceKind::Way);
    let prop_blocked = prop.observe.as_ref().expect("prop observe").blocked_share(ResourceKind::Way);
    assert!(
        conv_blocked > 0.0,
        "4 ways on one CONV bus must exhibit some bus contention"
    );
    assert!(
        prop_blocked < conv_blocked,
        "PROPOSED should relieve way blocking: blocked share {prop_blocked:.4} (PROPOSED) \
         vs {conv_blocked:.4} (CONV)"
    );
}

// ---------------------------------------------------------------------------
// 4. Timeline: pinned schema + span durations tie out to the counters.
// ---------------------------------------------------------------------------

#[test]
fn trace_timeline_validates_against_the_pinned_schema() {
    let mut cfg = observed(
        SsdConfig {
            iface: InterfaceKind::Proposed,
            ways: 4,
            blocks_per_chip: 64,
            ..SsdConfig::default()
        },
        true,
    );
    cfg.steady.enabled = true;
    cfg.steady.over_provision = 0.15;
    let r = Campaign::new(cfg, RequestKind::Write, 150).run();
    let o = r.observe.as_ref().expect("observe block");
    let trace = o.trace_json.as_deref().expect("timeline requested");
    validate_trace_json(trace).expect("pinned schema");
    // Pinned surface: Perfetto needs these to lay the tracks out.
    for needle in [
        "\"displayTimeUnit\":\"ns\"",
        "\"name\":\"process_name\"",
        "{\"name\":\"channel 0\"}",
        "{\"name\":\"bus\"}",
        "{\"name\":\"way 0\"}",
        "{\"name\":\"chip 0\"}",
        "{\"name\":\"gc\"}",
        "{\"name\":\"window\"}",
    ] {
        assert!(trace.contains(needle), "trace lost pinned element {needle}");
    }
    // The steady-state scenario collects garbage; the activations must
    // show up both as the counter and as instant marks on the gc track.
    assert!(o.gc_triggers > 0, "steady-state run should trigger GC");
    assert!(trace.contains("\"name\":\"gc_trigger\""), "missing gc_trigger instants");
}

/// Walk a validated trace and sum `E.args.ps - B.args.ps` per `(pid, tid)`
/// track. Validation already guaranteed per-track monotone timestamps and
/// stack-balanced spans, so array order is span order within a track.
fn span_sums_by_track(trace: &str) -> HashMap<(i64, i64), u64> {
    fn get<'a>(obj: &'a [(String, Value)], key: &str) -> Option<&'a Value> {
        obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
    fn num(obj: &[(String, Value)], key: &str) -> f64 {
        match get(obj, key) {
            Some(Value::Num(n)) => *n,
            _ => panic!("missing numeric {key}"),
        }
    }
    let root = json::parse(trace).expect("trace parses");
    let top = root.as_object().expect("trace top is an object");
    let events = match get(top, "traceEvents") {
        Some(Value::Array(a)) => a,
        _ => panic!("missing traceEvents"),
    };
    let mut stacks: HashMap<(i64, i64), Vec<u64>> = HashMap::new();
    let mut sums: HashMap<(i64, i64), u64> = HashMap::new();
    for ev in events {
        let e = ev.as_object().expect("event is an object");
        let ph = match get(e, "ph") {
            Some(Value::Str(s)) => s.as_str(),
            _ => panic!("missing ph"),
        };
        if ph != "B" && ph != "E" {
            continue;
        }
        let track = (num(e, "pid") as i64, num(e, "tid") as i64);
        let args = match get(e, "args") {
            Some(Value::Object(a)) => a.as_slice(),
            _ => panic!("missing args"),
        };
        let ps = num(args, "ps") as u64;
        if ph == "B" {
            stacks.entry(track).or_default().push(ps);
        } else {
            let begin = stacks
                .entry(track)
                .or_default()
                .pop()
                .expect("validated: E has a matching B");
            *sums.entry(track).or_insert(0) += ps - begin;
        }
    }
    sums
}

#[test]
fn trace_span_durations_tie_out_to_occupancy_counters() {
    // Property: the timeline and the occupancy table are two views of one
    // accounting. Bus and chip spans mirror their busy counters exactly
    // (both are granted intervals the observer also classifies as BUSY).
    // A way's span covers dispatch-to-completion, which is its busy time
    // plus any blocked/queued waits *inside* the job — so the span total
    // is bounded by those buckets, never by idle time.
    check(
        "trace spans vs occupancy counters",
        10,
        0x7E11_1A5E,
        |rng| {
            let iface = rng.next_bounded(3) as usize;
            let ways = [1u16, 2, 4][rng.next_bounded(3) as usize];
            let write = rng.next_bounded(2) == 0;
            let requests = 8 + rng.next_bounded(24) as usize;
            (iface, ways, write, requests)
        },
        |&(iface, ways, write, requests)| {
            let cfg = observed(
                SsdConfig {
                    iface: InterfaceKind::ALL[iface],
                    ways,
                    blocks_per_chip: 128,
                    ..SsdConfig::default()
                },
                true,
            );
            let mode = if write { RequestKind::Write } else { RequestKind::Read };
            let r = Campaign::new(cfg, mode, requests).run();
            let o = r.observe.as_ref().ok_or("missing observe block")?;
            let trace = o.trace_json.as_deref().ok_or("missing timeline")?;
            validate_trace_json(trace)?;
            let sums = span_sums_by_track(trace);
            let span = |ch: u16, tid: u16| sums.get(&(ch as i64, tid as i64)).copied().unwrap_or(0);
            for ch in 0..r.channels {
                let bus = row(o, ch, ResourceKind::Bus, 0);
                if span(ch, 0) != bus.busy_ps {
                    return Err(format!(
                        "ch{ch}: Σ bus spans {} != bus busy {}",
                        span(ch, 0),
                        bus.busy_ps
                    ));
                }
                for w in 0..ways {
                    let chip = row(o, ch, ResourceKind::Chip, w);
                    let chip_span = span(ch, 1 + ways + w);
                    if chip_span != chip.busy_ps {
                        return Err(format!(
                            "ch{ch} chip{w}: Σ array spans {chip_span} != chip busy {}",
                            chip.busy_ps
                        ));
                    }
                    let way = row(o, ch, ResourceKind::Way, w);
                    let way_span = span(ch, 1 + w);
                    let upper = way.busy_ps + way.blocked_ps + way.idle_queued_ps;
                    if way_span < way.busy_ps || way_span > upper {
                        return Err(format!(
                            "ch{ch} way{w}: Σ job spans {way_span} outside [busy {}, \
                             busy+blocked+queued {upper}]",
                            way.busy_ps
                        ));
                    }
                }
            }
            Ok(())
        },
        |&(iface, ways, write, requests)| {
            let mut out = Vec::new();
            if requests > 8 {
                out.push((iface, ways, write, requests / 2));
            }
            if ways > 1 {
                out.push((iface, ways / 2, write, requests));
            }
            out
        },
    );
}

// ---------------------------------------------------------------------------
// CI hook: validate a timeline artifact produced by `ddrnand analyze`.
// ---------------------------------------------------------------------------

/// The CI observe lane runs `ddrnand analyze --trace <file>` and then
/// re-runs this test with `OBSERVE_TRACE_FILE` pointing at the artifact,
/// proving the *shipped binary's* output — not just the library path —
/// satisfies the pinned schema. Without the env var this is a no-op.
#[test]
fn published_trace_artifact_validates() {
    let Ok(path) = std::env::var("OBSERVE_TRACE_FILE") else {
        eprintln!("OBSERVE_TRACE_FILE not set; skipping artifact validation");
        return;
    };
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("cannot read trace artifact {path}: {e}"));
    validate_trace_json(&text).unwrap_or_else(|e| panic!("artifact {path} failed schema: {e}"));
    assert!(
        text.contains("\"displayTimeUnit\":\"ns\""),
        "artifact {path} lost the pinned time unit"
    );
}
