//! Multi-tenant host path + QoS scheduling integration tests (PR 5).
//!
//! Covers the three contracts of the refactor:
//! 1. **Equivalence** — the `RoundRobin` way scheduler is bit-identical to
//!    the pre-refactor hard-coded arbiter (kept verbatim below as the
//!    oracle), and the multi-queue admission path with one queue is
//!    bit-identical to the classic SATA queue-depth path.
//! 2. **Dormancy** — configs without active `[host]`/`[qos]` sections
//!    reproduce the pre-refactor simulator exactly, through `SimWorkspace`
//!    reuse and across thread-pool sizes.
//! 3. **The E9 headline** — under a saturating two-tenant mix,
//!    `ReadPriority` and `WeightedQos` cut the latency-critical tenant's
//!    p99 versus `RoundRobin` while total throughput stays within 5%.

use ddrnand::config::SsdConfig;
use ddrnand::controller::sched::{Grant, SchedKind, WayScheduler};
use ddrnand::controller::way::WayState;
use ddrnand::coordinator::campaign::{Campaign, SimWorkspace};
use ddrnand::coordinator::experiments::{QosCell, QosSweepSpec, run_qos_sweep};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::coordinator::ssd::SsdSim;
use ddrnand::host::link::HostLinkKind;
use ddrnand::host::trace::{RequestKind, TraceGen};
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::util::prng::Prng;
use ddrnand::util::time::Ps;

/// The pre-refactor channel arbiter, verbatim (the body of the old
/// `ChannelState::next_way_wanting_bus`, including its `(class, rr-dist,
/// idx)` bookkeeping), wrapped in the new trait. Dispatch grants always
/// name the queue head — the old arbiter was FIFO within a way.
struct OldArbiter {
    rr_next: usize,
}

impl WayScheduler for OldArbiter {
    fn pick(&mut self, ways: &[WayState], now: Ps) -> Option<Grant> {
        let n = ways.len();
        let mut best: Option<(u8, usize, usize)> = None; // (class, rr-dist, idx)
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            if let Some(class) = ways[i].bus_class(now) {
                if class == 0 {
                    self.rr_next = (i + 1) % n;
                    return Some(Grant { way: i, job: 0 });
                }
                match best {
                    Some((c, _, _)) if c <= class => {}
                    _ => best = Some((class, off, i)),
                }
            }
        }
        best.map(|(_, _, i)| {
            self.rr_next = (i + 1) % n;
            Grant { way: i, job: 0 }
        })
    }

    fn reset(&mut self) {
        self.rr_next = 0;
    }
}

fn fingerprint(sim: &SsdSim, events: u64) -> (u64, Ps, u64, u64, u64, f64, f64) {
    (
        events,
        sim.finished_at(),
        sim.counters.pages_programmed,
        sim.counters.pages_read,
        sim.counters.requests_done,
        sim.latency.mean(),
        sim.bandwidth_mbps(),
    )
}

/// Randomized oracle: across random geometries, interfaces, queue depths
/// and workload mixes, the default `RoundRobin` scheduler produces
/// bit-identical runs to the pre-refactor arbiter.
#[test]
fn round_robin_scheduler_matches_pre_refactor_arbiter() {
    let mut rng = Prng::new(0xE9_0A);
    for case in 0..12 {
        let channels = 1 + rng.next_bounded(2) as u16;
        let ways = 1 + rng.next_bounded(4) as u16;
        let iface = match rng.next_bounded(3) {
            0 => InterfaceKind::Conv,
            1 => InterfaceKind::SyncOnly,
            _ => InterfaceKind::Proposed,
        };
        let queue_depth = 1 + rng.next_bounded(8) as u32;
        let n = 10 + rng.next_bounded(25) as usize;
        let write_fraction = 0.25 + 0.5 * (rng.next_bounded(100) as f64 / 100.0);
        let trace_seed = rng.next_bounded(u64::MAX / 2);
        let cfg = SsdConfig {
            iface,
            channels,
            ways,
            queue_depth,
            blocks_per_chip: 128,
            ..SsdConfig::default()
        };
        let trace = TraceGen::default()
            .mixed_sequential(n, write_fraction, trace_seed)
            .requests;
        let run = |inject_oracle: bool| {
            let mut sim = SsdSim::new(cfg.clone(), trace.clone());
            if inject_oracle {
                sim.set_way_schedulers(|| Box::new(OldArbiter { rr_next: 0 }));
            }
            sim.prefill_for_reads();
            let r = sim.run();
            fingerprint(&sim, r.events)
        };
        assert_eq!(
            run(false),
            run(true),
            "case {case}: RoundRobin diverged from the pre-refactor arbiter \
             (ch={channels} ways={ways} {iface:?} qd={queue_depth} n={n})"
        );
    }
}

/// A single-queue multi-queue link at the same depth is bit-identical to
/// the classic SATA queue-depth admission path — the new front end changes
/// mechanism, not behaviour, until queues/arbitration are actually used.
#[test]
fn single_queue_multi_queue_matches_sata_admission() {
    let mk = |link: HostLinkKind| {
        let mut cfg = SsdConfig {
            ways: 4,
            blocks_per_chip: 128,
            queue_depth: 4,
            ..SsdConfig::default()
        };
        cfg.host.link = link;
        cfg.host.queues = 1;
        cfg.host.queue_depth = 4;
        cfg
    };
    let run = |link: HostLinkKind, mode: RequestKind| {
        let trace = TraceGen::default().sequential(mode, 20).requests;
        let mut sim = SsdSim::new(mk(link), trace);
        sim.prefill_for_reads();
        let r = sim.run();
        fingerprint(&sim, r.events)
    };
    for mode in [RequestKind::Write, RequestKind::Read] {
        assert_eq!(
            run(HostLinkKind::Sata, mode),
            run(HostLinkKind::MultiQueue, mode),
            "{mode:?}"
        );
    }
}

/// Golden dormancy: a config whose `[host]`/`[qos]` sections carry
/// non-default but *dormant* values (SATA link, round-robin scheduler)
/// shares the reuse key with the plain config and reproduces its runs
/// bit-identically through `SimWorkspace` reuse.
#[test]
fn dormant_host_qos_bit_identical_through_reuse() {
    let base = SsdConfig {
        ways: 2,
        blocks_per_chip: 256,
        ..SsdConfig::default()
    };
    let mut dormant = base.clone();
    dormant.host.queues = 64;
    dormant.host.queue_depth = 3;
    dormant.qos.weights = [1, 1, 1, 1];
    assert_eq!(SsdSim::reuse_key(&base), SsdSim::reuse_key(&dormant));
    let fresh = Campaign::new(base.clone(), RequestKind::Write, 15).run();
    // Dirty a workspace with the dormant config, then reuse it for the
    // base config: the cached simulator is retargeted, not rebuilt.
    let mut ws = SimWorkspace::new();
    Campaign::new(dormant, RequestKind::Write, 12).run_in(&mut ws);
    let reused = Campaign::new(base, RequestKind::Write, 15).run_in(&mut ws);
    assert_eq!(ws.reuses, 1, "the dormant config must not fragment reuse");
    assert_eq!(reused.events, fresh.events);
    assert_eq!(reused.sim_time, fresh.sim_time);
    assert_eq!(reused.bandwidth_mbps, fresh.bandwidth_mbps);
    assert_eq!(reused.energy_nj_per_byte, fresh.energy_nj_per_byte);
    assert_eq!(reused.pages_programmed, fresh.pages_programmed);
    assert!(reused.streams.is_empty(), "single-stream runs stay stream-free");
}

/// Sparse stream ids (v3 traces need not be dense) produce no phantom
/// report rows: only streams that actually carried requests appear, and
/// a single-tenant run keeps its NaN fairness index instead of being
/// dragged to 1/n by empty phantoms.
#[test]
fn sparse_stream_ids_produce_no_phantom_streams() {
    use ddrnand::host::trace::{StreamTag, Trace, CLASS_NORMAL};
    let mut trace = TraceGen::default().sequential(RequestKind::Write, 6);
    trace.streams = vec![
        StreamTag {
            stream: 3,
            class: CLASS_NORMAL
        };
        6
    ];
    let cfg = SsdConfig {
        ways: 2,
        blocks_per_chip: 128,
        ..SsdConfig::default()
    };
    let rep = ddrnand::coordinator::campaign::run_trace(&cfg, &trace);
    assert_eq!(rep.requests, 6);
    assert_eq!(rep.streams.len(), 1, "only the tagged stream is reported");
    assert_eq!(rep.streams[0].stream, 3);
    assert_eq!(rep.streams[0].requests, 6);
    assert!(
        rep.fairness.is_nan(),
        "one real tenant has no fairness story, got {}",
        rep.fairness
    );
}

fn headline_spec() -> QosSweepSpec {
    QosSweepSpec {
        ways: vec![4],
        ifaces: vec![InterfaceKind::Proposed],
        schedulers: SchedKind::ALL.to_vec(),
        requests: 120,
        write_mbps: 55.0,
        read_mbps: 4.0,
        blocks_per_chip: 256,
        ..QosSweepSpec::default()
    }
}

fn qos_fingerprints(cells: &[QosCell]) -> Vec<(u64, Ps, f64, String)> {
    cells
        .iter()
        .map(|c| {
            (
                c.report.events,
                c.report.sim_time,
                c.report.streams[0].latency_p99_us,
                format!("{:?}/{}/{}", c.iface, c.ways, c.sched.name()),
            )
        })
        .collect()
}

/// The E9 headline, plus driver determinism: under a saturating
/// two-tenant mix, `ReadPriority` and `WeightedQos` cut the
/// latency-critical tenant's p99 versus `RoundRobin` while total
/// throughput stays within 5% — and the sweep is identical across
/// thread-pool sizes.
#[test]
fn qos_policies_cut_read_tenant_p99_at_stable_throughput() {
    let spec = headline_spec();
    let cells = run_qos_sweep(&spec, &ThreadPool::new(2));
    assert_eq!(cells.len(), 3);
    for pool_size in [1, 8] {
        let again = run_qos_sweep(&spec, &ThreadPool::new(pool_size));
        assert_eq!(
            qos_fingerprints(&cells),
            qos_fingerprints(&again),
            "sweep must be deterministic across pool size {pool_size}"
        );
    }
    let cell = |k: SchedKind| cells.iter().find(|c| c.sched == k).expect("grid point");
    let read_p99 = |k: SchedKind| {
        let s = &cell(k).report.streams[0];
        assert_eq!(s.stream, 0, "stream 0 is the latency-critical reader");
        assert!(s.requests > 0);
        s.latency_p99_us
    };
    let rr = read_p99(SchedKind::RoundRobin);
    let rp = read_p99(SchedKind::ReadPriority);
    let wq = read_p99(SchedKind::WeightedQos);
    assert!(
        rp < 0.5 * rr,
        "ReadPriority must cut the read tenant's p99 well below RoundRobin: {rp} vs {rr} us"
    );
    assert!(
        wq < 0.8 * rr,
        "WeightedQos must cut the read tenant's p99 below RoundRobin: {wq} vs {rr} us"
    );
    let rr_bw = cell(SchedKind::RoundRobin).report.bandwidth_mbps;
    for k in [SchedKind::ReadPriority, SchedKind::WeightedQos] {
        let bw = cell(k).report.bandwidth_mbps;
        assert!(
            (bw - rr_bw).abs() / rr_bw < 0.05,
            "{}: total throughput must stay within 5% of RoundRobin ({bw} vs {rr_bw} MB/s)",
            k.name()
        );
    }
    // The write tenant genuinely saturates the device in every policy:
    // it cannot achieve its (over-ceiling) offered load, yet still moves
    // a solid fraction of it through the measurement window.
    for c in &cells {
        let writer = &c.report.streams[1];
        assert!(
            writer.bandwidth_mbps > 0.4 * spec.write_mbps,
            "{}: writer achieved only {} MB/s",
            c.sched.name(),
            writer.bandwidth_mbps
        );
        assert!(writer.bandwidth_mbps < spec.write_mbps, "{}", c.sched.name());
    }
}

/// Weighted host-queue arbitration is live end to end: a closed-loop
/// two-tenant run over the multi-queue link completes with per-stream
/// accounting under both arbitration policies, and per-queue depths hold.
#[test]
fn multi_queue_weighted_arbitration_end_to_end() {
    use ddrnand::coordinator::campaign::{AccessPattern, TenantSpec};
    use ddrnand::host::link::QueueArb;
    use ddrnand::host::trace::{CLASS_BULK, CLASS_URGENT};
    let run = |arb: QueueArb| {
        let mut cfg = SsdConfig {
            ways: 2,
            blocks_per_chip: 128,
            ..SsdConfig::default()
        };
        cfg.host.link = HostLinkKind::MultiQueue;
        cfg.host.queues = 2;
        cfg.host.queue_depth = 2;
        cfg.host.arbitration = arb;
        let tenants = vec![
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_URGENT,
                requests: 10,
                offered_mbps: None,
            },
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_BULK,
                requests: 10,
                offered_mbps: None,
            },
        ];
        Campaign::multi_tenant(cfg, tenants).run()
    };
    for arb in [QueueArb::RoundRobin, QueueArb::Weighted] {
        let r = run(arb);
        assert_eq!(r.requests, 20, "{arb:?}");
        assert_eq!(r.streams.len(), 2);
        assert_eq!(r.streams[0].requests, 10);
        assert_eq!(r.streams[1].requests, 10);
        assert!(r.fairness > 0.0);
    }
    // The two arbitration policies genuinely schedule differently: the
    // urgent queue's 8:2 fetch share front-loads its requests, which
    // shows up somewhere in the run's timing fingerprint.
    let fp = |arb: QueueArb| {
        let r = run(arb);
        (
            r.sim_time,
            r.latency_mean_us.to_bits(),
            r.streams[0].latency_mean_us.to_bits(),
        )
    };
    assert_ne!(
        fp(QueueArb::RoundRobin),
        fp(QueueArb::Weighted),
        "weighted arbitration must change the admission interleaving"
    );
}
