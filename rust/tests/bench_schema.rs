//! The BENCH_engine.json pipeline: the committed artifact at the repo root
//! and every freshly generated perf log must conform to the
//! `ddrnand-bench-v2` schema, so drift between the writer
//! (`src/bench.rs::PerfLog`), the CI bench job and downstream consumers
//! fails loudly instead of rotting.
//!
//! CI runs this suite three ways: in the normal test step (validates the
//! committed file); right after `cargo bench --bench bench_engine` with
//! `BENCH_REQUIRE_RESULTS=1`, which additionally demands a non-empty
//! results array; and with `BENCH_BASELINE=<path>` pointing at the
//! previously committed artifact, which arms the blocking regression gate
//! against the freshly measured log.

use ddrnand::bench::{parse_bench_metrics, regression_gate, validate_bench_json, PerfLog};

fn repo_root_log() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine.json")
}

#[test]
fn committed_bench_log_is_schema_valid() {
    let path = repo_root_log();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let summary = validate_bench_json(&text)
        .unwrap_or_else(|e| panic!("{}: schema drift: {e}", path.display()));
    assert_eq!(summary.bench, "bench_engine");
    if std::env::var_os("BENCH_REQUIRE_RESULTS").is_some() {
        assert!(
            summary.results > 0,
            "{}: bench ran but recorded no results — writer/pipeline drift",
            path.display()
        );
        // The v2 trajectory must include the multi-threaded sharded runs,
        // not just serial measurements re-tagged.
        let metrics = parse_bench_metrics(&text).unwrap();
        assert!(
            metrics.iter().any(|m| m.threads >= 2),
            "{}: no multi-threaded record in a measured log",
            path.display()
        );
    }
}

/// The trajectory gate. For the repo's whole history the committed
/// BENCH_engine.json stayed the bootstrap placeholder — CI measured a log
/// on every push and then threw it away, so the "trajectory" had zero
/// points. CI now commits the measured log back to main and sets
/// `BENCH_EXPECT_COMMITTED=1` on this suite first: the artifact about to
/// become the committed trajectory must carry at least one real measured
/// record (with the real creation stamp the bootstrap file lacks), so an
/// empty trajectory can never regenerate silently.
#[test]
fn bench_trajectory_is_not_the_bootstrap_placeholder() {
    if std::env::var_os("BENCH_EXPECT_COMMITTED").is_none() {
        return;
    }
    let path = repo_root_log();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let metrics = parse_bench_metrics(&text)
        .unwrap_or_else(|e| panic!("{}: schema drift: {e}", path.display()));
    assert!(
        !metrics.is_empty(),
        "{}: still the bootstrap placeholder — the bench measured nothing",
        path.display()
    );
    assert!(
        metrics.iter().any(|m| m.value.is_some()),
        "{}: records exist but every value is null",
        path.display()
    );
    assert!(
        !text.contains("\"created_unix\": 0,"),
        "{}: missing the measurement timestamp a real bench run stamps",
        path.display()
    );
}

/// The writer and the validator agree: whatever `PerfLog` emits validates,
/// including escapes, non-finite values, and engine tags.
#[test]
fn generated_log_round_trips_through_validator() {
    let mut log = PerfLog::new("bench_engine");
    log.push("event_queue_100k/calendar", "ms_per_iter_mean", 1.25, 20);
    log.push("speedup \"quoted\"\n", "ratio", 1.7, 1);
    log.push("nan_case", "ms", f64::NAN, 3);
    log.push_tagged("sharded_steady_churn/4_threads", "events_per_sec", 2.1e6, 1, 4, 0);
    let summary = validate_bench_json(&log.to_json()).expect("writer output must validate");
    assert_eq!(summary.results, 4);
    // And the metric extractor sees the same records, tags included.
    let metrics = parse_bench_metrics(&log.to_json()).unwrap();
    assert_eq!(metrics.len(), 4);
    assert_eq!(metrics[0].value, Some(1.25));
    assert_eq!(metrics[0].threads, 1);
    assert_eq!(metrics[2].value, None); // NaN serialized as null
    assert_eq!(metrics[3].threads, 4);
    assert_eq!(metrics[3].window_ps, 0);
    // The empty log (a fresh checkout before any bench run) validates too.
    let empty = PerfLog::new("bench_engine");
    assert_eq!(validate_bench_json(&empty.to_json()).unwrap().results, 0);
}

#[test]
fn validator_rejects_drifted_logs() {
    // Missing schema key.
    assert!(validate_bench_json(r#"{"bench": "x", "results": []}"#).is_err());
    // results not an array.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x", "results": {}}"#
    )
    .is_err());
    // Record missing a required field.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1,
                         "threads": 1, "window_ps": 0}]}"#
    )
    .is_err());
    // n must be a positive integer.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 0,
                         "threads": 1, "window_ps": 0}]}"#
    )
    .is_err());
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 2.5,
                         "threads": 1, "window_ps": 0}]}"#
    )
    .is_err());
    // value must be numeric or null.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": "fast", "n": 1,
                         "threads": 1, "window_ps": 0}]}"#
    )
    .is_err());
    // Not JSON at all / trailing garbage.
    assert!(validate_bench_json("schema: yaml").is_err());
    assert!(validate_bench_json(r#"{"schema": "ddrnand-bench-v2"} extra"#).is_err());
    // Unknown top-level keys are tolerated (created_unix, note).
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x", "created_unix": 0,
            "note": "free text", "results": [
              {"name": "a", "metric": "ms", "value": null, "n": 1,
               "threads": 1, "window_ps": 0}]}"#
    )
    .is_ok());
}

/// The v2 schema pin: logs written before the parallel engine — the v1
/// schema id, or records lacking the engine tags — are schema drift, not
/// grandfathered entries. A perf number without its thread count cannot be
/// placed on the parallel-engine trajectory.
#[test]
fn v2_schema_pins_engine_tags() {
    // The old schema id is rejected outright.
    let err = validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x", "results": []}"#,
    )
    .unwrap_err();
    assert!(err.contains("bad schema value"), "{err}");
    // A v2 log whose record omits `threads` is rejected...
    let err = validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 1,
                         "window_ps": 0}]}"#,
    )
    .unwrap_err();
    assert!(err.contains("threads"), "{err}");
    // ...as is one omitting `window_ps`...
    let err = validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 1,
                         "threads": 2}]}"#,
    )
    .unwrap_err();
    assert!(err.contains("window_ps"), "{err}");
    // ...or carrying out-of-domain tags.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 1,
                         "threads": 0, "window_ps": 0}]}"#
    )
    .is_err());
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 1,
                         "threads": 2, "window_ps": -1}]}"#
    )
    .is_err());
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 1,
                         "threads": 2.5, "window_ps": 0}]}"#
    )
    .is_err());
}

fn log_with(records: &[(&str, &str, f64, u16, u64)]) -> String {
    let mut log = PerfLog::new("bench_engine");
    for &(name, metric, value, threads, window_ps) in records {
        log.push_tagged(name, metric, value, 1, threads, window_ps);
    }
    log.to_json()
}

/// The regression-gate semantics CI relies on: throughput and speedup
/// records block on >tolerance drops; wall-clock records stay advisory;
/// the bootstrap (empty) baseline gates nothing.
#[test]
fn regression_gate_blocks_throughput_drops() {
    let baseline = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 2.0e6, 4, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 2.0, 4, 0),
        ("event_queue_100k/calendar", "ms_per_iter_mean", 1.0, 1, 0),
    ]);
    // Identical numbers: clean.
    assert_eq!(regression_gate(&baseline, &baseline, 0.15).unwrap(), Vec::<String>::new());
    // A 10% dip is inside the 15% tolerance.
    let dip = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 1.8e6, 4, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 1.9, 4, 0),
        ("event_queue_100k/calendar", "ms_per_iter_mean", 1.0, 1, 0),
    ]);
    assert!(regression_gate(&baseline, &dip, 0.15).unwrap().is_empty());
    // A 25% throughput drop blocks.
    let drop = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 1.5e6, 4, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 2.0, 4, 0),
        ("event_queue_100k/calendar", "ms_per_iter_mean", 1.0, 1, 0),
    ]);
    let failures = regression_gate(&baseline, &drop, 0.15).unwrap();
    assert_eq!(failures.len(), 1, "{failures:?}");
    assert!(failures[0].contains("sharded_steady_churn/4_threads"), "{failures:?}");
    // A speedup collapse blocks too.
    let slow = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 2.0e6, 4, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 1.0, 4, 0),
        ("event_queue_100k/calendar", "ms_per_iter_mean", 1.0, 1, 0),
    ]);
    assert_eq!(regression_gate(&baseline, &slow, 0.15).unwrap().len(), 1);
    // Wall-clock regressions are advisory: a slower ms_per_iter alone passes.
    let lagging = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 2.0e6, 4, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 2.0, 4, 0),
        ("event_queue_100k/calendar", "ms_per_iter_mean", 40.0, 1, 0),
    ]);
    assert!(regression_gate(&baseline, &lagging, 0.15).unwrap().is_empty());
    // A gated record vanishing from the new log blocks (renames must
    // re-baseline explicitly, not silently drop coverage).
    let missing = log_with(&[
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 2.0, 4, 0),
    ]);
    assert_eq!(regression_gate(&baseline, &missing, 0.15).unwrap().len(), 1);
    // Records match on their engine tags: the same name at different
    // thread counts is a different measurement, and its absence blocks.
    let retagged = log_with(&[
        ("sharded_steady_churn/4_threads", "events_per_sec", 2.0e6, 2, 0),
        ("sharded_steady_churn/4_threads/speedup_vs_1thread", "ratio", 2.0, 4, 0),
    ]);
    assert_eq!(regression_gate(&baseline, &retagged, 0.15).unwrap().len(), 1);
    // The bootstrap baseline (no results yet) gates nothing.
    let empty = PerfLog::new("bench_engine").to_json();
    assert!(regression_gate(&empty, &drop, 0.15).unwrap().is_empty());
    // Garbage on either side is an error, not a pass.
    assert!(regression_gate("nope", &baseline, 0.15).is_err());
    assert!(regression_gate(&baseline, "nope", 0.15).is_err());
}

/// The CI hook: with `BENCH_BASELINE=<path>` set, compare the committed
/// baseline against the freshly benched repo-root log and fail the suite
/// on any blocking regression. Skips silently when the env var is unset
/// (normal local runs) or when the baseline is the bootstrap artifact.
#[test]
fn bench_regression_gate_vs_baseline() {
    let Some(baseline_path) = std::env::var_os("BENCH_BASELINE") else {
        return;
    };
    let baseline = std::fs::read_to_string(&baseline_path)
        .unwrap_or_else(|e| panic!("{}: {e}", std::path::Path::new(&baseline_path).display()));
    let current_path = repo_root_log();
    let current = std::fs::read_to_string(&current_path)
        .unwrap_or_else(|e| panic!("{}: {e}", current_path.display()));
    let failures = regression_gate(&baseline, &current, 0.15)
        .unwrap_or_else(|e| panic!("regression gate could not run: {e}"));
    assert!(
        failures.is_empty(),
        "perf regression vs committed baseline:\n  {}",
        failures.join("\n  ")
    );
}
