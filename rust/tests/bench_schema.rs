//! The BENCH_engine.json pipeline: the committed artifact at the repo root
//! and every freshly generated perf log must conform to the
//! `ddrnand-bench-v1` schema, so drift between the writer
//! (`src/bench.rs::PerfLog`), the CI bench job and downstream consumers
//! fails loudly instead of rotting.
//!
//! CI runs this suite twice: once in the normal test step (validates the
//! committed file), and once right after `cargo bench --bench bench_engine`
//! with `BENCH_REQUIRE_RESULTS=1`, which additionally demands a non-empty
//! results array — i.e. the bench actually recorded real numbers.

use ddrnand::bench::{validate_bench_json, PerfLog};

fn repo_root_log() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine.json")
}

#[test]
fn committed_bench_log_is_schema_valid() {
    let path = repo_root_log();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    let summary = validate_bench_json(&text)
        .unwrap_or_else(|e| panic!("{}: schema drift: {e}", path.display()));
    assert_eq!(summary.bench, "bench_engine");
    if std::env::var_os("BENCH_REQUIRE_RESULTS").is_some() {
        assert!(
            summary.results > 0,
            "{}: bench ran but recorded no results — writer/pipeline drift",
            path.display()
        );
    }
}

/// The writer and the validator agree: whatever `PerfLog` emits validates,
/// including escapes and non-finite values.
#[test]
fn generated_log_round_trips_through_validator() {
    let mut log = PerfLog::new("bench_engine");
    log.push("event_queue_100k/calendar", "ms_per_iter_mean", 1.25, 20);
    log.push("speedup \"quoted\"\n", "ratio", 1.7, 1);
    log.push("nan_case", "ms", f64::NAN, 3);
    let summary = validate_bench_json(&log.to_json()).expect("writer output must validate");
    assert_eq!(summary.results, 3);
    // The empty log (a fresh checkout before any bench run) validates too.
    let empty = PerfLog::new("bench_engine");
    assert_eq!(validate_bench_json(&empty.to_json()).unwrap().results, 0);
}

#[test]
fn validator_rejects_drifted_logs() {
    // Missing schema key.
    assert!(validate_bench_json(r#"{"bench": "x", "results": []}"#).is_err());
    // Wrong schema version.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v2", "bench": "x", "results": []}"#
    )
    .is_err());
    // results not an array.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x", "results": {}}"#
    )
    .is_err());
    // Record missing a required field.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1}]}"#
    )
    .is_err());
    // n must be a positive integer.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 0}]}"#
    )
    .is_err());
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": 1, "n": 2.5}]}"#
    )
    .is_err());
    // value must be numeric or null.
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x",
            "results": [{"name": "a", "metric": "ms", "value": "fast", "n": 1}]}"#
    )
    .is_err());
    // Not JSON at all / trailing garbage.
    assert!(validate_bench_json("schema: yaml").is_err());
    assert!(validate_bench_json(r#"{"schema": "ddrnand-bench-v1"} extra"#).is_err());
    // Unknown top-level keys are tolerated (created_unix, note).
    assert!(validate_bench_json(
        r#"{"schema": "ddrnand-bench-v1", "bench": "x", "created_unix": 0,
            "note": "free text", "results": [
              {"name": "a", "metric": "ms", "value": null, "n": 1}]}"#
    )
    .is_ok());
}
