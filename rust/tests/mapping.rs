//! Conformance suite for the demand-paged mapping tier
//! (`ddrnand::controller::ftl::demand`, DESIGN.md §13):
//!
//! 1. **Dormant-section golden** — a `[mapping]` section left in resident
//!    mode is bit-identical to no section at all, *including* through
//!    workspace reuse (the dormant knobs normalize out of the reuse key).
//! 2. **Warm-cache golden** — a cache sized to hold every translation page
//!    initializes fully resident, can never miss, and reproduces the
//!    resident simulator's results bit for bit end to end.
//! 3. **Translation traffic** — an undersized cache injects real flash
//!    reads/programs (visible in the report counters and the WAF), defers
//!    host ops in demand mode, and overlaps them in FMMU mode.
//! 4. **Observer attribution** — map-fill bus grants land in their own
//!    stall cause and the blocked-time accounting still ties out exactly.

use ddrnand::config::{MapMode, SsdConfig};
use ddrnand::coordinator::campaign::{Campaign, SimReport, SimWorkspace};
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;

/// Everything deterministic in a [`SimReport`] except the mapping-tier
/// counters themselves (those are what the warm-cache golden expects to
/// differ: hits accrue, but the DES outcome must not move).
fn fingerprint(r: &SimReport) -> Vec<u64> {
    vec![
        r.events,
        r.requests,
        r.bytes,
        r.pages_programmed,
        r.pages_read,
        r.blocks_erased,
        r.sim_time.as_ps() as u64,
        r.bandwidth_mbps.to_bits(),
        r.energy_nj_per_byte.to_bits(),
        r.latency_mean_us.to_bits(),
        r.latency_p50_us.to_bits(),
        r.latency_p99_us.to_bits(),
        r.waf.to_bits(),
    ]
}

/// Small SLC array: 2 ways x 128 blocks x 64 pages = 16,384 physical
/// pages, 14,745 logical; at 64 entries per translation page the map
/// spans 231 translation pages.
fn base_cfg() -> SsdConfig {
    SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 2,
        blocks_per_chip: 128,
        ..SsdConfig::default()
    }
}

fn demand_cfg(cache_pages: u64, mode: MapMode) -> SsdConfig {
    let mut c = base_cfg();
    c.mapping.mode = mode;
    c.mapping.cache_pages = cache_pages;
    c.mapping.entries_per_page = 64;
    assert!(c.validate().is_empty(), "{:?}", c.validate());
    c
}

#[test]
fn dormant_mapping_section_is_bit_identical_through_reuse() {
    // Resident mode with non-default knobs: the knobs are dormant and must
    // neither perturb the run nor force a workspace rebuild.
    let plain = base_cfg();
    let mut dormant = base_cfg();
    dormant.mapping.cache_pages = 9999;
    dormant.mapping.entries_per_page = 77;
    assert!(dormant.validate().is_empty());

    let fresh = Campaign::new(plain.clone(), RequestKind::Write, 100).run();
    let mut ws = SimWorkspace::new();
    let a = Campaign::new(plain, RequestKind::Write, 100).run_in(&mut ws);
    let b = Campaign::new(dormant, RequestKind::Write, 100).run_in(&mut ws);
    assert_eq!(fingerprint(&a), fingerprint(&fresh));
    assert_eq!(fingerprint(&b), fingerprint(&fresh));
    assert_eq!(a.map_hits + a.map_misses, 0, "resident mode consults no cache");
    assert_eq!(b.map_hits + b.map_misses, 0);
    assert_eq!(ws.builds, 1, "dormant [mapping] must not change the reuse key");
    assert_eq!(ws.reuses, 1);
}

#[test]
fn warm_cache_matches_resident_goldens_end_to_end() {
    // 512 >= 231 translation pages: the cache warm-starts fully resident
    // and can never miss, so the DES outcome is bit-identical to the
    // resident tier for both workload kinds.
    for mode in [RequestKind::Write, RequestKind::Read] {
        let resident = Campaign::new(base_cfg(), mode, 100).run();
        let warm = Campaign::new(demand_cfg(512, MapMode::Demand), mode, 100).run();
        assert_eq!(
            fingerprint(&warm),
            fingerprint(&resident),
            "{}: warm cache perturbed the simulation",
            mode.name()
        );
        assert_eq!(warm.map_misses, 0, "{}: a full cache cannot miss", mode.name());
        assert!(warm.map_hits > 0, "{}: hits must still be counted", mode.name());
        assert_eq!(warm.map_pages_read, 0);
        assert_eq!(warm.map_pages_programmed, 0);
    }
}

#[test]
fn starved_cache_injects_flash_traffic_and_defers() {
    let resident = Campaign::new(base_cfg(), RequestKind::Write, 120).run();
    let starved = Campaign::new(demand_cfg(4, MapMode::Demand), RequestKind::Write, 120).run();
    assert!(starved.map_misses > 0, "4-page cache over 231 tpages must miss");
    assert!(starved.map_pages_read > 0, "misses must become flash reads");
    assert!(
        starved.map_pages_programmed > 0,
        "dirty evictions must become flash programs"
    );
    assert!(starved.map_deferred > 0, "demand mode stalls host ops on misses");
    assert!(starved.map_wait_mean_us > 0.0);
    assert!(starved.map_hit_rate < 1.0 && starved.map_hit_rate >= 0.0);
    // Translation programs count as internal writes: amplification shows.
    assert!(
        starved.waf > resident.waf,
        "map write-backs must surface in WAF: {} <= {}",
        starved.waf,
        resident.waf
    );
    // And the run can only get slower, never faster.
    assert!(starved.sim_time >= resident.sim_time);
}

#[test]
fn fmmu_overlaps_instead_of_deferring() {
    let fmmu = Campaign::new(demand_cfg(4, MapMode::Fmmu), RequestKind::Write, 120).run();
    assert!(fmmu.map_misses > 0);
    assert!(fmmu.map_pages_read > 0);
    assert_eq!(fmmu.map_deferred, 0, "FMMU never stalls the host op on a miss");
    // Every fill still pays for its read on the flash array; at most one
    // fill is outstanding per translation page, so misses can only exceed
    // reads by piggy-backing on a fill already in flight.
    assert!(fmmu.map_misses >= fmmu.map_pages_read);
}

#[test]
fn map_fill_stalls_attributed_and_accounting_ties_out() {
    let mut c = demand_cfg(4, MapMode::Demand);
    c.observe.enabled = true;
    let r = Campaign::new(c, RequestKind::Write, 120).run();
    assert!(r.map_misses > 0);
    let o = r.observe.as_ref().expect("observation was enabled");
    // The four occupancy states partition each resource's wall clock.
    for res in &o.resources {
        assert_eq!(res.total_ps(), o.wall_ps, "{res:?}");
    }
    // Blocked time splits exactly across the three blocked causes — any
    // map-fill blocking lands in its own bucket, not in bus contention.
    let way = o.totals(ddrnand::observe::ResourceKind::Way);
    assert_eq!(
        o.stalls.bus_contention_ps + o.stalls.gc_barrier_ps + o.stalls.map_fill_ps,
        way[1],
        "stall attribution leak"
    );
}
