//! Tiered-flash subsystem integration tests (E8): the SLC-fraction sweep
//! headline (tiered beats pure-MLC write latency, converging to pure-SLC
//! as the fraction grows, on both CONV and PROPOSED), migration under a
//! full campaign, composition with the steady-state GC regime, and the
//! golden guarantee that a disabled `[tiering]` section leaves every run
//! bit-identical through `SimWorkspace` reuse.

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimReport, SimWorkspace};
use ddrnand::coordinator::experiments::{run_tiered_sweep, TieredSweepSpec};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;

fn tiered_cfg(iface: InterfaceKind, ways: u16, slc_fraction: f64) -> SsdConfig {
    let mut cfg = SsdConfig {
        iface,
        cell: CellType::Mlc,
        channels: 1,
        ways,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    if slc_fraction > 0.0 {
        cfg.tiering.enabled = true;
        cfg.tiering.slc_fraction = slc_fraction;
    }
    cfg
}

/// The E8 headline: at fixed total capacity under an offered write load
/// both partitions sustain, the tiered drive's write p50 beats pure MLC
/// and converges toward pure SLC as the SLC-tier fraction grows — for
/// CONV and PROPOSED alike.
#[test]
fn e8_fraction_sweep_orders_write_latency() {
    for iface in [InterfaceKind::Conv, InterfaceKind::Proposed] {
        let run = |fraction: f64| {
            let mut cfg = tiered_cfg(iface, 4, fraction);
            cfg.load.offered_mbps = Some(12.0);
            cfg.seed = 0xE8;
            Campaign::new(cfg, RequestKind::Write, 100).run()
        };
        let pure_mlc = run(0.0);
        let tiered = run(0.5);
        let pure_slc = run(1.0);
        assert_eq!(pure_mlc.mig_pages_programmed, 0);
        assert_eq!(pure_mlc.waf, 1.0);
        assert!(
            tiered.latency_p50_us < pure_mlc.latency_p50_us,
            "{iface}: tiered p50 must beat pure MLC: {} vs {} us",
            tiered.latency_p50_us,
            pure_mlc.latency_p50_us
        );
        assert!(
            pure_slc.latency_p50_us < tiered.latency_p50_us,
            "{iface}: all-SLC p50 must undercut the half partition: {} vs {} us",
            pure_slc.latency_p50_us,
            tiered.latency_p50_us
        );
        assert!(
            pure_slc.latency_p50_us < pure_mlc.latency_p50_us,
            "{iface}: the sweep must span MLC down to SLC latency"
        );
    }
}

/// A campaign whose sequential volume overflows the SLC tier migrates
/// through the real DES: migration counters populate, WAF rises above 1,
/// and reading everything back hits both tiers.
#[test]
fn overflowing_campaign_migrates_and_reads_back_from_both_tiers() {
    let mut cfg = tiered_cfg(InterfaceKind::Proposed, 2, 0.5);
    cfg.blocks_per_chip = 16; // SLC tier: 1 chip x 16 blocks x 128 pages = 8 MiB
    let mut ws = SimWorkspace::new();
    // 180 x 64 KiB = 11.25 MiB of writes into an 8 MiB SLC tier.
    let w = Campaign::new(cfg.clone(), RequestKind::Write, 180).run_in(&mut ws);
    assert_eq!(w.requests, 180);
    assert!(w.mig_pages_programmed > 0, "the fill must overflow the SLC tier");
    assert_eq!(w.mig_pages_read, w.mig_pages_programmed);
    assert!(w.waf > 1.0, "migration is write amplification: {}", w.waf);
    assert!(w.mig_energy_share > 0.0 && w.mig_energy_share < 1.0);
    // Read the same span back: the cold prefix was migrated to MLC, the
    // hot tail still lives in SLC.
    let r = Campaign::new(cfg, RequestKind::Read, 180).run_in(&mut ws);
    assert_eq!(r.requests, 180);
    assert!(r.slc_reads > 0, "recent data must be read from the SLC tier");
    assert!(r.mlc_reads > 0, "migrated data must be read from the MLC tier");
    assert!(r.slc_read_share > 0.0 && r.slc_read_share < 1.0);
}

/// Tiering composes with the steady-state regime: a preconditioned drive
/// under sustained random writes runs GC and migration in one simulation,
/// and both kinds of copy-back traffic are accounted separately.
#[test]
fn steady_plus_tiering_compose_gc_and_migration() {
    let mut cfg = tiered_cfg(InterfaceKind::Proposed, 2, 0.5);
    cfg.steady.enabled = true;
    cfg.steady.over_provision = 0.15;
    let r = Campaign::new(cfg, RequestKind::Write, 400).run();
    assert_eq!(r.requests, 400);
    assert!(r.mig_pages_programmed > 0, "steady rewrites must migrate");
    assert!(
        r.gc_pages_programmed > 0,
        "steady rewrites must also garbage-collect"
    );
    assert!(r.waf > 1.0, "waf={}", r.waf);
    assert!(r.blocks_erased > 0);
    // The amplification split stays disjoint: host programs + GC + WL +
    // migration = all programs.
    let internal = r.gc_pages_programmed + r.wl_pages_programmed + r.mig_pages_programmed;
    assert!(internal < r.pages_programmed);
    let host = r.pages_programmed - internal;
    assert!((r.waf - r.pages_programmed as f64 / host as f64).abs() < 1e-12);
}

/// Per-tier interfaces: a tiered drive with a PROPOSED SLC tier in front
/// of a CONV MLC tier migrates strictly faster than the all-CONV drive of
/// the same shape (the DDR interface question answered per tier).
#[test]
fn per_tier_interface_speeds_up_the_slc_tier() {
    let run = |slc_iface: Option<InterfaceKind>| {
        let mut cfg = tiered_cfg(InterfaceKind::Conv, 2, 0.5);
        cfg.blocks_per_chip = 16;
        cfg.tiering.slc_iface = slc_iface;
        let r = Campaign::new(cfg, RequestKind::Write, 180).run();
        assert!(r.mig_pages_programmed > 0);
        (r.latency_p50_us, r.bandwidth_mbps)
    };
    let (conv_p50, conv_bw) = run(None);
    let (mixed_p50, mixed_bw) = run(Some(InterfaceKind::Proposed));
    assert!(
        mixed_p50 < conv_p50,
        "a PROPOSED SLC tier must cut write p50 on a CONV drive: {mixed_p50} vs {conv_p50}"
    );
    assert!(mixed_bw > conv_bw);
}

fn fingerprint(r: &SimReport) -> (u64, i64, u64, u64, u64, u64, [u64; 5]) {
    (
        r.events,
        r.sim_time.as_ps(),
        r.pages_programmed,
        r.pages_read,
        r.mig_pages_programmed,
        r.slc_reads + r.mlc_reads,
        [
            r.bandwidth_mbps.to_bits(),
            r.energy_nj_per_byte.to_bits(),
            r.waf.to_bits(),
            r.latency_p50_us.to_bits(),
            r.latency_p99_us.to_bits(),
        ],
    )
}

/// Golden guarantee: with `[tiering]` disabled nothing changes — fresh-
/// drive and steady-state runs reproduce their pre-tiering fingerprints
/// bit-identically through a `SimWorkspace` that also served tiered runs,
/// and a dormant section (fields set, `enabled = false`) is inert.
#[test]
fn tiering_disabled_runs_bit_identical_through_workspace_reuse() {
    let plain = SsdConfig {
        channels: 1,
        ways: 2,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    let mut steady = plain.clone();
    steady.steady.enabled = true;
    steady.steady.over_provision = 0.10;
    let mut dormant = plain.clone();
    dormant.tiering.slc_fraction = 0.5;
    dormant.tiering.migrate_free_blocks = 8;
    // Reference fingerprints from dedicated fresh workspaces.
    let fresh_plain = Campaign::new(plain.clone(), RequestKind::Write, 60).run();
    let fresh_steady = Campaign::new(steady.clone(), RequestKind::Write, 150).run();
    // One shared workspace serves a tiered run between the golden runs.
    let mut ws = SimWorkspace::new();
    let tiered = Campaign::new(tiered_cfg(InterfaceKind::Proposed, 2, 0.5), RequestKind::Write, 60)
        .run_in(&mut ws);
    assert_eq!(tiered.cell, "MLC");
    let again_plain = Campaign::new(dormant, RequestKind::Write, 60).run_in(&mut ws);
    let again_steady = Campaign::new(steady, RequestKind::Write, 150).run_in(&mut ws);
    assert_eq!(fingerprint(&fresh_plain), fingerprint(&again_plain));
    assert_eq!(fingerprint(&fresh_steady), fingerprint(&again_steady));
    assert_eq!(again_plain.mig_pages_programmed, 0);
    assert_eq!(again_plain.slc_reads + again_plain.mlc_reads, 0);
    assert!(again_plain.slc_read_share.is_nan());
}

/// The E8 driver is deterministic and its grid is ordered: same spec,
/// same pool → bit-identical reports, fractions ordered per (iface, ways).
#[test]
fn e8_driver_deterministic_and_ordered() {
    let spec = TieredSweepSpec {
        ways: vec![2],
        slc_fractions: vec![0.0, 0.5, 1.0],
        ifaces: vec![InterfaceKind::Conv, InterfaceKind::Proposed],
        requests: 30,
        offered_mbps: Some(10.0),
        blocks_per_chip: 64,
        ..TieredSweepSpec::default()
    };
    let a = run_tiered_sweep(&spec, &ThreadPool::new(1));
    let b = run_tiered_sweep(&spec, &ThreadPool::new(4));
    assert_eq!(a.len(), 2 * 3);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.iface, y.iface);
        assert_eq!(x.slc_fraction, y.slc_fraction);
        assert_eq!(fingerprint(&x.report), fingerprint(&y.report));
    }
    for pair in a.chunks(3) {
        assert!(pair.windows(2).all(|w| w[0].slc_fraction < w[1].slc_fraction));
    }
}
