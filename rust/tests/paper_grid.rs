//! Integration: the DES reproduces Table 3's shape, and agrees with the
//! analytic model where the steady-state assumptions hold.

use ddrnand::analytic::{self, paper};
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;

fn cfg(iface: InterfaceKind, cell: CellType, ways: u16) -> SsdConfig {
    SsdConfig {
        iface,
        cell,
        ways,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    }
}

/// Run the DES for one Table 3 cell.
fn des_bw(iface: InterfaceKind, cell: CellType, ways: u16, mode: RequestKind) -> f64 {
    Campaign::new(cfg(iface, cell, ways), mode, 400).run().bandwidth_mbps
}

#[test]
fn table3_grid_des_vs_paper() {
    let mut rows = Vec::new();
    let mut worst = (0.0f64, String::new());
    for (cell, mode, table) in paper::TABLE3 {
        for (wi, &w) in paper::WAYS.iter().enumerate() {
            for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                let des = des_bw(*iface, cell, w, mode);
                let p = table[wi][ii];
                let err = (des - p) / p;
                rows.push(format!(
                    "{cell} {:>5} {w:>2}-way {:<9} paper={p:>7.2} des={des:>7.2} ({:+.1}%)",
                    mode.name(),
                    iface.name(),
                    err * 100.0
                ));
                if err.abs() > worst.0 {
                    worst = (err.abs(), rows.last().unwrap().clone());
                }
            }
        }
    }
    for r in &rows {
        println!("{r}");
    }
    println!("worst: {}", worst.1);
}

/// The qualitative Table 3 claims (§5.3.1), asserted on the DES itself.
#[test]
fn table3_shape_assertions() {
    // Ordering P > S > C everywhere.
    for (cell, mode, _) in paper::TABLE3 {
        for &w in &paper::WAYS {
            let c = des_bw(InterfaceKind::Conv, cell, w, mode);
            let s = des_bw(InterfaceKind::SyncOnly, cell, w, mode);
            let p = des_bw(InterfaceKind::Proposed, cell, w, mode);
            assert!(p > s && s > c, "{cell} {mode:?} {w}-way: {p} {s} {c}");
        }
    }
    // SLC read saturation degrees: CONV by 2-way, PROPOSED by 4-way.
    let r = |i, w| des_bw(i, CellType::Slc, w, RequestKind::Read);
    assert!((r(InterfaceKind::Conv, 2) - r(InterfaceKind::Conv, 16)).abs() < 1.0);
    assert!((r(InterfaceKind::Proposed, 4) - r(InterfaceKind::Proposed, 16)).abs() < 2.5);
    assert!(r(InterfaceKind::Proposed, 2) < 0.9 * r(InterfaceKind::Proposed, 4));
    // SLC write: CONV saturates by 8-way, PROPOSED keeps scaling to 16.
    let w = |i, ways| des_bw(i, CellType::Slc, ways, RequestKind::Write);
    assert!((w(InterfaceKind::Conv, 8) - w(InterfaceKind::Conv, 16)).abs() < 1.0);
    assert!(w(InterfaceKind::Proposed, 16) > 1.4 * w(InterfaceKind::Proposed, 8));
}

/// Table 4: channel scaling and the SATA "max" cells, on the DES.
#[test]
fn table4_shape_assertions() {
    let bw = |iface, cell, ch: u16, w: u16, mode| {
        let cfg = SsdConfig {
            iface,
            cell,
            channels: ch,
            ways: w,
            blocks_per_chip: 512,
            ..SsdConfig::default()
        };
        Campaign::new(cfg, mode, 300).run().bandwidth_mbps
    };
    for cell in [CellType::Slc, CellType::Mlc] {
        // Reads scale with channels until SATA binds at (4,4) PROPOSED.
        let r116 = bw(InterfaceKind::Proposed, cell, 1, 16, RequestKind::Read);
        let r28 = bw(InterfaceKind::Proposed, cell, 2, 8, RequestKind::Read);
        let r44 = bw(InterfaceKind::Proposed, cell, 4, 4, RequestKind::Read);
        assert!(r28 > 1.7 * r116, "{cell}: 2ch read should ~2x: {r28} vs {r116}");
        assert!(r44 > 280.0 && r44 <= 301.0, "{cell}: (4,4) read must hit SATA: {r44}");
        // Write-mode P/C advantage shrinks as channels replace ways (§5.3.2).
        let pc = |ch: u16, w: u16| {
            bw(InterfaceKind::Proposed, cell, ch, w, RequestKind::Write)
                / bw(InterfaceKind::Conv, cell, ch, w, RequestKind::Write)
        };
        assert!(pc(1, 16) > pc(4, 4), "{cell}: P/C must shrink with channels");
    }
}

/// Table 5's crossover claims on the DES energy metric.
#[test]
fn table5_energy_crossovers() {
    let e = |iface, ways, mode| {
        let cfg = cfg(iface, CellType::Slc, ways);
        Campaign::new(cfg, mode, 300).run().energy_nj_per_byte
    };
    for mode in [RequestKind::Write, RequestKind::Read] {
        assert!(e(InterfaceKind::Proposed, 1, mode) > e(InterfaceKind::Conv, 1, mode));
    }
    assert!(e(InterfaceKind::Proposed, 16, RequestKind::Write) < e(InterfaceKind::Conv, 16, RequestKind::Write));
    assert!(e(InterfaceKind::Proposed, 4, RequestKind::Read) < e(InterfaceKind::Conv, 4, RequestKind::Read));
}

#[test]
fn des_matches_analytic_steady_state() {
    // Where the steady-state assumptions hold (SLC, QD covers the array),
    // DES and analytic should agree within a few percent.
    for iface in InterfaceKind::ALL {
        for &w in &[1u16, 4, 16] {
            for mode in [RequestKind::Read, RequestKind::Write] {
                let c = cfg(iface, CellType::Slc, w);
                let des = Campaign::new(c.clone(), mode, 300).run().bandwidth_mbps;
                let ana = analytic::evaluate(&c, mode).0;
                let err = (des - ana).abs() / ana;
                assert!(
                    err < 0.12,
                    "{iface} SLC {mode:?} {w}-way: des={des:.2} analytic={ana:.2} err={:.1}%",
                    err * 100.0
                );
            }
        }
    }
}
