//! Open-loop (arrival-driven) workload integration tests: low-load
//! convergence to the closed-loop QD=1 latency, closed-loop golden
//! equality after open-loop workspace reuse, and the saturation-knee
//! ordering the E6 load sweep exists to demonstrate (PROPOSED sustains a
//! strictly higher offered load than CONV).

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::SimWorkspace;
use ddrnand::coordinator::experiments::knee_mbps;
use ddrnand::host::trace::{RequestKind, TraceGen};
use ddrnand::iface::timing::InterfaceKind;

fn cfg(iface: InterfaceKind, ways: u16) -> SsdConfig {
    SsdConfig {
        iface,
        ways,
        blocks_per_chip: 256,
        ..SsdConfig::default()
    }
}

/// At an offered load far below capacity every request meets an idle
/// device, so open-loop latency converges to the closed-loop QD=1 latency
/// (each QD=1 request equally meets an idle device).
#[test]
fn low_offered_load_converges_to_qd1_latency() {
    let gen = TraceGen::default();
    let mut ws = SimWorkspace::new();
    // Closed-loop QD=1 reference.
    let mut c1 = cfg(InterfaceKind::Proposed, 4);
    c1.queue_depth = 1;
    let closed = ws.run_trace(&c1, &gen.sequential(RequestKind::Write, 60));
    // Open loop at 2 MB/s: mean inter-arrival of a 64 KiB request is
    // ~33 ms, orders of magnitude above its service time.
    let open_trace = gen.poisson_arrivals(gen.sequential(RequestKind::Write, 60), 2.0, 42);
    let open = ws.run_trace(&cfg(InterfaceKind::Proposed, 4), &open_trace);
    let rel = (open.latency_mean_us - closed.latency_mean_us).abs() / closed.latency_mean_us;
    assert!(
        rel < 0.05,
        "open-loop mean {} us must converge to closed QD=1 mean {} us (rel {:.3})",
        open.latency_mean_us,
        closed.latency_mean_us,
        rel
    );
    // And the tail collapses onto the median: no queueing at this load.
    assert!(open.latency_p99_us < open.latency_p50_us * 1.10);
}

/// Golden guarantee: a workspace dirtied by an open-loop run reproduces
/// closed-loop results bit-identically (the open-loop machinery leaves no
/// trace when the arrival track is absent).
#[test]
fn closed_loop_bit_identical_after_open_loop_reuse() {
    let gen = TraceGen::default();
    let c = cfg(InterfaceKind::Proposed, 4);
    let closed_trace = gen.sequential(RequestKind::Write, 40);
    let fresh = SimWorkspace::new().run_trace(&c, &closed_trace);
    let mut ws = SimWorkspace::new();
    let open_trace = gen.poisson_arrivals(gen.sequential(RequestKind::Write, 40), 30.0, 7);
    let _ = ws.run_trace(&c, &open_trace);
    let reused = ws.run_trace(&c, &closed_trace);
    assert!(ws.reuses >= 1, "second run must reuse the simulator");
    assert_eq!(fresh.events, reused.events);
    assert_eq!(fresh.sim_time, reused.sim_time);
    assert_eq!(fresh.bandwidth_mbps, reused.bandwidth_mbps);
    assert_eq!(fresh.latency_mean_us, reused.latency_mean_us);
    assert_eq!(fresh.latency_p99_us, reused.latency_p99_us);
    assert_eq!(fresh.pages_programmed, reused.pages_programmed);
    assert_eq!(fresh.offered_mbps, 0.0);
    assert_eq!(reused.offered_mbps, 0.0);
}

/// The acceptance property of the load sweep: achieved throughput is
/// monotone in offered load, and PROPOSED's saturation knee sits strictly
/// above CONV's at 4 ways — way interleaving's benefit shown on the load
/// axis rather than the closed-loop bandwidth axis.
#[test]
fn proposed_knee_beats_conv_at_4_ways() {
    let run_curve = |iface| {
        let gen = TraceGen::default();
        let mut ws = SimWorkspace::new();
        let mut pts = Vec::new();
        let mut p95s = Vec::new();
        for i in 1..=6 {
            let offered = 40.0 * i as f64; // 40..240 MB/s
            let trace =
                gen.poisson_arrivals(gen.sequential(RequestKind::Read, 150), offered, 11);
            let rep = ws.run_trace(&cfg(iface, 4), &trace);
            pts.push((offered, rep.bandwidth_mbps));
            p95s.push(rep.latency_p95_us);
        }
        // Achieved throughput never decreases as offered load rises
        // (small tolerance for Poisson sampling noise).
        for w in pts.windows(2) {
            assert!(
                w[1].1 >= w[0].1 * 0.95,
                "{iface:?}: achieved dropped: {:?} -> {:?}",
                w[0],
                w[1]
            );
        }
        // Latency never improves with more load.
        for w in p95s.windows(2) {
            assert!(
                w[1] >= w[0] * 0.90,
                "{iface:?}: p95 latency dropped under load: {p95s:?}"
            );
        }
        pts
    };
    let conv = run_curve(InterfaceKind::Conv);
    let prop = run_curve(InterfaceKind::Proposed);
    let (conv_knee, prop_knee) = (knee_mbps(&conv), knee_mbps(&prop));
    assert!(
        prop_knee > conv_knee,
        "PROPOSED must sustain more offered load than CONV: {prop_knee} vs {conv_knee} \
         (conv curve {conv:?}, prop curve {prop:?})"
    );
    // Under heavy overload both achieve their closed-loop ceiling, and
    // PROPOSED's ceiling is higher (Table 3's shape survives open loop).
    assert!(prop.last().unwrap().1 > conv.last().unwrap().1);
}
