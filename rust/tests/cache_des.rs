//! DRAM-cache DES-path integration tests (`coordinator/ssd.rs`):
//! dirty-eviction flush ordering on both the write and the read path,
//! end-of-run dirty-page accounting (the shutdown-flush set), and the
//! golden guarantee that cache-disabled runs are untouched by the LRU
//! index rewrite — exercised through `SimWorkspace` reuse.

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimWorkspace};
use ddrnand::coordinator::ssd::SsdSim;
use ddrnand::host::trace::{Request, RequestKind, TraceGen};
use ddrnand::iface::timing::InterfaceKind;

fn cfg(cache_pages: u32) -> SsdConfig {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        channels: 1,
        ways: 2,
        blocks_per_chip: 256,
        ..SsdConfig::default()
    };
    cfg.cache.capacity_pages = cache_pages;
    cfg
}

fn writes(n: usize) -> Vec<Request> {
    TraceGen::default()
        .sequential(RequestKind::Write, n)
        .requests
}

/// Write-path dirty evictions flush to NAND as internal traffic, ordered
/// ahead of the request completion that caused them: with a cache smaller
/// than the footprint, exactly the evicted portion reaches NAND.
#[test]
fn write_path_dirty_evictions_flush_to_nand() {
    // 3 requests x 32 SLC pages = 96 dirty pages through a 32-page cache.
    let mut sim = SsdSim::new(cfg(32), writes(3));
    sim.run();
    assert_eq!(sim.counters.requests_done, 3);
    // 64 pages must have been evicted dirty and flushed; 32 stay cached.
    assert_eq!(sim.counters.pages_programmed, 64);
    assert_eq!(sim.counters.internal_pages, 64);
    assert_eq!(sim.cache_dirty_pages().len(), 32);
    // Flushes are host-attributed deferred data, never GC.
    assert_eq!(sim.counters.gc_pages_programmed, 0);
    assert_eq!(sim.waf(), 1.0);
}

/// Regression (read-path flush drop): a read miss whose eviction victim is
/// dirty must flush that page to NAND *before* the miss fill. The pre-fix
/// code silently discarded the flush — zero NAND programs, dirty data
/// lost; this test fails on that code.
#[test]
fn read_miss_dirty_eviction_flushes_before_fill() {
    // Cache holds 64 pages: one 64 KiB write (32 pages, dirty) + one read
    // (32 pages, clean) fill it; the second read evicts the 32 dirty
    // write pages.
    let mut trace = writes(1); // lpns 0..32 at offset 0
    let read_at = |mib: u64| Request {
        kind: RequestKind::Read,
        offset: mib * 1024 * 1024,
        bytes: 65536,
    };
    trace.push(read_at(2));
    trace.push(read_at(4));
    // Queue depth 1 pins the order: write caches its pages, then the two
    // reads fill and finally evict them.
    let mut c = cfg(64);
    c.queue_depth = 1;
    let mut sim = SsdSim::new(c, trace);
    sim.prefill_for_reads();
    sim.run();
    assert_eq!(sim.counters.requests_done, 3);
    assert_eq!(
        sim.counters.pages_programmed, 32,
        "the 32 dirty write pages must be flushed by the read evictions"
    );
    assert_eq!(sim.counters.internal_pages, 32);
    // The cache's own flush ledger agrees with the DES traffic.
    assert_eq!(sim.counters.pages_read, 64);
    assert!(sim.cache_dirty_pages().is_empty(), "all dirty pages evicted");
}

/// Shutdown accounting: what the run leaves dirty in DRAM is exactly the
/// written footprint minus what eviction already flushed — the set a
/// power-down flush would write (conservation of host pages).
#[test]
fn shutdown_dirty_set_conserves_host_pages() {
    let mut sim = SsdSim::new(cfg(4096), writes(4)); // cache > footprint
    sim.run();
    let host_pages = 4 * 32u64;
    assert_eq!(sim.counters.pages_programmed, 0, "nothing evicted");
    let dirty = sim.cache_dirty_pages();
    assert_eq!(dirty.len() as u64, host_pages);
    // Sorted, contiguous lpns from offset 0.
    assert_eq!(dirty, (0..host_pages).collect::<Vec<u64>>());
    // Small cache: flushed + still-dirty = host pages, bit for bit.
    let mut sim = SsdSim::new(cfg(32), writes(4));
    sim.run();
    assert_eq!(
        sim.counters.pages_programmed + sim.cache_dirty_pages().len() as u64,
        host_pages
    );
}

/// Golden: cache-disabled runs are bit-identical before/after the LRU
/// rewrite — pinned by fingerprint equality between a fresh simulator and
/// one reused (via the workspace) after cache-enabled runs dirtied it.
#[test]
fn cache_disabled_runs_bit_identical_through_reuse() {
    let fingerprint = |c: SsdConfig| {
        let mut ws = SimWorkspace::new();
        let r = Campaign::new(c, RequestKind::Write, 40).run_in(&mut ws);
        (
            r.events,
            r.sim_time,
            r.pages_programmed,
            r.bandwidth_mbps.to_bits(),
            r.latency_p99_us.to_bits(),
        )
    };
    let fresh = fingerprint(cfg(0));
    // Same geometry key: the cached → uncached switch reuses the simulator.
    let mut ws = SimWorkspace::new();
    let cached = Campaign::new(cfg(64), RequestKind::Write, 40).run_in(&mut ws);
    assert!(cached.pages_programmed > 0, "the tiny cache must flush");
    let reused = Campaign::new(cfg(0), RequestKind::Write, 40).run_in(&mut ws);
    assert!(ws.reuses >= 1, "the cache switch must not rebuild");
    assert_eq!(
        fresh,
        (
            reused.events,
            reused.sim_time,
            reused.pages_programmed,
            reused.bandwidth_mbps.to_bits(),
            reused.latency_p99_us.to_bits(),
        )
    );
}
