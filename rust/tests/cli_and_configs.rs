//! CLI smoke tests and shipped-config validation.

use ddrnand::cli;
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::host::trace::RequestKind;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(String::from).collect()
}

#[test]
fn every_shipped_config_parses_validates_and_runs() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs");
    let mut count = 0;
    for entry in std::fs::read_dir(&dir).expect("configs dir") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e != "toml").unwrap_or(true) {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let mut cfg = SsdConfig::from_toml(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(cfg.validate().is_empty(), "{}", path.display());
        // Keep the smoke run small.
        cfg.blocks_per_chip = 64;
        let rep = Campaign::new(cfg, RequestKind::Write, 10).run();
        assert!(rep.bandwidth_mbps > 0.0, "{}", path.display());
        count += 1;
    }
    assert!(count >= 9, "expected the shipped preset configs, found {count}");
}

#[test]
fn cli_table2_succeeds() {
    assert_eq!(cli::run(&argv("table2")), 0);
}

#[test]
fn cli_pvt_succeeds() {
    assert_eq!(cli::run(&argv("pvt --margin 1.05")), 0);
}

#[test]
fn cli_sweep_load_succeeds() {
    assert_eq!(
        cli::run(&argv(
            "sweep-load --requests 20 --points 2 --ways 2 --max-mbps 120 --csv"
        )),
        0
    );
}

#[test]
fn cli_sweep_steady_succeeds() {
    assert_eq!(
        cli::run(&argv(
            "sweep-steady --requests 40 --ways 2 --op 0.07,0.25 --offered-mbps 0 --csv"
        )),
        0
    );
}

#[test]
fn cli_sweep_tiered_succeeds() {
    assert_eq!(
        cli::run(&argv(
            "sweep-tiered --requests 10 --ways 2 --fractions 0,0.5 --offered-mbps 0 --csv"
        )),
        0
    );
}

#[test]
fn cli_sweep_qos_succeeds() {
    assert_eq!(
        cli::run(&argv(
            "sweep-qos --requests 30 --ways 2 --ifaces proposed \
             --schedulers round_robin,read_priority --write-mbps 40 --blocks 128 --csv"
        )),
        0
    );
}

#[test]
fn cli_sweep_map_succeeds() {
    assert_eq!(
        cli::run(&argv(
            "sweep-map --requests 40 --channels 1 --ways 2 --blocks 128 \
             --entries 64 --cache-pages 8,512 --hot 0.1:0.9 --csv"
        )),
        0
    );
}

#[test]
fn cli_sweep_map_rejects_bad_flags() {
    assert_eq!(cli::run(&argv("sweep-map --map-mode paged")), 1);
    assert_eq!(cli::run(&argv("sweep-map --cache-pages 0")), 1);
    assert_eq!(cli::run(&argv("sweep-map --hot 2:0.5")), 1);
    assert_eq!(cli::run(&argv("sweep-map --hot 0.5")), 1);
    assert_eq!(cli::run(&argv("sweep-map --cell qlc")), 1);
    assert_eq!(cli::run(&argv("sweep-map --ways 0")), 1);
}

#[test]
fn cli_sweep_qos_rejects_bad_flags() {
    assert_eq!(cli::run(&argv("sweep-qos --schedulers fifo")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --ways 0")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --ifaces quantum")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --link pcie9")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --read-mbps 0")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --write-mbps -5")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --blocks 8")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --cell qlc")), 1);
}

#[test]
fn cli_replay_rejects_v3_stream_overflow() {
    let dir = std::env::temp_dir().join("ddrnand_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    // Stream id 5 exceeds the preset's host.queues = 2: must be a clean
    // error, not a simulator assert.
    let trace = dir.join("overflow.v3");
    std::fs::write(&trace, "W 0 65536 0 1\nW 65536 65536 5 1\n").unwrap();
    let cfg = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("configs/qos_two_tenant_4way.toml");
    let cmd = format!("replay --trace {} --config {}", trace.display(), cfg.display());
    assert_eq!(cli::run(&argv(&cmd)), 1);
}

#[test]
fn cli_sweep_tiered_rejects_bad_flags() {
    assert_eq!(cli::run(&argv("sweep-tiered --fractions 1.5")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --ways 0")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --blocks 8")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --migrate-free 2")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --ifaces quantum")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --ways 1")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --arrival uniform")), 1);
    assert_eq!(cli::run(&argv("sweep-tiered --steady --op 0.9")), 1);
    // Capacity-infeasible grid point (tiny SLC tier, tight OP): must be a
    // clean pre-flight error, not a mid-sweep panic.
    assert_eq!(
        cli::run(&argv(
            "sweep-tiered --steady --op 0.1 --blocks 32 --ways 8 --fractions 0.125"
        )),
        1
    );
}

#[test]
fn cli_sweep_steady_rejects_bad_flags() {
    assert_eq!(cli::run(&argv("sweep-steady --op 0.9")), 1);
    assert_eq!(cli::run(&argv("sweep-steady --ways 0")), 1);
    assert_eq!(cli::run(&argv("sweep-steady --blocks 4")), 1);
    assert_eq!(cli::run(&argv("sweep-steady --arrival uniform")), 1);
    // 20 blocks x 7% OP = 1.4 spare blocks < the GC floor of 3: the CLI
    // must refuse cleanly instead of live-lock-asserting mid-sweep.
    assert_eq!(cli::run(&argv("sweep-steady --blocks 20 --op 0.07")), 1);
}

#[test]
fn cli_sweep_load_rejects_bad_flags() {
    assert_eq!(cli::run(&argv("sweep-load --arrival uniform")), 1);
    assert_eq!(cli::run(&argv("sweep-load --ways 0")), 1);
    assert_eq!(cli::run(&argv("sweep-load --mode scan")), 1);
}

/// `--threads N` selects the per-sim channel-sharded executor on the
/// sweep subcommands; `--jobs` sizes the sweep-level pool. Both must be
/// documented, accepted, and validated.
#[test]
fn cli_threads_flag_smoke() {
    assert_eq!(cli::run(&argv("sweep-ways --requests 12 --threads 2 --csv")), 0);
    assert_eq!(
        cli::run(&argv("sweep-load --requests 12 --points 2 --ways 2 --threads 2 --csv")),
        0
    );
    assert_eq!(
        cli::run(&argv(
            "sweep-steady --requests 20 --ways 2 --op 0.15 --offered-mbps 0 \
             --threads 2 --jobs 2 --csv"
        )),
        0
    );
}

#[test]
fn cli_threads_flag_rejects_bad_values() {
    assert_eq!(cli::run(&argv("sweep-ways --threads 0")), 1);
    assert_eq!(cli::run(&argv("sweep-ways --threads 300")), 1);
    assert_eq!(cli::run(&argv("sweep-qos --threads 0")), 1);
    // Not a number at all: parse error from the flag reader.
    assert_eq!(cli::run(&argv("sweep-ways --threads many")), 1);
}

#[test]
fn cli_usage_documents_engine_flags() {
    let usage = cli::usage();
    assert!(usage.contains("--threads N"), "usage lost the --threads flag");
    assert!(usage.contains("--jobs N"), "usage lost the --jobs flag");
    assert!(
        usage.contains("engine threads per simulation"),
        "usage must distinguish engine threads from sweep jobs"
    );
}

#[test]
fn cli_simulate_threads_flag_overrides_config() {
    let dir = std::env::temp_dir().join("ddrnand_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("threads_override.toml");
    std::fs::write(
        &cfg,
        "iface = \"proposed\"\nways = 2\nblocks_per_chip = 64\n\n[engine]\nthreads = 1\n",
    )
    .unwrap();
    let cmd = format!("simulate --config {} --requests 5 --threads 4", cfg.display());
    assert_eq!(cli::run(&argv(&cmd)), 0);
    // Without the flag, the TOML [engine] section stands untouched.
    let cmd = format!("simulate --config {} --requests 5", cfg.display());
    assert_eq!(cli::run(&argv(&cmd)), 0);
}

#[test]
fn cli_unknown_subcommand_fails() {
    assert_eq!(cli::run(&argv("frobnicate")), 2);
}

#[test]
fn cli_no_subcommand_prints_usage_ok() {
    assert_eq!(cli::run(&[]), 0);
}

#[test]
fn cli_trace_gen_and_replay_roundtrip() {
    let dir = std::env::temp_dir().join("ddrnand_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("t.trace");
    let cmd = format!("trace-gen --out {} --requests 20 --mode mixed", trace.display());
    assert_eq!(cli::run(&argv(&cmd)), 0);
    assert!(trace.exists());
    let cmd = format!("replay --trace {}", trace.display());
    assert_eq!(cli::run(&argv(&cmd)), 0);
}

#[test]
fn cli_simulate_with_config_file() {
    let dir = std::env::temp_dir().join("ddrnand_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("c.toml");
    std::fs::write(&cfg, "iface = \"sync_only\"\nways = 2\nblocks_per_chip = 64\n").unwrap();
    let cmd = format!("simulate --config {} --requests 5", cfg.display());
    assert_eq!(cli::run(&argv(&cmd)), 0);
}

#[test]
fn cli_simulate_missing_config_fails() {
    assert_eq!(cli::run(&argv("simulate")), 1);
}

#[test]
fn cli_dse_native_succeeds() {
    assert_eq!(cli::run(&argv("dse --native --sweep-tbyte")), 0);
}
