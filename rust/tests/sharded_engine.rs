//! The parallel-engine contracts, end to end:
//!
//! 1. **Bit-identity on the full SSD sim** — every shipped scenario class
//!    (fresh write, steady-state GC, tiered SLC/MLC, multi-tenant QoS)
//!    produces a bit-identical `SimReport` whether it runs on the classic
//!    serial engine, the windowed engine with an explicit window, or the
//!    windowed engine at 2/4 threads. Parallelism must never be a modeling
//!    decision.
//! 2. **Randomized oracle** — `ShardedSim` (serial and parallel) against
//!    `ReferenceSim`, a single global heap in strict key order, over
//!    randomized churn models.
//! 3. **Window-FIFO property** — conservative window boundaries never
//!    reorder events, in particular same-timestamp FIFO batches: the
//!    windowed engine's dispatch sequence equals the serial engine's for
//!    random workloads at random lookaheads.

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimReport};
use ddrnand::coordinator::experiments::{qos_point_config, QosSweepSpec};
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::proptest::{check, shrink_vec};
use ddrnand::sim::{
    Emit, Engine, Model, ReferenceSim, Scheduler, ShardModel, ShardedSim, WindowedEngine,
};
use ddrnand::util::prng::Prng;
use ddrnand::util::time::Ps;

/// Everything deterministic in a [`SimReport`] (wall clock excluded).
/// Floats compare by bit pattern so NaN percentiles (no-request streams)
/// still match.
fn fingerprint(r: &SimReport) -> Vec<u64> {
    let mut f = vec![
        r.events,
        r.requests,
        r.bytes,
        r.pages_programmed,
        r.pages_read,
        r.blocks_erased,
        r.sim_time.as_ps() as u64,
        r.bandwidth_mbps.to_bits(),
        r.energy_nj_per_byte.to_bits(),
        r.latency_mean_us.to_bits(),
        r.latency_p50_us.to_bits(),
        r.latency_p99_us.to_bits(),
        r.waf.to_bits(),
        r.fairness.to_bits(),
    ];
    for s in &r.streams {
        f.push(s.requests);
        f.push(s.bandwidth_mbps.to_bits());
        f.push(s.latency_p99_us.to_bits());
    }
    f
}

/// Run `cfg` at the serial engine, then at an explicit 1-thread window and
/// at 2/4 threads, asserting bit-identical reports throughout.
fn assert_thread_invariant(label: &str, cfg: SsdConfig, mode: RequestKind, requests: usize) {
    assert!(cfg.validate().is_empty(), "{label}: config invalid: {:?}", cfg.validate());
    let baseline = fingerprint(&Campaign::new(cfg.clone(), mode, requests).run());
    for threads in [1u16, 2, 4] {
        let mut c = cfg.clone();
        c.engine.threads = threads;
        // threads = 1 exercises the explicit window-override path; the
        // multi-thread runs derive the window from the bus timing.
        c.engine.window_ps = if threads == 1 { 1_000_000 } else { 0 };
        let got = fingerprint(&Campaign::new(c, mode, requests).run());
        assert_eq!(
            got, baseline,
            "{label}: windowed engine at {threads} threads diverged from the serial engine"
        );
    }
}

#[test]
fn fresh_write_is_thread_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_thread_invariant("fresh write", cfg, RequestKind::Write, 120);
}

#[test]
fn fresh_read_is_thread_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Conv,
        ways: 2,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_thread_invariant("fresh read", cfg, RequestKind::Read, 100);
}

#[test]
fn steady_state_gc_is_thread_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.steady.enabled = true;
    cfg.steady.over_provision = 0.15;
    cfg.steady.wear_level_spread = 16;
    assert_thread_invariant("steady-state", cfg, RequestKind::Write, 150);
}

#[test]
fn tiered_flash_is_thread_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        cell: CellType::Mlc,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.tiering.enabled = true;
    cfg.tiering.slc_fraction = 0.5;
    assert_thread_invariant("tiered", cfg, RequestKind::Write, 120);
}

#[test]
fn multi_tenant_qos_is_thread_invariant() {
    // The E9 shape: latency-critical reader vs saturating bulk writer over
    // the multi-queue host path, on the weighted-QoS way scheduler.
    let spec = QosSweepSpec {
        requests: 80,
        ..QosSweepSpec::default()
    };
    let cfg = qos_point_config(
        &spec,
        InterfaceKind::Proposed,
        4,
        ddrnand::controller::sched::SchedKind::WeightedQos,
    )
    .expect("qos point config");
    let baseline = fingerprint(&Campaign::multi_tenant(cfg.clone(), spec.tenants()).run());
    for threads in [1u16, 2, 4] {
        let mut c = cfg.clone();
        c.engine.threads = threads;
        c.engine.window_ps = if threads == 1 { 1_000_000 } else { 0 };
        let got = fingerprint(&Campaign::multi_tenant(c, spec.tenants()).run());
        assert_eq!(
            got, baseline,
            "qos multi-tenant: windowed engine at {threads} threads diverged"
        );
    }
}

// ---------------------------------------------------------------------------
// Randomized ShardedSim-vs-ReferenceSim oracle.
// ---------------------------------------------------------------------------

const LOOKAHEAD: Ps = Ps::ns(50);

/// Randomized churn: each event mutates per-shard PRNG state, then spawns a
/// local follow-up at a random sub-lookahead gap or (sometimes) a
/// cross-shard message at a random delay >= the lookahead. Because handler
/// order per shard is deterministic, the PRNG state trajectory — and hence
/// the whole event cascade — must be identical under every execution.
struct RandomChurn {
    shards: u32,
    rng: Prng,
    left: u32,
    handled: u64,
    acc: u64,
}

impl ShardModel for RandomChurn {
    type Ev = u64;
    fn handle(&mut self, now: Ps, ev: u64, out: &mut Emit<u64>) {
        self.handled += 1;
        self.acc = self
            .acc
            .rotate_left(9)
            .wrapping_add(ev ^ now.as_ps() as u64);
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        let la = LOOKAHEAD.as_ps() as u64;
        if self.rng.next_bounded(8) == 0 {
            let dest = self.rng.next_bounded(self.shards as u64) as u32;
            let delay = Ps::ps((la + self.rng.next_bounded(la)) as i64);
            out.send_after(dest, delay, self.acc);
        } else {
            // Same-timestamp chains (delay 0) included on purpose.
            let delay = Ps::ps(self.rng.next_bounded(la) as i64);
            out.local_after(delay, self.acc);
        }
    }
}

fn churn_models(shards: u32, seed: u64, budget: u32) -> Vec<RandomChurn> {
    (0..shards)
        .map(|s| RandomChurn {
            shards,
            rng: Prng::new(seed ^ (0x9E37 + s as u64 * 0x1000_0000_0001)),
            left: budget,
            handled: 0,
            acc: s as u64,
        })
        .collect()
}

#[test]
fn sharded_matches_reference_oracle_across_threads() {
    for seed in [1u64, 0xBEEF, 0xDD12_7A5D] {
        let shards = 6u32;
        let budget = 400u32;
        // Reference: one global heap in strict (time, src, seq) order.
        let mut reference = ReferenceSim::new(churn_models(shards, seed, budget));
        for s in 0..shards {
            reference.seed(s, Ps::ZERO, s as u64);
        }
        let want = reference.run(Ps::MAX);
        assert!(want.drained);
        let want_state: Vec<(u64, u64)> = reference.models().map(|m| (m.handled, m.acc)).collect();

        for threads in [1usize, 2, 4] {
            let mut sim = ShardedSim::new(churn_models(shards, seed, budget), LOOKAHEAD);
            for s in 0..shards {
                sim.seed(s, Ps::ZERO, s as u64);
            }
            let got = sim.run(Ps::MAX, threads);
            assert_eq!(
                (got.end_time, got.events, got.drained),
                (want.end_time, want.events, want.drained),
                "seed {seed:#x}, {threads} threads: RunResult diverged from reference"
            );
            let got_state: Vec<(u64, u64)> = sim.models().map(|m| (m.handled, m.acc)).collect();
            assert_eq!(
                got_state, want_state,
                "seed {seed:#x}, {threads} threads: model state diverged from reference"
            );
        }
    }
}

#[test]
fn sharded_oracle_holds_under_horizon_legs() {
    // Chopping the run into horizon legs (as the coordinator's request
    // admission does) must not change where events land either.
    let seed = 0xFEED_u64;
    let shards = 4u32;
    let mut reference = ReferenceSim::new(churn_models(shards, seed, 200));
    for s in 0..shards {
        reference.seed(s, Ps::ZERO, s as u64);
    }
    let want = reference.run(Ps::MAX);
    let want_state: Vec<(u64, u64)> = reference.models().map(|m| (m.handled, m.acc)).collect();

    let mut sim = ShardedSim::new(churn_models(shards, seed, 200), LOOKAHEAD);
    for s in 0..shards {
        sim.seed(s, Ps::ZERO, s as u64);
    }
    let mut events = 0;
    let mut leg_end = Ps::us(1);
    let final_res = loop {
        let r = sim.run(leg_end, 2);
        events += r.events;
        if r.drained {
            break r;
        }
        leg_end = leg_end.saturating_add(Ps::us(1));
    };
    assert_eq!(final_res.end_time, want.end_time);
    assert_eq!(events, want.events);
    let got_state: Vec<(u64, u64)> = sim.models().map(|m| (m.handled, m.acc)).collect();
    assert_eq!(got_state, want_state);
}

// ---------------------------------------------------------------------------
// Window-FIFO property: windows never reorder dispatch.
// ---------------------------------------------------------------------------

/// Records its dispatch sequence; occasionally chains same-timestamp
/// follow-ups (`now_ev`) and short-delay events, the patterns a window
/// boundary could plausibly reorder.
#[derive(Default)]
struct Recorder {
    seen: Vec<(i64, u64)>,
}

impl Model for Recorder {
    type Ev = u64;
    fn handle(&mut self, sched: &mut Scheduler<u64>, ev: u64) {
        self.seen.push((sched.now().as_ps(), ev));
        // Deterministic in (ev): chain two same-timestamp children and one
        // short-delay child for a slice of the id space.
        if ev % 7 == 0 && ev > 0 {
            sched.now_ev(ev / 7);
            sched.now_ev(ev / 7 + 1);
        }
        if ev % 11 == 3 {
            sched.after(Ps::ns((ev % 97 + 1) as i64), ev / 3);
        }
    }
}

#[test]
fn window_boundaries_never_reorder_fifo_events() {
    check(
        "windowed dispatch == serial dispatch",
        60,
        0x57A6_11D0,
        |rng| {
            let n = 1 + rng.next_bounded(40) as usize;
            let seeds: Vec<(u64, u64)> = (0..n)
                // Coarse time buckets force same-timestamp collisions.
                .map(|_| (rng.next_bounded(12) * 100, rng.next_bounded(500)))
                .collect();
            let lookahead_ps = 1 + rng.next_bounded(200_000);
            (seeds, lookahead_ps)
        },
        |(seeds, lookahead_ps)| {
            let run_serial = |seeds: &[(u64, u64)]| {
                let mut m = Recorder::default();
                let mut s = Scheduler::new();
                for &(t, ev) in seeds {
                    s.at(Ps::ns(t as i64), ev);
                }
                let r = Engine::run(&mut m, &mut s, Ps::MAX);
                (m.seen, r.events, r.end_time)
            };
            let run_windowed = |seeds: &[(u64, u64)], la: u64| {
                let mut m = Recorder::default();
                let mut s = Scheduler::new();
                for &(t, ev) in seeds {
                    s.at(Ps::ns(t as i64), ev);
                }
                let mut engine = WindowedEngine::new(Ps::ps(la as i64));
                let r = engine.run(&mut m, &mut s, Ps::MAX);
                (m.seen, r.events, r.end_time)
            };
            let want = run_serial(seeds);
            let got = run_windowed(seeds, *lookahead_ps);
            if got != want {
                return Err(format!(
                    "dispatch diverged at lookahead {lookahead_ps} ps: \
                     serial {} events, windowed {} events",
                    want.1, got.1
                ));
            }
            Ok(())
        },
        |(seeds, lookahead_ps)| {
            let mut out: Vec<(Vec<(u64, u64)>, u64)> = shrink_vec(seeds)
                .into_iter()
                .map(|s| (s, *lookahead_ps))
                .collect();
            if *lookahead_ps > 1 {
                out.push((seeds.clone(), lookahead_ps / 2));
                out.push((seeds.clone(), 1));
            }
            out
        },
    );
}
