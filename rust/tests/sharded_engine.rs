//! The parallel-engine contracts, end to end:
//!
//! 1. **Thread-identity on the full SSD sim** — every shipped scenario
//!    class (fresh write, steady-state GC, tiered SLC/MLC, multi-tenant
//!    QoS, demand-paged mapping, observe-enabled) produces a
//!    byte-identical `SimReport` at threads 1/2/4 for a fixed window
//!    width. The window width is a *fidelity* knob — FTL job release is
//!    quantized to window boundaries — but the thread count must never be
//!    a modeling decision: the channel-sharded executor at one thread is
//!    the reference for itself at many.
//! 2. **Randomized oracle** — `ShardedSim` (serial and parallel) against
//!    `ReferenceSim`, a single global heap in strict key order, over
//!    randomized churn models; hubless and hub-coupled (the serialized
//!    commit step with boundary reinjection).
//! 3. **Window-FIFO property** — conservative window boundaries never
//!    reorder events, in particular same-timestamp FIFO batches: the
//!    windowed engine's dispatch sequence equals the serial engine's for
//!    random workloads at random lookaheads.

use ddrnand::config::{MapMode, SsdConfig};
use ddrnand::coordinator::campaign::{Campaign, SimReport};
use ddrnand::coordinator::experiments::{qos_point_config, QosSweepSpec};
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::proptest::{check, shrink_vec};
use ddrnand::sim::{
    Emit, Engine, EventKey, Hub, HubEmit, Model, ReferenceSim, Scheduler, ShardModel,
    ShardedSim, WindowedEngine,
};
use ddrnand::util::prng::Prng;
use ddrnand::util::time::Ps;

/// Everything deterministic in a [`SimReport`] (wall clock excluded).
/// Floats compare by bit pattern so NaN percentiles (no-request streams)
/// still match.
fn fingerprint(r: &SimReport) -> Vec<u64> {
    let mut f = vec![
        r.events,
        r.requests,
        r.bytes,
        r.pages_programmed,
        r.pages_read,
        r.blocks_erased,
        r.sim_time.as_ps() as u64,
        r.bandwidth_mbps.to_bits(),
        r.energy_nj_per_byte.to_bits(),
        r.latency_mean_us.to_bits(),
        r.latency_p50_us.to_bits(),
        r.latency_p99_us.to_bits(),
        r.waf.to_bits(),
        r.fairness.to_bits(),
    ];
    for s in &r.streams {
        f.push(s.requests);
        f.push(s.bandwidth_mbps.to_bits());
        f.push(s.latency_p99_us.to_bits());
    }
    f
}

/// Run `cfg` through the channel-sharded executor at a fixed window width
/// and threads 1/2/4, asserting byte-identical reports throughout. The
/// one-thread sharded run is the baseline: the window width is a fidelity
/// knob (FTL job release is quantized to window boundaries), so identity
/// is demanded across thread counts at equal width — never against the
/// classic serial engine, which the default config still selects
/// untouched. Both an explicit wide window and the derived (bus
/// min-phase) lookahead are covered.
fn assert_thread_invariant(label: &str, cfg: SsdConfig, mode: RequestKind, requests: usize) {
    assert!(cfg.validate().is_empty(), "{label}: config invalid: {:?}", cfg.validate());
    for window_ps in [1_000_000u64, 0] {
        let run_at = |threads: u16| {
            let mut c = cfg.clone();
            c.engine.threads = threads;
            c.engine.window_ps = window_ps;
            fingerprint(&Campaign::new(c, mode, requests).run())
        };
        // With window 0 the 1-thread config is not windowed at all, so
        // the 2-thread run anchors the derived-lookahead comparison.
        let baseline = run_at(if window_ps == 0 { 2 } else { 1 });
        for threads in [2u16, 4] {
            assert_eq!(
                run_at(threads),
                baseline,
                "{label}: sharded executor at {threads} threads (window {window_ps}) diverged"
            );
        }
    }
}

#[test]
fn fresh_write_is_thread_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_thread_invariant("fresh write", cfg, RequestKind::Write, 120);
}

#[test]
fn fresh_read_is_thread_invariant() {
    let cfg = SsdConfig {
        iface: InterfaceKind::Conv,
        ways: 2,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    assert_thread_invariant("fresh read", cfg, RequestKind::Read, 100);
}

#[test]
fn steady_state_gc_is_thread_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.steady.enabled = true;
    cfg.steady.over_provision = 0.15;
    cfg.steady.wear_level_spread = 16;
    assert_thread_invariant("steady-state", cfg, RequestKind::Write, 150);
}

#[test]
fn tiered_flash_is_thread_invariant() {
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        cell: CellType::Mlc,
        ways: 4,
        blocks_per_chip: 64,
        ..SsdConfig::default()
    };
    cfg.tiering.enabled = true;
    cfg.tiering.slc_fraction = 0.5;
    assert_thread_invariant("tiered", cfg, RequestKind::Write, 120);
}

#[test]
fn multi_tenant_qos_is_thread_invariant() {
    // The E9 shape: latency-critical reader vs saturating bulk writer over
    // the multi-queue host path, on the weighted-QoS way scheduler.
    let spec = QosSweepSpec {
        requests: 80,
        ..QosSweepSpec::default()
    };
    let cfg = qos_point_config(
        &spec,
        InterfaceKind::Proposed,
        4,
        ddrnand::controller::sched::SchedKind::WeightedQos,
    )
    .expect("qos point config");
    for window_ps in [1_000_000u64, 0] {
        let run_at = |threads: u16| {
            let mut c = cfg.clone();
            c.engine.threads = threads;
            c.engine.window_ps = window_ps;
            fingerprint(&Campaign::multi_tenant(c, spec.tenants()).run())
        };
        let baseline = run_at(if window_ps == 0 { 2 } else { 1 });
        for threads in [2u16, 4] {
            assert_eq!(
                run_at(threads),
                baseline,
                "qos multi-tenant: sharded executor at {threads} threads (window {window_ps}) diverged"
            );
        }
    }
}

#[test]
fn demand_paged_mapping_is_thread_invariant() {
    // Map fills crossing commit boundaries: the tests/mapping.rs shapes —
    // a warm cache (512 >= 231 translation pages, never misses) and a
    // starved one (4 pages, constant fill reads + dirty write-backs that
    // park and resume host ops across windows) — plus the overlapping
    // FMMU variant.
    for (label, cache_pages, mode) in [
        ("warm map cache", 512u64, MapMode::Demand),
        ("starved map cache", 4, MapMode::Demand),
        ("starved fmmu", 4, MapMode::Fmmu),
    ] {
        let mut cfg = SsdConfig {
            iface: InterfaceKind::Proposed,
            ways: 2,
            blocks_per_chip: 128,
            ..SsdConfig::default()
        };
        cfg.mapping.mode = mode;
        cfg.mapping.cache_pages = cache_pages;
        cfg.mapping.entries_per_page = 64;
        assert_thread_invariant(label, cfg, RequestKind::Write, 120);
    }
}

#[test]
fn observed_runs_are_thread_invariant_including_observe_block() {
    // With observation on, each shard carries its own single-channel
    // observer slice and the commit step mirrors host-link occupancy over;
    // the merged whole-drive observe block — occupancy, stall causes, GC
    // marks and the Perfetto timeline byte for byte — must be equal at
    // every thread count, on top of the usual report fingerprint.
    let mut cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        ways: 4,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    };
    cfg.observe.enabled = true;
    cfg.observe.timeline = true;
    cfg.engine.window_ps = 1_000_000;
    assert!(cfg.validate().is_empty(), "{:?}", cfg.validate());
    let run_at = |threads: u16| {
        let mut c = cfg.clone();
        c.engine.threads = threads;
        Campaign::new(c, RequestKind::Write, 120).run()
    };
    let base = run_at(1);
    let base_obs = base.observe.as_ref().expect("observe block");
    for threads in [2u16, 4] {
        let got = run_at(threads);
        assert_eq!(
            fingerprint(&got),
            fingerprint(&base),
            "observed run diverged at {threads} threads"
        );
        assert_eq!(
            got.observe.as_ref().expect("observe block"),
            base_obs,
            "observe block diverged at {threads} threads"
        );
    }
}

// ---------------------------------------------------------------------------
// Randomized ShardedSim-vs-ReferenceSim oracle.
// ---------------------------------------------------------------------------

const LOOKAHEAD: Ps = Ps::ns(50);

/// Randomized churn: each event mutates per-shard PRNG state, then spawns a
/// local follow-up at a random sub-lookahead gap or (sometimes) a
/// cross-shard message at a random delay >= the lookahead. Because handler
/// order per shard is deterministic, the PRNG state trajectory — and hence
/// the whole event cascade — must be identical under every execution.
struct RandomChurn {
    shards: u32,
    rng: Prng,
    left: u32,
    handled: u64,
    acc: u64,
}

impl ShardModel for RandomChurn {
    type Ev = u64;
    type Msg = ();
    fn handle(&mut self, now: Ps, ev: u64, out: &mut Emit<u64>) {
        self.handled += 1;
        self.acc = self
            .acc
            .rotate_left(9)
            .wrapping_add(ev ^ now.as_ps() as u64);
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        let la = LOOKAHEAD.as_ps() as u64;
        if self.rng.next_bounded(8) == 0 {
            let dest = self.rng.next_bounded(self.shards as u64) as u32;
            let delay = Ps::ps((la + self.rng.next_bounded(la)) as i64);
            out.send_after(dest, delay, self.acc);
        } else {
            // Same-timestamp chains (delay 0) included on purpose.
            let delay = Ps::ps(self.rng.next_bounded(la) as i64);
            out.local_after(delay, self.acc);
        }
    }
}

fn churn_models(shards: u32, seed: u64, budget: u32) -> Vec<RandomChurn> {
    (0..shards)
        .map(|s| RandomChurn {
            shards,
            rng: Prng::new(seed ^ (0x9E37 + s as u64 * 0x1000_0000_0001)),
            left: budget,
            handled: 0,
            acc: s as u64,
        })
        .collect()
}

#[test]
fn sharded_matches_reference_oracle_across_threads() {
    for seed in [1u64, 0xBEEF, 0xDD12_7A5D] {
        let shards = 6u32;
        let budget = 400u32;
        // Reference: one global heap in strict (time, src, seq) order.
        let mut reference = ReferenceSim::new(churn_models(shards, seed, budget));
        for s in 0..shards {
            reference.seed(s, Ps::ZERO, s as u64);
        }
        let want = reference.run(Ps::MAX);
        assert!(want.drained);
        let want_state: Vec<(u64, u64)> = reference.models().map(|m| (m.handled, m.acc)).collect();

        for threads in [1usize, 2, 4] {
            let mut sim = ShardedSim::new(churn_models(shards, seed, budget), LOOKAHEAD);
            for s in 0..shards {
                sim.seed(s, Ps::ZERO, s as u64);
            }
            let got = sim.run(Ps::MAX, threads);
            assert_eq!(
                (got.end_time, got.events, got.drained),
                (want.end_time, want.events, want.drained),
                "seed {seed:#x}, {threads} threads: RunResult diverged from reference"
            );
            let got_state: Vec<(u64, u64)> = sim.models().map(|m| (m.handled, m.acc)).collect();
            assert_eq!(
                got_state, want_state,
                "seed {seed:#x}, {threads} threads: model state diverged from reference"
            );
        }
    }
}

#[test]
fn sharded_oracle_holds_under_horizon_legs() {
    // Chopping the run into horizon legs (as the coordinator's request
    // admission does) must not change where events land either.
    let seed = 0xFEED_u64;
    let shards = 4u32;
    let mut reference = ReferenceSim::new(churn_models(shards, seed, 200));
    for s in 0..shards {
        reference.seed(s, Ps::ZERO, s as u64);
    }
    let want = reference.run(Ps::MAX);
    let want_state: Vec<(u64, u64)> = reference.models().map(|m| (m.handled, m.acc)).collect();

    let mut sim = ShardedSim::new(churn_models(shards, seed, 200), LOOKAHEAD);
    for s in 0..shards {
        sim.seed(s, Ps::ZERO, s as u64);
    }
    let mut events = 0;
    let mut leg_end = Ps::us(1);
    let final_res = loop {
        let r = sim.run(leg_end, 2);
        events += r.events;
        if r.drained {
            break r;
        }
        leg_end = leg_end.saturating_add(Ps::us(1));
    };
    assert_eq!(final_res.end_time, want.end_time);
    assert_eq!(events, want.events);
    let got_state: Vec<(u64, u64)> = sim.models().map(|m| (m.handled, m.acc)).collect();
    assert_eq!(got_state, want_state);
}

// ---------------------------------------------------------------------------
// Hub-coupled oracle: the serialized commit step with reinjection.
// ---------------------------------------------------------------------------

/// Hub-coupled churn: like [`RandomChurn`] but a slice of the spawn budget
/// goes to [`Emit::commit`] messages instead of calendar events, so the
/// commit stream exercises the `(time, shard, seq)` merge order.
struct HubbedChurn {
    rng: Prng,
    left: u32,
    handled: u64,
    acc: u64,
}

impl ShardModel for HubbedChurn {
    type Ev = u64;
    type Msg = u64;
    fn handle(&mut self, now: Ps, ev: u64, out: &mut Emit<u64, u64>) {
        self.handled += 1;
        self.acc = self
            .acc
            .rotate_left(7)
            .wrapping_add(ev ^ now.as_ps() as u64);
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        let la = LOOKAHEAD.as_ps() as u64;
        if self.rng.next_bounded(4) == 0 {
            out.commit(self.acc);
        } else {
            let delay = Ps::ps(self.rng.next_bounded(la) as i64);
            out.local_after(delay, self.acc);
        }
    }
}

/// Order-sensitive commit step: folds every message — time, source shard,
/// payload — into a running digest (any reordering changes it), and
/// reinjects one boundary event per message at a digest-derived shard, so
/// hub injections feed back into the shard calendars.
struct DigestHub {
    shards: u32,
    digest: u64,
    seen: u64,
}

impl Hub<HubbedChurn> for DigestHub {
    fn next_time(&mut self) -> Option<Ps> {
        None
    }
    fn commit(&mut self, msgs: &[(EventKey, u64)], _w_end: Ps, out: &mut HubEmit<u64>) {
        for (k, m) in msgs {
            self.seen += 1;
            self.digest = self
                .digest
                .rotate_left(11)
                .wrapping_add(k.at.as_ps() as u64 ^ ((k.src as u64) << 17) ^ m);
            let dest = (self.digest % self.shards as u64) as u32;
            out.send_at(dest, out.w_end(), self.digest);
        }
    }
}

fn hubbed_models(shards: u32, seed: u64, budget: u32) -> Vec<HubbedChurn> {
    (0..shards)
        .map(|s| HubbedChurn {
            rng: Prng::new(seed ^ (0xA11CE + s as u64 * 0x1000_0000_0001)),
            left: budget,
            handled: 0,
            acc: s as u64,
        })
        .collect()
}

#[test]
fn hub_commit_step_matches_reference_oracle_across_threads() {
    for seed in [3u64, 0xC0FFEE, 0x5EED_1DEA] {
        let shards = 6u32;
        let budget = 300u32;
        let mut reference = ReferenceSim::new(hubbed_models(shards, seed, budget));
        for s in 0..shards {
            reference.seed(s, Ps::ZERO, s as u64);
        }
        let mut ref_hub = DigestHub { shards, digest: 0, seen: 0 };
        let want = reference.run_hub(Ps::MAX, LOOKAHEAD, &mut ref_hub);
        assert!(want.drained);
        assert!(ref_hub.seen > 0, "seed {seed:#x}: no commits — oracle is vacuous");
        let want_state: Vec<(u64, u64)> = reference.models().map(|m| (m.handled, m.acc)).collect();

        for threads in [1usize, 2, 4, 8] {
            let mut sim = ShardedSim::new(hubbed_models(shards, seed, budget), LOOKAHEAD);
            for s in 0..shards {
                sim.seed(s, Ps::ZERO, s as u64);
            }
            let mut hub = DigestHub { shards, digest: 0, seen: 0 };
            let got = sim.run_hub(Ps::MAX, threads, &mut hub);
            assert_eq!(
                (got.end_time, got.events, got.drained),
                (want.end_time, want.events, want.drained),
                "seed {seed:#x}, {threads} threads: RunResult diverged from reference"
            );
            assert_eq!(
                (hub.digest, hub.seen),
                (ref_hub.digest, ref_hub.seen),
                "seed {seed:#x}, {threads} threads: commit stream diverged from reference"
            );
            let got_state: Vec<(u64, u64)> = sim.models().map(|m| (m.handled, m.acc)).collect();
            assert_eq!(
                got_state, want_state,
                "seed {seed:#x}, {threads} threads: model state diverged from reference"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Randomized full-SsdSim thread-identity oracle.
// ---------------------------------------------------------------------------

#[test]
fn randomized_ssd_configs_are_thread_invariant() {
    // Random scenario, fixed window: threads 1/2/4/8 over the channel
    // shards must agree byte for byte. Complements the curated goldens
    // above with configuration-space coverage (channel count, ways,
    // interface, steady-state GC, window width, workload mix).
    check(
        "sharded SsdSim is thread-invariant",
        8,
        0x51AB_DED5,
        |rng| {
            let iface = rng.next_bounded(2);
            let channels = [2u16, 4][rng.next_bounded(2) as usize];
            let ways = [1u16, 2, 4][rng.next_bounded(3) as usize];
            let steady = rng.next_bounded(3) == 0;
            let write = rng.next_bounded(3) != 0;
            // 100ns ..= ~10us: spans sub-phase and multi-op windows.
            let window_ps = 100_000 + rng.next_bounded(10_000_000);
            let requests = 30 + rng.next_bounded(50) as usize;
            (iface, channels, ways, steady, write, window_ps, requests)
        },
        |&(iface, channels, ways, steady, write, window_ps, requests)| {
            let mut cfg = SsdConfig {
                iface: if iface == 0 {
                    InterfaceKind::Conv
                } else {
                    InterfaceKind::Proposed
                },
                channels,
                ways,
                blocks_per_chip: 64,
                ..SsdConfig::default()
            };
            if steady {
                cfg.steady.enabled = true;
                cfg.steady.over_provision = 0.15;
                cfg.steady.wear_level_spread = 16;
            }
            cfg.engine.window_ps = window_ps;
            let errs = cfg.validate();
            if !errs.is_empty() {
                return Err(format!("invalid config: {errs:?}"));
            }
            let mode = if write { RequestKind::Write } else { RequestKind::Read };
            let run_at = |threads: u16| {
                let mut c = cfg.clone();
                c.engine.threads = threads;
                fingerprint(&Campaign::new(c, mode, requests).run())
            };
            let baseline = run_at(1);
            for threads in [2u16, 4, 8] {
                if run_at(threads) != baseline {
                    return Err(format!("diverged at {threads} threads"));
                }
            }
            Ok(())
        },
        |_| Vec::new(),
    );
}

// ---------------------------------------------------------------------------
// Window-FIFO property: windows never reorder dispatch.
// ---------------------------------------------------------------------------

/// Records its dispatch sequence; occasionally chains same-timestamp
/// follow-ups (`now_ev`) and short-delay events, the patterns a window
/// boundary could plausibly reorder.
#[derive(Default)]
struct Recorder {
    seen: Vec<(i64, u64)>,
}

impl Model for Recorder {
    type Ev = u64;
    fn handle(&mut self, sched: &mut Scheduler<u64>, ev: u64) {
        self.seen.push((sched.now().as_ps(), ev));
        // Deterministic in (ev): chain two same-timestamp children and one
        // short-delay child for a slice of the id space.
        if ev % 7 == 0 && ev > 0 {
            sched.now_ev(ev / 7);
            sched.now_ev(ev / 7 + 1);
        }
        if ev % 11 == 3 {
            sched.after(Ps::ns((ev % 97 + 1) as i64), ev / 3);
        }
    }
}

#[test]
fn window_boundaries_never_reorder_fifo_events() {
    check(
        "windowed dispatch == serial dispatch",
        60,
        0x57A6_11D0,
        |rng| {
            let n = 1 + rng.next_bounded(40) as usize;
            let seeds: Vec<(u64, u64)> = (0..n)
                // Coarse time buckets force same-timestamp collisions.
                .map(|_| (rng.next_bounded(12) * 100, rng.next_bounded(500)))
                .collect();
            let lookahead_ps = 1 + rng.next_bounded(200_000);
            (seeds, lookahead_ps)
        },
        |(seeds, lookahead_ps)| {
            let run_serial = |seeds: &[(u64, u64)]| {
                let mut m = Recorder::default();
                let mut s = Scheduler::new();
                for &(t, ev) in seeds {
                    s.at(Ps::ns(t as i64), ev);
                }
                let r = Engine::run(&mut m, &mut s, Ps::MAX);
                (m.seen, r.events, r.end_time)
            };
            let run_windowed = |seeds: &[(u64, u64)], la: u64| {
                let mut m = Recorder::default();
                let mut s = Scheduler::new();
                for &(t, ev) in seeds {
                    s.at(Ps::ns(t as i64), ev);
                }
                let mut engine = WindowedEngine::new(Ps::ps(la as i64));
                let r = engine.run(&mut m, &mut s, Ps::MAX);
                (m.seen, r.events, r.end_time)
            };
            let want = run_serial(seeds);
            let got = run_windowed(seeds, *lookahead_ps);
            if got != want {
                return Err(format!(
                    "dispatch diverged at lookahead {lookahead_ps} ps: \
                     serial {} events, windowed {} events",
                    want.1, got.1
                ));
            }
            Ok(())
        },
        |(seeds, lookahead_ps)| {
            let mut out: Vec<(Vec<(u64, u64)>, u64)> = shrink_vec(seeds)
                .into_iter()
                .map(|s| (s, *lookahead_ps))
                .collect();
            if *lookahead_ps > 1 {
                out.push((seeds.clone(), lookahead_ps / 2));
                out.push((seeds.clone(), 1));
            }
            out
        },
    );
}
