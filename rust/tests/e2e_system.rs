//! End-to-end system tests: full stack (SATA → cache → FTL → scheduler →
//! bus → chips) under workloads the paper's tables don't cover — GC
//! pressure, cache effects, hybrid FTL, failure-ish corner cases.

use ddrnand::config::{FtlKind, SsdConfig};
use ddrnand::coordinator::campaign::run_trace;
use ddrnand::coordinator::ssd::SsdSim;
use ddrnand::host::trace::{Request, RequestKind, Trace, TraceGen};
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;

fn base_cfg() -> SsdConfig {
    SsdConfig {
        iface: InterfaceKind::Proposed,
        cell: CellType::Slc,
        channels: 2,
        ways: 4,
        blocks_per_chip: 32,
        ..SsdConfig::default()
    }
}

/// Steady-state GC: write the volume several times over; the simulator must
/// finish, relocate pages, and still beat CONV.
#[test]
fn gc_pressure_completes_and_proposed_still_wins() {
    let run = |iface| {
        let cfg = SsdConfig {
            iface,
            utilization: 0.85,
            ..base_cfg()
        };
        // Logical capacity: 2*4 chips * 32 blocks * 64 pages * 2KiB * 0.85
        // ≈ 28.5 MiB; keep the footprint at 26 MiB and write ~3x that
        // with wrap-around.
        let volume = 26 * 1024 * 1024u64;
        let reqs = (volume * 3 / 65536) as usize;
        let trace: Vec<Request> = (0..reqs)
            .map(|i| Request {
                kind: RequestKind::Write,
                offset: (i as u64 * 65536) % (volume - 65536),
                bytes: 65536,
            })
            .collect();
        let mut sim = SsdSim::new(cfg, trace);
        sim.run();
        let (reloc, erases, _) = sim.ftl_stats();
        assert!(erases > 0, "rewriting 3x the volume must trigger GC erases");
        (sim.bandwidth_mbps(), reloc, erases)
    };
    let (prop_bw, _, _) = run(InterfaceKind::Proposed);
    let (conv_bw, _, _) = run(InterfaceKind::Conv);
    assert!(
        prop_bw > conv_bw,
        "PROPOSED must still win under GC: {prop_bw} vs {conv_bw}"
    );
}

/// The DRAM cache absorbs a hot working set and beats the uncached config.
#[test]
fn cache_improves_hot_workload() {
    let hot_requests: Vec<Request> = (0..400)
        .map(|i| Request {
            kind: if i % 2 == 0 { RequestKind::Write } else { RequestKind::Read },
            offset: (i as u64 % 8) * 65536, // 512 KiB hot set
            bytes: 65536,
        })
        .collect();
    let run = |cache_pages: u32| {
        let mut cfg = base_cfg();
        cfg.cache.capacity_pages = cache_pages;
        let trace = Trace::from_requests(hot_requests.clone());
        run_trace(&cfg, &trace).bandwidth_mbps
    };
    let uncached = run(0);
    let cached = run(1024); // 2 MiB cache > hot set
    assert!(
        cached > 1.5 * uncached,
        "cache must accelerate the hot set: {cached} vs {uncached}"
    );
}

/// Hybrid FTL services the paper's sequential workload correctly (merges
/// happen, data survives, throughput is positive and sane).
#[test]
fn hybrid_ftl_full_system() {
    let mut cfg = base_cfg();
    cfg.ftl = FtlKind::Hybrid;
    let trace = TraceGen::default().sequential(RequestKind::Write, 100);
    let rep = run_trace(&cfg, &trace);
    assert_eq!(rep.requests, 100);
    assert!(rep.bandwidth_mbps > 1.0);
}

/// Mixed read/write workloads complete with both request kinds accounted.
#[test]
fn mixed_workload_accounting() {
    let cfg = base_cfg();
    let trace = TraceGen::default().mixed_sequential(200, 0.5, 7);
    let rep = run_trace(&cfg, &trace);
    assert_eq!(rep.requests, 200);
    assert_eq!(rep.bytes, 200 * 65536);
    assert!(rep.pages_read > 0 && rep.pages_programmed > 0);
}

/// Random (non-sequential) reads lose striping alignment but must still
/// work and still rank the interfaces correctly.
#[test]
fn random_reads_preserve_interface_ordering() {
    let bw = |iface| {
        let cfg = SsdConfig {
            iface,
            ..base_cfg()
        };
        let trace = TraceGen::default().random(RequestKind::Read, 150, 16 << 20, 3);
        run_trace(&cfg, &trace).bandwidth_mbps
    };
    let conv = bw(InterfaceKind::Conv);
    let sync = bw(InterfaceKind::SyncOnly);
    let prop = bw(InterfaceKind::Proposed);
    assert!(prop > sync && sync > conv, "{prop} {sync} {conv}");
}

/// Single-page requests (smallest possible) and odd-sized requests.
#[test]
fn odd_request_sizes() {
    let cfg = base_cfg();
    let trace = Trace::from_requests(vec![
        Request { kind: RequestKind::Write, offset: 0, bytes: 2048 },
        Request { kind: RequestKind::Write, offset: 2048, bytes: 1 },
        Request { kind: RequestKind::Write, offset: 4096, bytes: 3000 },
        Request { kind: RequestKind::Read, offset: 0, bytes: 2048 },
        Request { kind: RequestKind::Read, offset: 2048, bytes: 6144 },
    ]);
    let rep = run_trace(&cfg, &trace);
    assert_eq!(rep.requests, 5);
    // bytes=1 still occupies one page; bytes=3000 spans two.
    assert!(rep.pages_programmed >= 4);
}

/// SATA1 halves the cap; a fast array must saturate it.
#[test]
fn sata_generation_caps_bandwidth() {
    let mut cfg = base_cfg();
    cfg.channels = 4;
    cfg.ways = 4;
    cfg.sata = ddrnand::host::sata::SataGen::sata1(); // 150 MB/s
    let trace = TraceGen::default().sequential(RequestKind::Read, 200);
    let rep = run_trace(&cfg, &trace);
    assert!(
        rep.bandwidth_mbps <= 150.0 + 1.0,
        "cap violated: {}",
        rep.bandwidth_mbps
    );
    assert!(
        rep.bandwidth_mbps > 120.0,
        "a 4x4 PROPOSED array should saturate SATA1: {}",
        rep.bandwidth_mbps
    );
}

/// Queue-depth sensitivity: QD1 must not deadlock and QD32 must not break
/// accounting; bandwidth grows (weakly) with queue depth.
#[test]
fn queue_depth_sweep() {
    let bw = |qd| {
        let mut cfg = base_cfg();
        cfg.queue_depth = qd;
        let trace = TraceGen::default().sequential(RequestKind::Write, 150);
        run_trace(&cfg, &trace).bandwidth_mbps
    };
    let q1 = bw(1);
    let q4 = bw(4);
    let q32 = bw(32);
    assert!(q1 > 0.0);
    assert!(q4 >= q1 * 0.99, "QD4 {q4} vs QD1 {q1}");
    assert!(q32 >= q4 * 0.99, "QD32 {q32} vs QD4 {q4}");
}

/// Config TOML → simulation round trip (the `simulate` CLI path).
#[test]
fn toml_config_to_simulation() {
    let cfg = SsdConfig::from_toml(
        r#"
iface = "sync_only"
cell = "mlc"
channels = 2
ways = 2
blocks_per_chip = 16
"#,
    )
    .unwrap();
    let trace = TraceGen::default().sequential(RequestKind::Write, 20);
    let rep = run_trace(&cfg, &trace);
    assert_eq!(rep.iface, "SYNC_ONLY");
    assert_eq!(rep.cell, "MLC");
    assert!(rep.bandwidth_mbps > 0.0);
}
