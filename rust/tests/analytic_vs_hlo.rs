//! Integration: the AOT JAX/Pallas artifact (through PJRT) agrees with the
//! pure-Rust analytic mirror — the cross-layer correctness contract.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message) when
//! the artifacts are absent so `cargo test` works on a fresh checkout.

use ddrnand::analytic::{self, DesignPoint};
use ddrnand::config::SsdConfig;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::pvt::PvtModel;
use ddrnand::iface::timing::{IfaceParams, InterfaceKind};
use ddrnand::nand::datasheet::CellType;
use ddrnand::runtime::{design_point_row, iface_params_row, Runtime, MC_S};
use ddrnand::util::prng::Prng;

fn runtime() -> Option<Runtime> {
    let dir = Runtime::default_dir();
    if !Runtime::artifacts_present(&dir) {
        eprintln!("SKIP: artifacts missing in {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Runtime::load(&dir).expect("artifact load"))
}

fn all_configs() -> Vec<SsdConfig> {
    let mut out = Vec::new();
    for iface in InterfaceKind::ALL {
        for cell in [CellType::Slc, CellType::Mlc] {
            for (ch, w) in [(1u16, 1u16), (1, 4), (1, 16), (2, 8), (4, 4)] {
                out.push(SsdConfig {
                    iface,
                    cell,
                    channels: ch,
                    ways: w,
                    ..SsdConfig::default()
                });
            }
        }
    }
    out
}

#[test]
fn perf_artifact_matches_rust_mirror() {
    let Some(rt) = runtime() else { return };
    let cfgs = all_configs();
    let points: Vec<DesignPoint> = cfgs.iter().map(DesignPoint::from_config).collect();
    let hlo = rt.perf_batch(&points).expect("perf_batch");
    for (i, (p, h)) in points.iter().zip(&hlo).enumerate() {
        let want = [
            analytic::read_bandwidth_mbps(p),
            analytic::write_bandwidth_mbps(p),
            analytic::energy_nj_per_byte(p, RequestKind::Read),
            analytic::energy_nj_per_byte(p, RequestKind::Write),
        ];
        for k in 0..4 {
            let rel = (h[k] - want[k]).abs() / want[k];
            assert!(
                rel < 2e-4,
                "cfg {i} out {k}: hlo={} rust={} rel={rel}",
                h[k],
                want[k]
            );
        }
    }
}

#[test]
fn perf_artifact_row_layout_is_stable() {
    // Guards the cross-language column contract: a deliberate column swap
    // must produce different results.
    let Some(rt) = runtime() else { return };
    let cfg = SsdConfig::default();
    let p = DesignPoint::from_config(&cfg);
    let row = design_point_row(&p);
    assert_eq!(row.len(), 12);
    let base = rt.perf_batch(&[p]).unwrap()[0];
    let mut swapped = p;
    std::mem::swap(&mut swapped.t_r_ns, &mut swapped.t_prog_ns);
    let other = rt.perf_batch(&[swapped]).unwrap()[0];
    assert_ne!(base[0], other[0], "column order must matter");
}

#[test]
fn timing_artifact_matches_equations() {
    let Some(rt) = runtime() else { return };
    // Table 2 corner + a sweep of alpha and t_BYTE.
    let mut corners = vec![iface_params_row(&IfaceParams::default())];
    for i in 0..20 {
        let p = IfaceParams {
            alpha: 0.5 * i as f64 / 19.0,
            t_byte_ns: 4.0 + i as f64,
            ..IfaceParams::default()
        };
        corners.push(iface_params_row(&p));
    }
    let out = rt.timing_batch(&corners).expect("timing_batch");
    // Paper values at the Table 2 corner.
    assert!((out[0][0] - 19.81).abs() < 0.01, "conv={}", out[0][0]);
    assert!((out[0][2] - 12.0).abs() < 1e-3, "proposed={}", out[0][2]);
    // Equation agreement across the sweep.
    for (i, c) in corners.iter().enumerate() {
        let p = IfaceParams {
            t_out_ns: c[0],
            t_in_ns: c[1],
            t_s_ns: c[2],
            t_h_ns: c[3],
            t_diff_ns: c[4],
            t_rea_ns: c[5],
            t_byte_ns: c[6],
            alpha: c[7],
            t_ios_ns: c[8],
            t_ioh_ns: c[9],
        };
        let want = analytic::tp_min_ns(&p);
        for k in 0..3 {
            let rel = (out[i][k] - want[k]).abs() / want[k];
            assert!(rel < 1e-4, "corner {i} iface {k}: {} vs {}", out[i][k], want[k]);
        }
        let gain = out[i][0] / out[i][2];
        assert!((out[i][3] - gain).abs() < 1e-4);
    }
}

#[test]
fn mc_artifact_matches_rust_pvt_distributionally() {
    let Some(rt) = runtime() else { return };
    // Same margin, same sigmas, *independent* randomness: the violation
    // probabilities should agree within Monte Carlo error.
    let mut rng = Prng::new(0x5EED);
    let z: Vec<f32> = (0..MC_S * 4).map(|_| rng.next_gaussian() as f32).collect();
    let corner = iface_params_row(&IfaceParams::default());
    let margin = 1.02;
    let hlo = rt
        .mc_batch(&[corner], &z, [0.10, 0.05, margin])
        .expect("mc_batch")[0];

    let pvt = PvtModel {
        chip_sigma: 0.10,
        board_sigma: 0.05,
    };
    let params = IfaceParams::default();
    for (k, kind) in InterfaceKind::ALL.iter().enumerate() {
        let tp = params.tp_min_ns(*kind) * margin;
        let want = pvt.violation_probability(*kind, &params, tp, 40_000, 99);
        let diff = (hlo[k] - want).abs();
        assert!(
            diff < 0.02,
            "{kind}: hlo={} rust={} diff={diff}",
            hlo[k],
            want
        );
    }
    // And the paper's ordering: CONV most sensitive.
    assert!(hlo[0] > hlo[2], "CONV should violate more than PROPOSED");
}

#[test]
fn dse_hlo_and_native_backends_agree() {
    let Some(rt) = runtime() else { return };
    use ddrnand::dse::{evaluate, Backend, Space};
    let space = Space::default();
    let (hlo, b1) = evaluate(&space, Some(&rt)).unwrap();
    let (native, b2) = evaluate(&space, None).unwrap();
    assert_eq!(b1, Backend::Hlo);
    assert_eq!(b2, Backend::Native);
    for (h, n) in hlo.iter().zip(&native) {
        assert!((h.read_bw - n.read_bw).abs() / n.read_bw < 2e-4);
        assert!((h.write_bw - n.write_bw).abs() / n.write_bw < 2e-4);
    }
}
