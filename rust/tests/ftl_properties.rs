//! Property-based tests over FTL and simulator invariants, using the
//! in-crate proptest harness (rust/src/proptest.rs).

use ddrnand::config::{FtlKind, SsdConfig};
use ddrnand::controller::ftl::hybrid::HybridFtl;
use ddrnand::controller::ftl::page_map::PageMapFtl;
use ddrnand::controller::ftl::{check_mapping_consistency, Ftl};
use ddrnand::coordinator::ssd::SsdSim;
use ddrnand::host::trace::{Request, RequestKind};
use ddrnand::nand::geometry::Geometry;
use ddrnand::proptest::{check, shrink_vec};
use ddrnand::util::prng::Prng;

fn small_geom() -> Geometry {
    Geometry {
        channels: 2,
        ways: 2,
        blocks_per_chip: 8,
        pages_per_block: 8,
        page_bytes: 2048,
    }
}

/// Any write sequence leaves the page-map FTL consistent: each mapped lpn
/// resolves to a unique in-range ppn, and reading back every written lpn
/// succeeds.
#[test]
fn prop_page_map_consistency_under_random_writes() {
    let logical = 128u64;
    check(
        "page-map consistency",
        60,
        0xF71,
        |rng: &mut Prng| {
            let n = 50 + rng.next_bounded(400) as usize;
            (0..n).map(|_| rng.next_bounded(logical)).collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let mut ftl = PageMapFtl::new(small_geom(), logical);
            let mut written = std::collections::BTreeSet::new();
            for &lpn in writes {
                let plan = ftl.plan_write(lpn);
                if plan.target_ppn >= ftl.geometry().total_pages() {
                    return Err(format!("ppn {} out of range", plan.target_ppn));
                }
                written.insert(lpn);
            }
            for &lpn in &written {
                if ftl.translate(lpn).is_none() {
                    return Err(format!("written lpn {lpn} unreadable"));
                }
            }
            let lpns: Vec<u64> = (0..logical).collect();
            check_mapping_consistency(&ftl, &lpns)
        },
        |v| shrink_vec(v),
    );
}

/// The hybrid FTL preserves every written page across merges.
#[test]
fn prop_hybrid_preserves_data() {
    let geom = small_geom();
    let logical_blocks = 16u64; // conservative subset
    check(
        "hybrid durability",
        40,
        0xF72,
        |rng: &mut Prng| {
            let n = 30 + rng.next_bounded(200) as usize;
            (0..n)
                .map(|_| rng.next_bounded(logical_blocks * geom.pages_per_block as u64))
                .collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let mut ftl = HybridFtl::new(small_geom(), 3);
            let mut latest = std::collections::BTreeMap::new();
            for (i, &lpn) in writes.iter().enumerate() {
                let plan = ftl.plan_write(lpn);
                latest.insert(lpn, i);
                if plan.target_ppn >= ftl.geometry().total_pages() {
                    return Err(format!("ppn {} out of range", plan.target_ppn));
                }
                // Free-block floor: merges reserve a spare, so the pool
                // never empties mid-sequence.
                if ftl.free_block_count() < 1 {
                    return Err(format!("write {i}: hybrid free-block pool emptied"));
                }
            }
            for &lpn in latest.keys() {
                if ftl.translate(lpn).is_none() {
                    return Err(format!("lpn {lpn} lost after merges"));
                }
            }
            Ok(())
        },
        |v| shrink_vec(v),
    );
}

/// Free-page accounting never goes negative and erases reclaim exactly one
/// block's worth of pages.
#[test]
fn prop_page_map_free_accounting() {
    let logical = 96u64;
    check(
        "free-page accounting",
        40,
        0xF73,
        |rng: &mut Prng| {
            let n = 100 + rng.next_bounded(600) as usize;
            (0..n).map(|_| rng.next_bounded(logical)).collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let geom = small_geom();
            let mut ftl = PageMapFtl::new(geom, logical);
            let total = geom.total_pages();
            for &lpn in writes {
                ftl.plan_write(lpn);
                let free = ftl.free_pages();
                if free > total {
                    return Err(format!("free {free} > total {total}"));
                }
            }
            Ok(())
        },
        |v| shrink_vec(v),
    );
}

/// GC conservation invariants, checked after *every* write of a random
/// sequence that drives the page-map FTL deep into steady-state GC:
/// no lpn is lost or duplicated across collections, and the allocator's
/// valid-page total equals the number of currently-mapped lpns exactly.
#[test]
fn prop_gc_conserves_lpns_and_valid_counts() {
    let logical = 64u64; // 50% of the 128-page small_geom -> heavy GC
    check(
        "GC lpn/valid-count conservation",
        30,
        0xF74,
        |rng: &mut Prng| {
            let n = 200 + rng.next_bounded(800) as usize;
            (0..n).map(|_| rng.next_bounded(logical)).collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let mut ftl = PageMapFtl::new(small_geom(), logical);
            let mut mapped = std::collections::BTreeSet::new();
            for (i, &lpn) in writes.iter().enumerate() {
                ftl.plan_write(lpn);
                mapped.insert(lpn);
                // Conservation: live pages == mapped lpns, exactly.
                let valid = ftl.valid_pages_total();
                if valid != mapped.len() as u64 {
                    return Err(format!(
                        "write {i}: valid {valid} != mapped {}",
                        mapped.len()
                    ));
                }
            }
            // No lpn lost...
            for &lpn in &mapped {
                if ftl.translate(lpn).is_none() {
                    return Err(format!("lpn {lpn} lost across collections"));
                }
            }
            // ...and none duplicated (unique in-range ppns).
            check_mapping_consistency(&ftl, &(0..logical).collect::<Vec<_>>())
        },
        |v| shrink_vec(v),
    );
}

/// Free-block floor: once GC has started reclaiming, the threshold keeps
/// at least one erased block per chip at every step (the headroom that
/// lets relocations land mid-reclaim), and free-page accounting never
/// exceeds physical capacity.
#[test]
fn prop_gc_free_block_floor_respected() {
    let logical = 64u64;
    check(
        "GC free-block floor",
        30,
        0xF75,
        |rng: &mut Prng| {
            let n = 200 + rng.next_bounded(800) as usize;
            (0..n).map(|_| rng.next_bounded(logical)).collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let geom = small_geom();
            let mut ftl = PageMapFtl::new(geom, logical);
            let total = geom.total_pages();
            for (i, &lpn) in writes.iter().enumerate() {
                ftl.plan_write(lpn);
                if ftl.free_pages() > total {
                    return Err(format!("write {i}: free {} > total {total}", ftl.free_pages()));
                }
                if ftl.erases() > 0 && ftl.min_free_blocks() < 1 {
                    return Err(format!(
                        "write {i}: free-block floor broken (min {} after {} erases)",
                        ftl.min_free_blocks(),
                        ftl.erases()
                    ));
                }
            }
            Ok(())
        },
        |v| shrink_vec(v),
    );
}

/// Wear stays bounded under the leveler: for any uniform-random write
/// sequence long enough to cycle a chip's blocks many times, dynamic +
/// static wear leveling keep the FTL-visible P/E spread within the static
/// threshold (plus a small transient — WL is amortized to block rolls).
#[test]
fn prop_wear_spread_bounded_under_leveler() {
    // Single chip, 8 blocks x 16 pages, 50% utilized — the geometry of the
    // in-module leveler unit test, driven here with randomized sequences.
    let geom = Geometry {
        channels: 1,
        ways: 1,
        blocks_per_chip: 8,
        pages_per_block: 16,
        page_bytes: 2048,
    };
    let logical = 64u64;
    check(
        "wear spread bounded",
        15,
        0xF76,
        |rng: &mut Prng| {
            let n = 1500 + rng.next_bounded(1500) as usize;
            (0..n).map(|_| rng.next_bounded(logical)).collect::<Vec<u64>>()
        },
        |writes: &Vec<u64>| {
            let mut ftl = PageMapFtl::new(geom, logical);
            for &lpn in writes {
                ftl.plan_write(lpn);
            }
            let bound = ftl.tuning.static_wl_threshold + 3;
            if ftl.wear_spread() > bound {
                return Err(format!(
                    "spread {} exceeds leveler bound {bound}",
                    ftl.wear_spread()
                ));
            }
            Ok(())
        },
        |v| shrink_vec(v),
    );
}

/// Full-simulator metamorphic property: doubling the trace roughly doubles
/// simulated time (steady-state linearity), and bandwidth is invariant.
#[test]
fn prop_simulation_time_linearity() {
    let run = |n: usize| {
        let cfg = SsdConfig {
            ways: 4,
            blocks_per_chip: 256,
            ..SsdConfig::default()
        };
        let trace: Vec<Request> = (0..n)
            .map(|i| Request {
                kind: RequestKind::Write,
                offset: i as u64 * 65536,
                bytes: 65536,
            })
            .collect();
        let mut sim = SsdSim::new(cfg, trace);
        sim.run();
        (sim.finished_at(), sim.bandwidth_mbps())
    };
    let (t1, bw1) = run(100);
    let (t2, bw2) = run(200);
    let ratio = t2.as_ps() as f64 / t1.as_ps() as f64;
    assert!((ratio - 2.0).abs() < 0.05, "time ratio {ratio}");
    assert!((bw1 - bw2).abs() / bw1 < 0.05, "bw {bw1} vs {bw2}");
}

/// Determinism: identical seeds and configs give bit-identical outcomes,
/// regardless of thread scheduling in the sweep pool.
#[test]
fn prop_sweep_determinism() {
    use ddrnand::coordinator::campaign::Campaign;
    use ddrnand::coordinator::pool::ThreadPool;
    let jobs = || {
        (1u16..=8)
            .map(|w| {
                let cfg = SsdConfig {
                    ways: w,
                    blocks_per_chip: 128,
                    ..SsdConfig::default()
                };
                move || Campaign::new(cfg, RequestKind::Write, 50).run().sim_time
            })
            .collect::<Vec<_>>()
    };
    let a = ThreadPool::new(8).run_all(jobs());
    let b = ThreadPool::new(1).run_all(jobs());
    assert_eq!(a, b, "sweep results must not depend on thread interleaving");
}

/// Starvation-freedom of the `WeightedQos` way scheduler: for any random
/// mix of queued job classes across ways and any all-positive weight
/// vector, draining the scheduler serves every class with pending work at
/// least once per 2·Σweights consecutive grants — no class can starve.
#[test]
fn prop_weighted_qos_is_starvation_free() {
    use ddrnand::controller::sched::{SchedKind, WayScheduler};
    use ddrnand::controller::way::{JobPhase, PageJob, PageJobKind, WayState};
    use ddrnand::nand::chip::Chip;
    use ddrnand::nand::datasheet::NandTiming;
    use ddrnand::util::time::Ps;

    type Case = (Vec<Vec<u8>>, [u32; 4]); // per-way job classes, weights
    check(
        "weighted-qos starvation freedom",
        60,
        0xE9_51,
        |rng: &mut Prng| -> Case {
            let nways = 1 + rng.next_bounded(4) as usize;
            let queues = (0..nways)
                .map(|_| {
                    let n = 1 + rng.next_bounded(25) as usize;
                    (0..n).map(|_| rng.next_bounded(4) as u8).collect()
                })
                .collect();
            let weights = [
                1 + rng.next_bounded(8) as u32,
                1 + rng.next_bounded(8) as u32,
                1 + rng.next_bounded(8) as u32,
                1 + rng.next_bounded(8) as u32,
            ];
            (queues, weights)
        },
        |case: &Case| {
            let (queues, weights) = case;
            let mut ways: Vec<WayState> = queues
                .iter()
                .map(|classes| {
                    let mut w = WayState::new(Chip::new(NandTiming::slc(), 8));
                    for &class in classes {
                        w.push(PageJob {
                            req: 0,
                            stream: 0,
                            class,
                            kind: PageJobKind::Program,
                            block: 0,
                            page: 0,
                            bytes: 2048,
                            phase: JobPhase::Queued,
                        });
                    }
                    w
                })
                .collect();
            // A class is *eligible* when some way holds a dispatchable
            // candidate of it: before that way's background barrier for
            // host classes, the barrier job itself for class 3 (the
            // plan-order rule, `WayState::reorder_window`). The service
            // bound applies to eligible classes; a class blocked behind a
            // barrier is withheld by the ordering invariant, not starved
            // by the scheduler. Eligibility is monotone until served
            // (grants only shrink queues), so the counter is sound.
            let eligible = |ways: &[WayState], class: u8| -> bool {
                ways.iter().any(|w| {
                    if w.queued_of_class(class) == 0 {
                        return false;
                    }
                    let window = w.reorder_window();
                    let limit = if class == 3 {
                        (window + 1).min(w.queue_len())
                    } else {
                        window
                    };
                    w.first_of_class_in(class, limit).is_some()
                })
            };
            let total: usize = queues.iter().map(Vec::len).sum();
            let bound = 2 * weights.iter().sum::<u32>() as usize;
            let mut sched =
                ddrnand::controller::sched::build(SchedKind::WeightedQos, *weights);
            // Grants since an eligible class was last served.
            let mut waiting = [0usize; 4];
            let mut served = 0usize;
            while let Some(g) = sched.pick(&ways, Ps::ZERO) {
                let was_eligible: Vec<bool> =
                    (0..4u8).map(|c| eligible(&ways, c)).collect();
                let job = ways[g.way]
                    .take_job(g.job)
                    .ok_or_else(|| format!("grant named a missing job: {g:?}"))?;
                served += 1;
                if served > total {
                    return Err("scheduler granted more jobs than exist".into());
                }
                let c = job.class as usize;
                waiting[c] = 0;
                for other in 0..4 {
                    if other == c {
                        continue;
                    }
                    if was_eligible[other] {
                        waiting[other] += 1;
                        if waiting[other] > bound {
                            return Err(format!(
                                "class {other} starved for {} grants (bound {bound}, \
                                 weights {weights:?})",
                                waiting[other]
                            ));
                        }
                    } else {
                        waiting[other] = 0;
                    }
                }
            }
            if served != total {
                return Err(format!("drained {served} of {total} jobs"));
            }
            Ok(())
        },
        |case| {
            // Shrink by dropping whole ways, then halving each way's queue.
            let (queues, weights) = case;
            let mut out: Vec<Case> = shrink_vec(queues)
                .into_iter()
                .map(|q| (q, *weights))
                .collect();
            out.extend(
                queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    .map(|(i, q)| {
                        let mut smaller = queues.clone();
                        smaller[i] = q[..q.len() / 2].to_vec();
                        (smaller, *weights)
                    }),
            );
            out
        },
    );
}
