//! E1 — Table 2 / §5.2: operating-frequency determination, plus the A1
//! α-sweep ablation and the timing-kernel wall-clock (native vs PJRT).
//!
//! Run: `cargo bench --bench bench_table2`

use ddrnand::analytic;
use ddrnand::bench::bench;
use ddrnand::coordinator::experiments::table2_text;
use ddrnand::iface::timing::{IfaceParams, InterfaceKind};
use ddrnand::runtime::{iface_params_row, Runtime};

fn main() {
    println!("{}", table2_text());

    // A1 ablation: α sweep on Eq. (6).
    println!("A1 — alpha sweep (Eq. 6), CONV t_P,min and frequency:");
    for i in 0..=5 {
        let alpha = i as f64 * 0.1;
        let p = IfaceParams {
            alpha,
            ..IfaceParams::default()
        };
        println!(
            "  alpha={alpha:.1}  t_P,min={:6.2} ns  f={:>2} MHz  (PROPOSED stays {} MHz)",
            p.conv_tp_min_ns(),
            p.operating_freq_mhz(InterfaceKind::Conv),
            p.operating_freq_mhz(InterfaceKind::Proposed),
        );
    }
    println!();

    // Wall-clock: native equation evaluation over a big grid.
    let corners: Vec<[f64; 10]> = (0..1024)
        .map(|i| {
            let p = IfaceParams {
                alpha: (i % 6) as f64 * 0.1,
                t_byte_ns: 4.0 + (i % 17) as f64,
                ..IfaceParams::default()
            };
            iface_params_row(&p)
        })
        .collect();

    let r = bench("timing equations, native (1024 corners)", 3, 30, || {
        for c in &corners {
            let p = IfaceParams {
                t_out_ns: c[0],
                t_in_ns: c[1],
                t_s_ns: c[2],
                t_h_ns: c[3],
                t_diff_ns: c[4],
                t_rea_ns: c[5],
                t_byte_ns: c[6],
                alpha: c[7],
                t_ios_ns: c[8],
                t_ioh_ns: c[9],
            };
            std::hint::black_box(analytic::tp_min_ns(&p));
        }
    });
    println!("{}", r.report());

    let dir = Runtime::default_dir();
    if Runtime::artifacts_present(&dir) {
        let rt = Runtime::load(&dir).expect("load artifacts");
        println!("(PJRT compile: {:.1} ms one-off)", rt.compile_ms);
        let r = bench("timing equations, PJRT HLO (1024 corners)", 3, 30, || {
            std::hint::black_box(rt.timing_batch(&corners).unwrap());
        });
        println!("{}", r.report());
    } else {
        println!("artifacts missing; skipping PJRT timing bench (run `make artifacts`)");
    }
}
