//! §Perf microbenchmarks: event-calendar ops (bucketed calendar vs the
//! BinaryHeap baseline), DES engine dispatch (incl. same-timestamp batch
//! drain), full-SSD simulation events/s, sweep scaling across threads with
//! per-worker simulator reuse, and the PJRT analytic-batch latency.
//!
//! Numbers are printed human-readable AND recorded machine-readable to
//! `BENCH_engine.json` at the repo root (override with `$BENCH_JSON`), so
//! every perf PR leaves a measured trajectory (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench bench_engine`

use ddrnand::bench::{bench, throughput, PerfLog};
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimWorkspace};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::bus::BusTiming;
use ddrnand::iface::timing::{IfaceParams, InterfaceKind};
use ddrnand::sim::{Emit, Engine, EventQueue, HeapEventQueue, Model, Scheduler, ShardModel, ShardedSim};
use ddrnand::util::time::Ps;

/// Ping-pong model: minimal per-event work to measure engine overhead.
struct PingPong {
    left: u64,
}
impl Model for PingPong {
    type Ev = u32;
    fn handle(&mut self, sched: &mut Scheduler<u32>, ev: u32) {
        if self.left > 0 {
            self.left -= 1;
            sched.after(Ps::ns(10), ev ^ 1);
        }
    }
}

/// Fan-out model: every event at t spawns a batch of events at t + 100ns,
/// exercising the same-timestamp batch drain.
struct FanOut {
    rounds: u32,
    width: u32,
    handled: u64,
}
impl Model for FanOut {
    type Ev = u32;
    fn handle(&mut self, sched: &mut Scheduler<u32>, round: u32) {
        self.handled += 1;
        if round < self.rounds && self.handled % self.width as u64 == 1 {
            for _ in 0..self.width {
                sched.after(Ps::ns(100), round + 1);
            }
        }
    }
}

/// The microbench op sequence, identical for both calendar implementations:
/// `n` pushes with hashed times in [0, 1 ms), then a full drain.
fn hashed_time(i: u32) -> Ps {
    Ps::ns(((i.wrapping_mul(2_654_435_761)) % 1_000_000) as i64)
}

/// Per-channel churn for the sharded-engine bench: each shard runs a dense
/// local event chain (gap = lookahead/64, so a conservative window holds
/// ~64 events per shard) with a cross-channel message every
/// `cross_every`-th event at exactly the lookahead delay — the same shape
/// as way traffic with occasional cross-channel completions, parameterized
/// from the steady-state preset's PROPOSED bus timing.
struct ChannelChurn {
    shards: u32,
    lookahead: Ps,
    local_gap: Ps,
    cross_every: u64,
    /// Remaining events this shard may spawn (bounds the run).
    left: u64,
    handled: u64,
    acc: u64,
}

impl ShardModel for ChannelChurn {
    type Ev = u64;
    type Msg = ();
    fn handle(&mut self, _now: Ps, ev: u64, out: &mut Emit<u64>) {
        self.handled += 1;
        // A few arithmetic mixes standing in for way-state bookkeeping.
        self.acc = self.acc.rotate_left(7) ^ ev.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        if self.left == 0 {
            return;
        }
        self.left -= 1;
        if self.handled % self.cross_every == 0 {
            let dest = (out.shard() + 1) % self.shards;
            out.send_after(dest, self.lookahead, self.acc);
        } else {
            out.local_after(self.local_gap, self.acc);
        }
    }
}

/// One sharded-churn run: `shards` channels, `per_shard` events each.
/// Returns (total events, elapsed seconds).
fn sharded_churn_run(shards: u32, per_shard: u64, lookahead: Ps, threads: usize) -> (u64, f64) {
    let models: Vec<ChannelChurn> = (0..shards)
        .map(|_| ChannelChurn {
            shards,
            lookahead,
            local_gap: Ps::ps((lookahead.as_ps() / 64).max(1)),
            cross_every: 256,
            left: per_shard,
            handled: 0,
            acc: 0,
        })
        .collect();
    let mut sim = ShardedSim::new(models, lookahead);
    for s in 0..shards {
        sim.seed(s, Ps::ZERO, s as u64);
    }
    let t0 = std::time::Instant::now();
    let res = sim.run(Ps::MAX, threads);
    let secs = t0.elapsed().as_secs_f64();
    assert!(res.drained, "churn bench must drain");
    (res.events, secs)
}

fn main() {
    let mut log = PerfLog::new("bench_engine");

    // 1. Raw event-calendar ops: bucketed calendar vs BinaryHeap baseline.
    const QN: u32 = 100_000;
    let heap = bench("event queue: 100k push+pop (heap baseline)", 3, 20, || {
        let mut q = HeapEventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", heap.report());
    log.push_bench("event_queue_100k/heap", &heap);
    let cal = bench("event queue: 100k push+pop (calendar)", 3, 20, || {
        let mut q = EventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", cal.report());
    log.push_bench("event_queue_100k/calendar", &cal);
    let speedup = heap.summary.median / cal.summary.median;
    println!("  -> calendar speedup vs heap baseline: {speedup:.2}x (target >= 1.3x)");
    log.push("event_queue_100k/speedup_vs_heap", "ratio", speedup, 20);

    // 1b. Tie-heavy variant: 100 events per timestamp (batch shape).
    let heap_ties = bench("event queue: 100k ties x100 (heap baseline)", 3, 20, || {
        let mut q = HeapEventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i / 100), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", heap_ties.report());
    log.push_bench("event_queue_ties/heap", &heap_ties);
    let cal_ties = bench("event queue: 100k ties x100 (calendar)", 3, 20, || {
        let mut q = EventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i / 100), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", cal_ties.report());
    log.push_bench("event_queue_ties/calendar", &cal_ties);
    log.push(
        "event_queue_ties/speedup_vs_heap",
        "ratio",
        heap_ties.summary.median / cal_ties.summary.median,
        20,
    );

    // 2. Engine dispatch overhead (sparse queue, alternating events).
    println!(
        "{}",
        throughput("DES engine: ping-pong events", || {
            let n = 5_000_000u64;
            let mut m = PingPong { left: n };
            let mut s = Scheduler::new();
            s.at(Ps::ZERO, 0u32);
            let t0 = std::time::Instant::now();
            let res = Engine::run(&mut m, &mut s, Ps::MAX);
            let secs = t0.elapsed().as_secs_f64();
            log.push("engine_pingpong/events_per_sec", "events_per_sec", res.events as f64 / secs, 1);
            (res.events, secs)
        })
    );

    // 2b. Batch drain: wide same-timestamp fan-outs.
    println!(
        "{}",
        throughput("DES engine: same-timestamp fan-out batches", || {
            let mut m = FanOut {
                rounds: 2_000,
                width: 500,
                handled: 0,
            };
            let mut s = Scheduler::new();
            for _ in 0..500 {
                s.at(Ps::ZERO, 0u32);
            }
            let t0 = std::time::Instant::now();
            let res = Engine::run(&mut m, &mut s, Ps::MAX);
            let secs = t0.elapsed().as_secs_f64();
            log.push("engine_fanout/events_per_sec", "events_per_sec", res.events as f64 / secs, 1);
            (res.events, secs)
        })
    );

    // 3. Full-SSD simulation throughput.
    for (iface, ways, label, key) in [
        (InterfaceKind::Proposed, 16u16, "PROPOSED 16-way SLC write", "full_sim/proposed_16way"),
        (InterfaceKind::Conv, 4, "CONV 4-way SLC write", "full_sim/conv_4way"),
    ] {
        println!(
            "{}",
            throughput(&format!("full SSD sim: {label}"), || {
                let cfg = SsdConfig {
                    iface,
                    ways,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                let t0 = std::time::Instant::now();
                let rep = Campaign::new(cfg, RequestKind::Write, 2000).run();
                let secs = t0.elapsed().as_secs_f64();
                log.push(key, "events_per_sec", rep.events as f64 / secs, 1);
                log.push(key, "wall_ms", rep.wall_ms, 1);
                (rep.events, secs)
            })
        );
    }

    // 3b. Sharded-executor overhead on the full SSD sim at a shape that
    //     cannot parallelize (1 channel -> 1 shard, run serially): the
    //     same campaign as `full_sim/conv_4way` dispatched through the
    //     channel-sharded executor, measuring pure window + commit-step
    //     bookkeeping. (Results are thread-invariant but, unlike the old
    //     WindowedEngine, not bit-identical to the classic engine: job
    //     release is quantized to window boundaries.)
    println!(
        "{}",
        throughput("full SSD sim: CONV 4-way via sharded executor (2 threads)", || {
            let mut cfg = SsdConfig {
                iface: InterfaceKind::Conv,
                ways: 4,
                blocks_per_chip: 512,
                ..SsdConfig::default()
            };
            cfg.engine.threads = 2;
            let t0 = std::time::Instant::now();
            let rep = Campaign::new(cfg, RequestKind::Write, 2000).run();
            let secs = t0.elapsed().as_secs_f64();
            log.push_tagged(
                "full_sim/conv_4way_windowed",
                "events_per_sec",
                rep.events as f64 / secs,
                1,
                2,
                0,
            );
            (rep.events, secs)
        })
    );

    // 3c. Sharded engine: channel-parallel churn parameterized from the
    //     steady-state preset's PROPOSED bus timing (8 channels, lookahead
    //     = the bus's shortest phase). Every thread count dispatches the
    //     identical global event order; wall clock is the only difference.
    let lookahead =
        BusTiming::from_params(&IfaceParams::default(), InterfaceKind::Proposed).min_phase();
    const SHARDS: u32 = 8;
    const PER_SHARD: u64 = 250_000;
    let mut base_events = 0u64;
    let mut base_secs = 0.0f64;
    for threads in [1usize, 2, 4] {
        let (events, secs) = sharded_churn_run(SHARDS, PER_SHARD, lookahead, threads);
        println!(
            "sharded churn: {threads} threads  {SHARDS} channels  {events:>9} events  {secs:.2}s  ({}/s)",
            ddrnand::util::fmt::fmt_si(events as f64 / secs)
        );
        log.push_tagged(
            &format!("sharded_steady_churn/{threads}_threads"),
            "events_per_sec",
            events as f64 / secs,
            1,
            threads as u16,
            0,
        );
        if threads == 1 {
            base_events = events;
            base_secs = secs;
        } else {
            assert_eq!(
                events, base_events,
                "sharded run must dispatch the identical event count at any thread count"
            );
            let speedup = base_secs / secs;
            println!("  -> speedup vs 1 thread: {speedup:.2}x");
            log.push_tagged(
                &format!("sharded_steady_churn/{threads}_threads/speedup_vs_1thread"),
                "ratio",
                speedup,
                1,
                threads as u16,
                0,
            );
        }
    }

    // 3d. True channel shards on the full SSD sim: a saturated 8-channel
    //     E2-style point (PROPOSED, 4 ways/channel, closed loop at depth
    //     64) through the channel-sharded executor at an explicit 50 us
    //     window, threads 1/2/4. The thread count must not show in the
    //     report — only in the wall clock; the 4-thread speedup ratio is
    //     the record the regression gate watches (>= 1.5x target).
    const GRID_WINDOW_PS: u64 = 50_000_000;
    let grid_run = |threads: u16| {
        let mut cfg = SsdConfig {
            iface: InterfaceKind::Proposed,
            channels: 8,
            ways: 4,
            blocks_per_chip: 256,
            queue_depth: 64,
            ..SsdConfig::default()
        };
        cfg.engine.threads = threads;
        cfg.engine.window_ps = GRID_WINDOW_PS;
        let t0 = std::time::Instant::now();
        let rep = Campaign::new(cfg, RequestKind::Write, 1600).run();
        let secs = t0.elapsed().as_secs_f64();
        let fp = (
            rep.events,
            rep.sim_time,
            rep.pages_programmed,
            rep.bandwidth_mbps.to_bits(),
        );
        (rep.events, secs, fp)
    };
    let mut grid_base: Option<(f64, (u64, Ps, u64, u64))> = None;
    for threads in [1u16, 2, 4] {
        let (events, secs, fp) = grid_run(threads);
        println!(
            "sharded SSD grid: {threads} threads  8 channels  {events:>9} events  {secs:.2}s  ({}/s)",
            ddrnand::util::fmt::fmt_si(events as f64 / secs)
        );
        log.push_tagged(
            &format!("sharded_ssd_grid/{threads}_threads"),
            "events_per_sec",
            events as f64 / secs,
            1,
            threads,
            GRID_WINDOW_PS,
        );
        match &grid_base {
            None => grid_base = Some((secs, fp)),
            Some((base_secs, base_fp)) => {
                assert_eq!(
                    fp, *base_fp,
                    "sharded SSD grid must report identically at any thread count"
                );
                let speedup = base_secs / secs;
                println!("  -> speedup vs 1 thread: {speedup:.2}x");
                log.push_tagged(
                    &format!("sharded_ssd_grid/{threads}_threads/speedup_vs_1thread"),
                    "ratio",
                    speedup,
                    1,
                    threads,
                    GRID_WINDOW_PS,
                );
            }
        }
    }

    // 4. Sweep scaling across worker threads, with per-worker simulator
    //    reuse (SimWorkspace) — the campaign path the paper sweeps use.
    let sweep = |threads| {
        let pool = ThreadPool::new(threads);
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move |ws: &mut SimWorkspace| {
                    let cfg = SsdConfig {
                        iface: InterfaceKind::Proposed,
                        ways: 1 + (i % 16) as u16,
                        blocks_per_chip: 512,
                        ..SsdConfig::default()
                    };
                    let rep = Campaign::new(cfg, RequestKind::Write, 300).run_in(ws);
                    (rep.events, rep.wall_ms)
                }
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = pool.run_all_with(jobs, SimWorkspace::new);
        let ev: u64 = out.iter().map(|(e, _)| e).sum();
        let mean_wall: f64 = out.iter().map(|(_, w)| w).sum::<f64>() / out.len() as f64;
        (ev, t0.elapsed().as_secs_f64(), mean_wall)
    };
    for threads in [1usize, 4, 0] {
        let (ev, secs, mean_wall) = sweep(threads);
        let shown = if threads == 0 { num_cpus() } else { threads };
        println!(
            "sweep scaling: {shown:>2} threads  16 sims  {ev:>9} events  {secs:.2}s  ({mean_wall:.1} ms/point)"
        );
        log.push(
            &format!("sweep_16sims/{shown}_threads"),
            "wall_sec",
            secs,
            16,
        );
        log.push(
            &format!("sweep_16sims/{shown}_threads_per_point"),
            "wall_ms_mean",
            mean_wall,
            16,
        );
    }

    // 5. PJRT analytic batch (skipped without artifacts or the `pjrt`
    //    feature — see rust/src/runtime/mod.rs).
    let dir = ddrnand::runtime::Runtime::default_dir();
    if ddrnand::runtime::Runtime::artifacts_present(&dir) {
        let rt = ddrnand::runtime::Runtime::load(&dir).unwrap();
        let points: Vec<_> = (0..4096)
            .map(|i| {
                let cfg = SsdConfig {
                    ways: 1 + (i % 16) as u16,
                    ..SsdConfig::default()
                };
                ddrnand::analytic::DesignPoint::from_config(&cfg)
            })
            .collect();
        let r = bench("PJRT perf batch (4096 design points)", 3, 30, || {
            std::hint::black_box(rt.perf_batch(&points).unwrap());
        });
        println!("{}", r.report());
        println!(
            "  -> {:.2}M design points/s through the AOT artifact",
            4096.0 / r.summary.mean / 1e3
        );
        log.push_bench("pjrt_perf_batch_4096", &r);
    }

    // Emit the machine-readable trajectory.
    let path = std::env::var_os("BENCH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine.json")
        });
    // A log that cannot be written is a broken pipeline, not a warning:
    // CI's trajectory commit-back and regression gate both read this file,
    // and a silent skip here is how the committed baseline stayed the
    // bootstrap placeholder forever.
    if let Err(e) = log.write(&path) {
        panic!("could not write perf log to {}: {e}", path.display());
    }
}

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
