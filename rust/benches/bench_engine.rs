//! §Perf microbenchmarks: DES engine event throughput, event-queue ops,
//! full-SSD simulation events/s, sweep scaling across threads, and the
//! PJRT analytic-batch latency. Numbers recorded in EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench bench_engine`

use ddrnand::bench::{bench, throughput};
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::sim::{Engine, EventQueue, Model, Scheduler};
use ddrnand::util::time::Ps;

/// Ping-pong model: minimal per-event work to measure engine overhead.
struct PingPong {
    left: u64,
}
impl Model for PingPong {
    type Ev = u32;
    fn handle(&mut self, sched: &mut Scheduler<u32>, ev: u32) {
        if self.left > 0 {
            self.left -= 1;
            sched.after(Ps::ns(10), ev ^ 1);
        }
    }
}

fn main() {
    // 1. Raw event-queue ops.
    let r = bench("event queue: 100k push+pop (heap)", 3, 20, || {
        let mut q = EventQueue::new();
        for i in 0..100_000u32 {
            q.push(Ps::ns(((i * 2_654_435_761u32) % 1_000_000) as i64), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", r.report());

    // 2. Engine dispatch overhead.
    println!(
        "{}",
        throughput("DES engine: ping-pong events", || {
            let n = 5_000_000u64;
            let mut m = PingPong { left: n };
            let mut s = Scheduler::new();
            s.at(Ps::ZERO, 0u32);
            let t0 = std::time::Instant::now();
            let res = Engine::run(&mut m, &mut s, Ps::MAX);
            (res.events, t0.elapsed().as_secs_f64())
        })
    );

    // 3. Full-SSD simulation throughput.
    for (iface, ways, label) in [
        (InterfaceKind::Proposed, 16u16, "PROPOSED 16-way SLC write"),
        (InterfaceKind::Conv, 4, "CONV 4-way SLC write"),
    ] {
        println!(
            "{}",
            throughput(&format!("full SSD sim: {label}"), || {
                let cfg = SsdConfig {
                    iface,
                    ways,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                let t0 = std::time::Instant::now();
                let rep = Campaign::new(cfg, RequestKind::Write, 2000).run();
                (rep.events, t0.elapsed().as_secs_f64())
            })
        );
    }

    // 4. Sweep scaling across worker threads.
    let sweep = |threads| {
        let pool = ThreadPool::new(threads);
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || {
                    let cfg = SsdConfig {
                        iface: InterfaceKind::Proposed,
                        ways: 1 + (i % 16) as u16,
                        blocks_per_chip: 512,
                        ..SsdConfig::default()
                    };
                    Campaign::new(cfg, RequestKind::Write, 300).run().events
                }
            })
            .collect();
        let t0 = std::time::Instant::now();
        let ev: u64 = pool.run_all(jobs).iter().sum();
        (ev, t0.elapsed().as_secs_f64())
    };
    for threads in [1usize, 4, 0] {
        let (ev, secs) = sweep(threads);
        println!(
            "sweep scaling: {:>2} threads  16 sims  {:>9} events  {:.2}s",
            if threads == 0 { num_cpus() } else { threads },
            ev,
            secs
        );
    }

    // 5. PJRT analytic batch.
    let dir = ddrnand::runtime::Runtime::default_dir();
    if ddrnand::runtime::Runtime::artifacts_present(&dir) {
        let rt = ddrnand::runtime::Runtime::load(&dir).unwrap();
        let points: Vec<_> = (0..4096)
            .map(|i| {
                let cfg = SsdConfig {
                    ways: 1 + (i % 16) as u16,
                    ..SsdConfig::default()
                };
                ddrnand::analytic::DesignPoint::from_config(&cfg)
            })
            .collect();
        let r = bench("PJRT perf batch (4096 design points)", 3, 30, || {
            std::hint::black_box(rt.perf_batch(&points).unwrap());
        });
        println!("{}", r.report());
        println!(
            "  -> {:.2}M design points/s through the AOT artifact",
            4096.0 / r.summary.mean / 1e3
        );
    }
}

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
