//! §Perf microbenchmarks: event-calendar ops (bucketed calendar vs the
//! BinaryHeap baseline), DES engine dispatch (incl. same-timestamp batch
//! drain), full-SSD simulation events/s, sweep scaling across threads with
//! per-worker simulator reuse, and the PJRT analytic-batch latency.
//!
//! Numbers are printed human-readable AND recorded machine-readable to
//! `BENCH_engine.json` at the repo root (override with `$BENCH_JSON`), so
//! every perf PR leaves a measured trajectory (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench bench_engine`

use ddrnand::bench::{bench, throughput, PerfLog};
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::{Campaign, SimWorkspace};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::sim::{Engine, EventQueue, HeapEventQueue, Model, Scheduler};
use ddrnand::util::time::Ps;

/// Ping-pong model: minimal per-event work to measure engine overhead.
struct PingPong {
    left: u64,
}
impl Model for PingPong {
    type Ev = u32;
    fn handle(&mut self, sched: &mut Scheduler<u32>, ev: u32) {
        if self.left > 0 {
            self.left -= 1;
            sched.after(Ps::ns(10), ev ^ 1);
        }
    }
}

/// Fan-out model: every event at t spawns a batch of events at t + 100ns,
/// exercising the same-timestamp batch drain.
struct FanOut {
    rounds: u32,
    width: u32,
    handled: u64,
}
impl Model for FanOut {
    type Ev = u32;
    fn handle(&mut self, sched: &mut Scheduler<u32>, round: u32) {
        self.handled += 1;
        if round < self.rounds && self.handled % self.width as u64 == 1 {
            for _ in 0..self.width {
                sched.after(Ps::ns(100), round + 1);
            }
        }
    }
}

/// The microbench op sequence, identical for both calendar implementations:
/// `n` pushes with hashed times in [0, 1 ms), then a full drain.
fn hashed_time(i: u32) -> Ps {
    Ps::ns(((i.wrapping_mul(2_654_435_761)) % 1_000_000) as i64)
}

fn main() {
    let mut log = PerfLog::new("bench_engine");

    // 1. Raw event-calendar ops: bucketed calendar vs BinaryHeap baseline.
    const QN: u32 = 100_000;
    let heap = bench("event queue: 100k push+pop (heap baseline)", 3, 20, || {
        let mut q = HeapEventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", heap.report());
    log.push_bench("event_queue_100k/heap", &heap);
    let cal = bench("event queue: 100k push+pop (calendar)", 3, 20, || {
        let mut q = EventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", cal.report());
    log.push_bench("event_queue_100k/calendar", &cal);
    let speedup = heap.summary.median / cal.summary.median;
    println!("  -> calendar speedup vs heap baseline: {speedup:.2}x (target >= 1.3x)");
    log.push("event_queue_100k/speedup_vs_heap", "ratio", speedup, 20);

    // 1b. Tie-heavy variant: 100 events per timestamp (batch shape).
    let heap_ties = bench("event queue: 100k ties x100 (heap baseline)", 3, 20, || {
        let mut q = HeapEventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i / 100), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", heap_ties.report());
    log.push_bench("event_queue_ties/heap", &heap_ties);
    let cal_ties = bench("event queue: 100k ties x100 (calendar)", 3, 20, || {
        let mut q = EventQueue::new();
        for i in 0..QN {
            q.push(hashed_time(i / 100), i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", cal_ties.report());
    log.push_bench("event_queue_ties/calendar", &cal_ties);
    log.push(
        "event_queue_ties/speedup_vs_heap",
        "ratio",
        heap_ties.summary.median / cal_ties.summary.median,
        20,
    );

    // 2. Engine dispatch overhead (sparse queue, alternating events).
    println!(
        "{}",
        throughput("DES engine: ping-pong events", || {
            let n = 5_000_000u64;
            let mut m = PingPong { left: n };
            let mut s = Scheduler::new();
            s.at(Ps::ZERO, 0u32);
            let t0 = std::time::Instant::now();
            let res = Engine::run(&mut m, &mut s, Ps::MAX);
            let secs = t0.elapsed().as_secs_f64();
            log.push("engine_pingpong/events_per_sec", "events_per_sec", res.events as f64 / secs, 1);
            (res.events, secs)
        })
    );

    // 2b. Batch drain: wide same-timestamp fan-outs.
    println!(
        "{}",
        throughput("DES engine: same-timestamp fan-out batches", || {
            let mut m = FanOut {
                rounds: 2_000,
                width: 500,
                handled: 0,
            };
            let mut s = Scheduler::new();
            for _ in 0..500 {
                s.at(Ps::ZERO, 0u32);
            }
            let t0 = std::time::Instant::now();
            let res = Engine::run(&mut m, &mut s, Ps::MAX);
            let secs = t0.elapsed().as_secs_f64();
            log.push("engine_fanout/events_per_sec", "events_per_sec", res.events as f64 / secs, 1);
            (res.events, secs)
        })
    );

    // 3. Full-SSD simulation throughput.
    for (iface, ways, label, key) in [
        (InterfaceKind::Proposed, 16u16, "PROPOSED 16-way SLC write", "full_sim/proposed_16way"),
        (InterfaceKind::Conv, 4, "CONV 4-way SLC write", "full_sim/conv_4way"),
    ] {
        println!(
            "{}",
            throughput(&format!("full SSD sim: {label}"), || {
                let cfg = SsdConfig {
                    iface,
                    ways,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                let t0 = std::time::Instant::now();
                let rep = Campaign::new(cfg, RequestKind::Write, 2000).run();
                let secs = t0.elapsed().as_secs_f64();
                log.push(key, "events_per_sec", rep.events as f64 / secs, 1);
                log.push(key, "wall_ms", rep.wall_ms, 1);
                (rep.events, secs)
            })
        );
    }

    // 4. Sweep scaling across worker threads, with per-worker simulator
    //    reuse (SimWorkspace) — the campaign path the paper sweeps use.
    let sweep = |threads| {
        let pool = ThreadPool::new(threads);
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move |ws: &mut SimWorkspace| {
                    let cfg = SsdConfig {
                        iface: InterfaceKind::Proposed,
                        ways: 1 + (i % 16) as u16,
                        blocks_per_chip: 512,
                        ..SsdConfig::default()
                    };
                    let rep = Campaign::new(cfg, RequestKind::Write, 300).run_in(ws);
                    (rep.events, rep.wall_ms)
                }
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = pool.run_all_with(jobs, SimWorkspace::new);
        let ev: u64 = out.iter().map(|(e, _)| e).sum();
        let mean_wall: f64 = out.iter().map(|(_, w)| w).sum::<f64>() / out.len() as f64;
        (ev, t0.elapsed().as_secs_f64(), mean_wall)
    };
    for threads in [1usize, 4, 0] {
        let (ev, secs, mean_wall) = sweep(threads);
        let shown = if threads == 0 { num_cpus() } else { threads };
        println!(
            "sweep scaling: {shown:>2} threads  16 sims  {ev:>9} events  {secs:.2}s  ({mean_wall:.1} ms/point)"
        );
        log.push(
            &format!("sweep_16sims/{shown}_threads"),
            "wall_sec",
            secs,
            16,
        );
        log.push(
            &format!("sweep_16sims/{shown}_threads_per_point"),
            "wall_ms_mean",
            mean_wall,
            16,
        );
    }

    // 5. PJRT analytic batch (skipped without artifacts or the `pjrt`
    //    feature — see rust/src/runtime/mod.rs).
    let dir = ddrnand::runtime::Runtime::default_dir();
    if ddrnand::runtime::Runtime::artifacts_present(&dir) {
        let rt = ddrnand::runtime::Runtime::load(&dir).unwrap();
        let points: Vec<_> = (0..4096)
            .map(|i| {
                let cfg = SsdConfig {
                    ways: 1 + (i % 16) as u16,
                    ..SsdConfig::default()
                };
                ddrnand::analytic::DesignPoint::from_config(&cfg)
            })
            .collect();
        let r = bench("PJRT perf batch (4096 design points)", 3, 30, || {
            std::hint::black_box(rt.perf_batch(&points).unwrap());
        });
        println!("{}", r.report());
        println!(
            "  -> {:.2}M design points/s through the AOT artifact",
            4096.0 / r.summary.mean / 1e3
        );
        log.push_bench("pjrt_perf_batch_4096", &r);
    }

    // Emit the machine-readable trajectory.
    let path = std::env::var_os("BENCH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_engine.json")
        });
    if let Err(e) = log.write(&path) {
        eprintln!("warning: could not write perf log to {}: {e}", path.display());
    }
}

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
