//! E3 — Fig. 9 / Table 4: constant-capacity channel/way sweep
//! ((1ch,16w), (2ch,8w), (4ch,4w)) × {SLC,MLC} × {write,read} × 3 ifaces.
//! The (4,4) read configs should hit the SATA2 300 MB/s cap ("max").
//!
//! Run: `cargo bench --bench bench_fig9_table4`

use ddrnand::coordinator::experiments::{render_cells, run_table4};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;

fn main() {
    let requests: usize = std::env::var("REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let pool = ThreadPool::new(0);
    let t0 = std::time::Instant::now();
    let cells = run_table4(requests, &pool);
    println!(
        "{}",
        render_cells(
            "E3 / Fig. 9 + Table 4 — channel/way configurations at constant capacity (MB/s)",
            &cells,
            false
        )
    );

    // SATA saturation check: the paper marks (4,4) reads as "max".
    for c in cells.iter().filter(|c| {
        c.channels == 4 && c.mode == RequestKind::Read && c.paper.is_none()
    }) {
        let frac = c.report.bandwidth_mbps / 300.0;
        println!(
            "SATA saturation: {} {} (4ch,4way) read = {:.2} MB/s = {:.1}% of the SATA2 cap",
            c.cell.name(),
            c.iface.name(),
            c.report.bandwidth_mbps,
            frac * 100.0
        );
    }
    println!("\nbench wall-clock: {:.2}s", t0.elapsed().as_secs_f64());
}
