//! E2 — Fig. 8 / Table 3: single-channel way-interleaving sweep across
//! {1,2,4,8,16} ways × {SLC,MLC} × {write,read} × {CONV,SYNC_ONLY,PROPOSED}.
//!
//! Prints the same rows the paper reports, with paper-vs-measured deltas
//! and the P/S, P/C geomean ratio columns.
//!
//! Run: `cargo bench --bench bench_fig8_table3` (env `REQUESTS=n` to scale)

use ddrnand::coordinator::experiments::{headline, render_cells, run_table3};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::util::stats::geomean;

fn main() {
    let requests: usize = std::env::var("REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let pool = ThreadPool::new(0);
    let t0 = std::time::Instant::now();
    let cells = run_table3(requests, &pool);
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "{}",
        render_cells("E2 / Fig. 8 + Table 3 — way-interleaving sweep (MB/s)", &cells, false)
    );

    // The paper's ratio columns (geometric means, per Table 3 footnote).
    println!("ratio columns (geomean across way degrees):");
    for cell in [CellType::Slc, CellType::Mlc] {
        for mode in [RequestKind::Write, RequestKind::Read] {
            let get = |iface| {
                cells
                    .iter()
                    .filter(|c| c.cell == cell && c.mode == mode && c.iface == iface)
                    .map(|c| c.report.bandwidth_mbps)
                    .collect::<Vec<_>>()
            };
            let conv = get(InterfaceKind::Conv);
            let sync = get(InterfaceKind::SyncOnly);
            let prop = get(InterfaceKind::Proposed);
            let ps: Vec<f64> = prop.iter().zip(&sync).map(|(p, s)| p / s).collect();
            let pc: Vec<f64> = prop.iter().zip(&conv).map(|(p, c)| p / c).collect();
            println!(
                "  {cell} {:<5}: P/S={:.2}  P/C={:.2}",
                mode.name(),
                geomean(&ps),
                geomean(&pc)
            );
        }
    }
    println!();
    println!("{}", headline(&cells));
    let events: u64 = cells.iter().map(|c| c.report.events).sum();
    println!(
        "bench wall-clock: {wall:.2}s for {} simulations ({} DES events, {:.1}M events/s aggregate)",
        cells.len(),
        events,
        events as f64 / wall / 1e6
    );
}
