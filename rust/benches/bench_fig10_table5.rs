//! E4 — Fig. 10 / Table 5: controller energy per transferred byte (nJ/B)
//! for SLC designs across way degrees, all three interfaces.
//!
//! The paper's qualitative claim to reproduce: PROPOSED costs *more* energy
//! per byte at low interleaving but becomes the *cheapest* at high degrees
//! (write: by 16-way; read: from 4-way on).
//!
//! Run: `cargo bench --bench bench_fig10_table5`

use ddrnand::coordinator::experiments::{render_cells, run_table5};
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;

fn main() {
    let requests: usize = std::env::var("REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let pool = ThreadPool::new(0);
    let cells = run_table5(requests, &pool);
    println!(
        "{}",
        render_cells(
            "E4 / Fig. 10 + Table 5 — controller energy per byte (nJ/B, SLC)",
            &cells,
            true
        )
    );

    // Crossover verification.
    let e = |iface, ways, mode| {
        cells
            .iter()
            .find(|c| c.iface == iface && c.ways == ways && c.mode == mode)
            .map(|c| c.report.energy_nj_per_byte)
            .unwrap()
    };
    for mode in [RequestKind::Write, RequestKind::Read] {
        let p1 = e(InterfaceKind::Proposed, 1, mode);
        let c1 = e(InterfaceKind::Conv, 1, mode);
        let p16 = e(InterfaceKind::Proposed, 16, mode);
        let c16 = e(InterfaceKind::Conv, 16, mode);
        let s16 = e(InterfaceKind::SyncOnly, 16, mode);
        println!(
            "{:<5}: 1-way PROPOSED {:.2} vs CONV {:.2} nJ/B ({}); 16-way PROPOSED {:.2} vs CONV {:.2} vs SYNC {:.2} ({})",
            mode.name(),
            p1,
            c1,
            if p1 > c1 { "PROPOSED costlier, as in paper" } else { "UNEXPECTED" },
            p16,
            c16,
            s16,
            if p16 < c16 && p16 < s16 { "PROPOSED cheapest, as in paper" } else { "UNEXPECTED" },
        );
    }
}
