//! Offline shim for the `anyhow` crate (API-compatible subset).
//!
//! The build must succeed with no crates.io access, so this workspace
//! vendors the small part of `anyhow` the project uses: [`Error`],
//! [`Result`], the [`Context`] extension trait and the `anyhow!` /
//! `bail!` / `ensure!` macros. Error values carry a context chain so
//! `{:#}` formatting prints `outermost: ...: root cause` like the real
//! crate. Swapping back to crates.io `anyhow` is a one-line change in
//! `rust/Cargo.toml`.

use std::fmt;

/// A string-backed error with an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The outermost message (context chain excluded).
    pub fn root_message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cause = &self.source;
            while let Some(c) = cause {
                write!(f, ": {}", c.msg)?;
                cause = &c.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause = &self.source;
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {}", c.msg)?;
            cause = &c.source;
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        chain.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Box<Error>> = None;
        for msg in chain.into_iter().rev() {
            err = Some(Box::new(Error { msg, source: err }));
        }
        *err.expect("chain is non-empty")
    }
}

/// `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, Error> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("reading file");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), std::io::Error> = Ok(());
        let out = r.with_context(|| -> String { panic!("must not run") });
        assert!(out.is_ok());
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let e = none.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn macros() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        fn fails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope 1");
        fn checks(v: u8) -> Result<u8> {
            ensure!(v < 10, "too big: {v}");
            Ok(v)
        }
        assert!(checks(5).is_ok());
        assert!(checks(50).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }
}
