//! Workload traces.
//!
//! The paper's experiments use "widely used sequential traces that consist
//! of 64-KB read/write data chunks" [30] (MMC system specification access
//! patterns). [`TraceGen`] produces those plus random and mixed workloads
//! for the extended experiments; [`Trace`] round-trips through a simple
//! text format so external traces can be replayed.
//!
//! ## Open-loop traces (v2)
//!
//! A trace may additionally carry one **arrival timestamp per request**
//! (`Trace::arrivals`). Such a trace is *open loop*: the host submits
//! request `i` at `arrivals[i]` regardless of how the device is keeping
//! up, which is the sustained-load regime the E6 sweep (`ddrnand
//! sweep-load`, DESIGN.md) measures latency under. An empty arrival track
//! is the classic *closed loop*: the device is refilled to its queue
//! depth as requests complete.
//!
//! The text format grows a fourth column for this (v1 files still parse):
//!
//! ```text
//! # v1 (closed loop):  <R|W> <offset-bytes> <length-bytes>
//! # v2 (open loop):    <R|W> <offset-bytes> <length-bytes> <arrival-ps>
//! ```
//!
//! Arrivals are integer picoseconds from the start of the run and must be
//! non-decreasing; mixing v1 and v2 rows in one file is rejected.
//!
//! ## Multi-stream traces (v3)
//!
//! A trace may carry one **stream tag per request** (`Trace::streams`):
//! a submission-queue / tenant id plus a priority class. Tagged traces
//! drive the multi-tenant host path (`[host]`/`[qos]` in the config,
//! `ddrnand sweep-qos`, DESIGN.md §7). The text format appends the two
//! columns after v1 or v2 rows (v1/v2 files still parse):
//!
//! ```text
//! # v3 (closed loop):  <R|W> <offset-bytes> <length-bytes> <stream> <class>
//! # v3 (open loop):    <R|W> <offset-bytes> <length-bytes> <arrival-ps> <stream> <class>
//! ```
//!
//! Host classes are 0 (latency-critical) ≤ class ≤ 2 (bulk); class 3 is
//! reserved for the device's internal background traffic (GC, wear
//! leveling, migration) and rejected in trace files. All rows of one file
//! must carry the same column shape.

use crate::util::prng::Prng;
use crate::util::time::Ps;

/// Highest-priority host class: latency-critical traffic.
pub const CLASS_URGENT: u8 = 0;
/// Default host class.
pub const CLASS_NORMAL: u8 = 1;
/// Lowest host class: bulk / best-effort traffic.
pub const CLASS_BULK: u8 = 2;
/// Internal background traffic (GC / wear-leveling / migration copy-back);
/// never valid in a host trace.
pub const CLASS_BACKGROUND: u8 = 3;
/// Number of scheduling classes (host classes plus background).
pub const NUM_CLASSES: usize = 4;

/// Stream tag of one request: which submission queue / tenant it belongs
/// to and its priority class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct StreamTag {
    pub stream: u16,
    pub class: u8,
}

/// Read or write.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestKind {
    Read,
    Write,
}

impl RequestKind {
    pub fn name(self) -> &'static str {
        match self {
            RequestKind::Read => "read",
            RequestKind::Write => "write",
        }
    }
}

/// One host request: a contiguous byte extent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub kind: RequestKind,
    /// Byte offset into the logical volume.
    pub offset: u64,
    /// Length in bytes.
    pub bytes: u32,
}

/// An ordered workload.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
    /// Open-loop arrival timestamps, one per request, non-decreasing.
    /// Empty = closed loop (see the module docs).
    pub arrivals: Vec<Ps>,
    /// Stream tags, one per request. Empty = single-stream (everything is
    /// stream 0 at the default class; see the module docs).
    pub streams: Vec<StreamTag>,
}

impl Trace {
    /// A closed-loop trace over `requests` (no arrival track).
    pub fn from_requests(requests: Vec<Request>) -> Trace {
        Trace {
            requests,
            arrivals: Vec::new(),
            streams: Vec::new(),
        }
    }

    /// Does this trace carry per-request stream tags?
    pub fn is_multi_stream(&self) -> bool {
        !self.streams.is_empty()
    }

    /// Number of streams: max tagged stream id + 1 (1 for untagged traces,
    /// 0 for empty ones).
    pub fn stream_count(&self) -> usize {
        if self.streams.is_empty() {
            usize::from(!self.requests.is_empty())
        } else {
            self.streams.iter().map(|t| t.stream as usize).max().unwrap_or(0) + 1
        }
    }

    /// Merge per-stream traces into one multi-stream trace; part `i`
    /// becomes stream `i` with priority class `parts[i].1`. Either every
    /// part is open loop — the merge is ordered by arrival, ties broken by
    /// stream id, so the result's arrival track is non-decreasing — or
    /// every part is closed loop, in which case the streams are
    /// interleaved round robin one request at a time. Mixing the two is an
    /// error, as are classes outside the host range.
    pub fn merge_streams(parts: &[(Trace, u8)]) -> Result<Trace, String> {
        if parts.is_empty() {
            return Ok(Trace::default());
        }
        if parts.len() > u16::MAX as usize {
            return Err("too many streams".into());
        }
        for (i, (t, class)) in parts.iter().enumerate() {
            if *class > CLASS_BULK {
                return Err(format!(
                    "stream {i}: class {class} outside the host range 0..={CLASS_BULK}"
                ));
            }
            if t.is_open_loop() != parts[0].0.is_open_loop() {
                return Err(format!(
                    "stream {i}: open-loop and closed-loop parts cannot merge"
                ));
            }
            if t.is_open_loop() && t.arrivals.len() != t.requests.len() {
                return Err(format!("stream {i}: arrival track length mismatch"));
            }
        }
        let open = parts[0].0.is_open_loop();
        let total: usize = parts.iter().map(|(t, _)| t.requests.len()).sum();
        let mut out = Trace {
            requests: Vec::with_capacity(total),
            arrivals: Vec::with_capacity(if open { total } else { 0 }),
            streams: Vec::with_capacity(total),
        };
        let mut cursor = vec![0usize; parts.len()];
        while out.requests.len() < total {
            let next = if open {
                // Earliest next arrival; ties go to the lowest stream id.
                (0..parts.len())
                    .filter(|&i| cursor[i] < parts[i].0.requests.len())
                    .min_by_key(|&i| parts[i].0.arrivals[cursor[i]])
                    .expect("unmerged requests remain")
            } else {
                // Round robin: one request per non-exhausted stream in turn.
                let round = out.requests.len() % parts.len();
                (0..parts.len())
                    .map(|o| (round + o) % parts.len())
                    .find(|&i| cursor[i] < parts[i].0.requests.len())
                    .expect("unmerged requests remain")
            };
            let (t, class) = &parts[next];
            out.requests.push(t.requests[cursor[next]]);
            if open {
                out.arrivals.push(t.arrivals[cursor[next]]);
            }
            out.streams.push(StreamTag {
                stream: next as u16,
                class: *class,
            });
            cursor[next] += 1;
        }
        debug_assert!(out.arrivals.windows(2).all(|w| w[0] <= w[1]));
        Ok(out)
    }

    pub fn total_bytes(&self) -> u64 {
        self.requests.iter().map(|r| r.bytes as u64).sum()
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Does this trace drive the device open loop (arrival timestamps)?
    pub fn is_open_loop(&self) -> bool {
        !self.arrivals.is_empty()
    }

    /// Mean offered load implied by the arrival track, in MB/s (decimal,
    /// like the paper's tables), measured over the arrival span. `None`
    /// for closed-loop traces and degenerate (single-instant) spans.
    pub fn offered_mbps(&self) -> Option<f64> {
        let first = *self.arrivals.first()?;
        let last = *self.arrivals.last()?;
        let span = last - first;
        if span <= Ps::ZERO {
            return None;
        }
        Some(self.total_bytes() as f64 / span.as_secs_f64() / 1e6)
    }

    /// Serialize to the text trace format: `R|W <offset> <bytes>` per line
    /// (v1), with an `<arrival-ps>` column when the trace carries an
    /// arrival track (v2) and trailing `<stream> <class>` columns when it
    /// carries stream tags (v3). '#' comments allowed.
    pub fn to_text(&self) -> String {
        let open = self.is_open_loop();
        let tagged = self.is_multi_stream();
        assert!(
            !open || self.arrivals.len() == self.requests.len(),
            "arrival track length mismatch: {} arrivals for {} requests",
            self.arrivals.len(),
            self.requests.len()
        );
        assert!(
            !tagged || self.streams.len() == self.requests.len(),
            "stream track length mismatch: {} tags for {} requests",
            self.streams.len(),
            self.requests.len()
        );
        let mut s = String::with_capacity(self.requests.len() * 24);
        let header = match (open, tagged) {
            (false, false) => "# ddrnand trace v1: <R|W> <offset-bytes> <length-bytes>\n",
            (true, false) => {
                "# ddrnand trace v2: <R|W> <offset-bytes> <length-bytes> <arrival-ps>\n"
            }
            (false, true) => {
                "# ddrnand trace v3: <R|W> <offset-bytes> <length-bytes> <stream> <class>\n"
            }
            (true, true) => {
                "# ddrnand trace v3: <R|W> <offset-bytes> <length-bytes> <arrival-ps> \
                 <stream> <class>\n"
            }
        };
        s.push_str(header);
        for (i, r) in self.requests.iter().enumerate() {
            let k = match r.kind {
                RequestKind::Read => 'R',
                RequestKind::Write => 'W',
            };
            s.push_str(&format!("{k} {} {}", r.offset, r.bytes));
            if open {
                s.push_str(&format!(" {}", self.arrivals[i].as_ps()));
            }
            if tagged {
                s.push_str(&format!(" {} {}", self.streams[i].stream, self.streams[i].class));
            }
            s.push('\n');
        }
        s
    }

    /// Parse the text trace format (v1, v2 or v3; see the module docs).
    /// The number of columns after `<length-bytes>` selects the shape —
    /// 0: v1, 1: v2 arrival, 2: v3 stream+class, 3: v3 arrival+stream+
    /// class — and every row of a file must share one shape.
    pub fn from_text(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        let mut arrivals: Vec<Ps> = Vec::new();
        let mut streams: Vec<StreamTag> = Vec::new();
        let mut shape: Option<usize> = None;
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let kind = match it.next() {
                Some("R") | Some("r") => RequestKind::Read,
                Some("W") | Some("w") => RequestKind::Write,
                other => return Err(format!("line {}: bad kind {other:?}", i + 1)),
            };
            let offset: u64 = it
                .next()
                .ok_or_else(|| format!("line {}: missing offset", i + 1))?
                .parse()
                .map_err(|e| format!("line {}: bad offset: {e}", i + 1))?;
            let bytes: u32 = it
                .next()
                .ok_or_else(|| format!("line {}: missing length", i + 1))?
                .parse()
                .map_err(|e| format!("line {}: bad length: {e}", i + 1))?;
            if bytes == 0 {
                return Err(format!("line {}: zero-length request", i + 1));
            }
            let extras: Vec<&str> = it.collect();
            if extras.len() > 3 {
                return Err(format!("line {}: too many fields", i + 1));
            }
            match shape {
                None => shape = Some(extras.len()),
                Some(s) if s != extras.len() => {
                    return Err(format!(
                        "line {}: {} extra column(s) after {} on earlier rows \
                         (all rows must share one shape)",
                        i + 1,
                        extras.len(),
                        s
                    ));
                }
                Some(_) => {}
            }
            // Shapes 1 and 3 lead with an arrival; 2 and 3 end with
            // <stream> <class>.
            if extras.len() % 2 == 1 {
                let ps: i64 = extras[0]
                    .parse()
                    .map_err(|e| format!("line {}: bad arrival: {e}", i + 1))?;
                if ps < 0 {
                    return Err(format!("line {}: negative arrival {ps}", i + 1));
                }
                let at = Ps::ps(ps);
                if let Some(&prev) = arrivals.last() {
                    if at < prev {
                        return Err(format!(
                            "line {}: arrival moves backwards ({at} < {prev})",
                            i + 1
                        ));
                    }
                }
                arrivals.push(at);
            }
            if extras.len() >= 2 {
                let stream: u16 = extras[extras.len() - 2]
                    .parse()
                    .map_err(|e| format!("line {}: bad stream: {e}", i + 1))?;
                let class: u8 = extras[extras.len() - 1]
                    .parse()
                    .map_err(|e| format!("line {}: bad class: {e}", i + 1))?;
                if class > CLASS_BULK {
                    return Err(format!(
                        "line {}: class {class} outside the host range 0..={CLASS_BULK} \
                         ({CLASS_BACKGROUND} is reserved for background traffic)",
                        i + 1
                    ));
                }
                streams.push(StreamTag { stream, class });
            }
            requests.push(Request {
                kind,
                offset,
                bytes,
            });
        }
        Ok(Trace {
            requests,
            arrivals,
            streams,
        })
    }
}

/// Workload generators.
#[derive(Debug, Clone)]
pub struct TraceGen {
    /// Request size in bytes (64 KiB in the paper).
    pub request_bytes: u32,
}

impl Default for TraceGen {
    fn default() -> Self {
        TraceGen {
            request_bytes: 64 * 1024,
        }
    }
}

impl TraceGen {
    /// The paper's workload: `n` back-to-back sequential requests of one
    /// kind, starting at offset 0.
    pub fn sequential(&self, kind: RequestKind, n: usize) -> Trace {
        let requests = (0..n)
            .map(|i| Request {
                kind,
                offset: i as u64 * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace::from_requests(requests)
    }

    /// Uniform-random offsets within `volume_bytes`, aligned to the request
    /// size.
    pub fn random(
        &self,
        kind: RequestKind,
        n: usize,
        volume_bytes: u64,
        seed: u64,
    ) -> Trace {
        let mut rng = Prng::new(seed);
        let slots = (volume_bytes / self.request_bytes as u64).max(1);
        let requests = (0..n)
            .map(|_| Request {
                kind,
                offset: rng.next_bounded(slots) * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace::from_requests(requests)
    }

    /// Hotspot locality: with probability `hot_prob` a request targets the
    /// first `hot_fraction` of the volume, otherwise the remainder; offsets
    /// are uniform within the chosen region and aligned to the request
    /// size. `hot_prob = hot_fraction` degenerates to [`TraceGen::random`]'s
    /// distribution (uniform over the whole volume). This is the knob the
    /// mapping-tier sweep (E11) turns: a small hot set keeps the same few
    /// translation pages resident while the cold tail forces cache misses.
    pub fn hotspot(
        &self,
        kind: RequestKind,
        n: usize,
        volume_bytes: u64,
        hot_fraction: f64,
        hot_prob: f64,
        seed: u64,
    ) -> Trace {
        assert!(
            (0.0..=1.0).contains(&hot_fraction) && (0.0..=1.0).contains(&hot_prob),
            "hot fraction and probability must be within [0, 1]"
        );
        let mut rng = Prng::new(seed);
        let slots = (volume_bytes / self.request_bytes as u64).max(1);
        // At least one slot on each side so both branches stay non-empty
        // (a single-slot volume has no cold region at all).
        let hot_slots = ((slots as f64 * hot_fraction) as u64).clamp(1, slots.max(2) - 1);
        let cold_slots = slots.saturating_sub(hot_slots);
        let requests = (0..n)
            .map(|_| {
                let slot = if cold_slots == 0 || rng.next_bool(hot_prob) {
                    rng.next_bounded(hot_slots)
                } else {
                    hot_slots + rng.next_bounded(cold_slots)
                };
                Request {
                    kind,
                    offset: slot * self.request_bytes as u64,
                    bytes: self.request_bytes,
                }
            })
            .collect();
        Trace::from_requests(requests)
    }

    /// Mixed read/write sequential stream with the given write fraction.
    pub fn mixed_sequential(&self, n: usize, write_fraction: f64, seed: u64) -> Trace {
        let mut rng = Prng::new(seed);
        let requests = (0..n)
            .map(|i| Request {
                kind: if rng.next_bool(write_fraction) {
                    RequestKind::Write
                } else {
                    RequestKind::Read
                },
                offset: i as u64 * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace::from_requests(requests)
    }

    /// Stamp Poisson-process arrivals onto `trace` so its mean offered
    /// load is `offered_mbps` (decimal MB/s). The first request arrives at
    /// t = 0; each following gap is exponential with a per-request mean
    /// proportional to that request's size, so mixed-size traces still hit
    /// the target byte rate. The result is an open-loop trace.
    pub fn poisson_arrivals(&self, trace: Trace, offered_mbps: f64, seed: u64) -> Trace {
        // A Poisson stream is the degenerate burst of one; keeping a single
        // stamping loop means the two arrival kinds can never diverge.
        self.bursty_arrivals(trace, offered_mbps, 1, seed)
    }

    /// Stamp bursty arrivals: requests arrive in back-to-back groups of
    /// `burst` sharing one instant, and the group starts form a Poisson
    /// process at the same long-run byte rate `offered_mbps`. This is the
    /// aggregated-submission host pattern (deep instantaneous queues at an
    /// unchanged mean load), the stress case for way interleaving.
    pub fn bursty_arrivals(
        &self,
        mut trace: Trace,
        offered_mbps: f64,
        burst: usize,
        seed: u64,
    ) -> Trace {
        assert!(offered_mbps > 0.0, "offered load must be positive");
        assert!(burst >= 1, "burst must be >= 1");
        let mut rng = Prng::new(seed);
        let mut at = Ps::ZERO;
        trace.arrivals.clear();
        trace.arrivals.reserve(trace.requests.len());
        for chunk in trace.requests.chunks(burst) {
            for _ in chunk {
                trace.arrivals.push(at);
            }
            let bytes: u64 = chunk.iter().map(|r| r.bytes as u64).sum();
            let mean_gap_ps = bytes as f64 / (offered_mbps * 1e6) * 1e12;
            at += Ps::ps((mean_gap_ps * rng.next_exponential()).round() as i64);
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_contiguous() {
        let t = TraceGen::default().sequential(RequestKind::Write, 4);
        assert_eq!(t.len(), 4);
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.offset, i as u64 * 65536);
            assert_eq!(r.bytes, 65536);
            assert_eq!(r.kind, RequestKind::Write);
        }
        assert_eq!(t.total_bytes(), 4 * 65536);
        assert!(!t.is_open_loop());
    }

    #[test]
    fn hotspot_skews_toward_hot_region() {
        let gen = TraceGen::default();
        let volume = 1024 * 65536u64; // 1024 slots
        let t = gen.hotspot(RequestKind::Write, 2000, volume, 0.1, 0.9, 42);
        assert_eq!(t.len(), 2000);
        let hot_bytes = 102 * 65536u64; // floor(1024 * 0.1) slots
        let hot = t.requests.iter().filter(|r| r.offset < hot_bytes).count();
        // ~90% should land in the first 10% of the volume.
        assert!(hot > 1700, "only {hot}/2000 requests hit the hot region");
        assert!(t.requests.iter().all(|r| r.offset < volume));
        // Deterministic for a fixed seed.
        let u = gen.hotspot(RequestKind::Write, 2000, volume, 0.1, 0.9, 42);
        assert_eq!(t.requests, u.requests);
    }

    #[test]
    fn hotspot_handles_tiny_volumes() {
        let gen = TraceGen::default();
        // Single-slot volume: everything is "hot"; must not panic.
        let t = gen.hotspot(RequestKind::Read, 16, 65536, 0.5, 0.5, 1);
        assert!(t.requests.iter().all(|r| r.offset == 0));
    }

    #[test]
    fn text_roundtrip() {
        let t = TraceGen::default().mixed_sequential(32, 0.5, 1);
        let text = t.to_text();
        assert!(text.starts_with("# ddrnand trace v1"));
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(t.requests, back.requests);
        assert!(back.arrivals.is_empty());
    }

    #[test]
    fn v2_text_roundtrip() {
        let gen = TraceGen::default();
        let t = gen.poisson_arrivals(gen.mixed_sequential(32, 0.5, 1), 40.0, 9);
        let text = t.to_text();
        assert!(text.starts_with("# ddrnand trace v2"));
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(t.requests, back.requests);
        assert_eq!(t.arrivals, back.arrivals);
        assert!(back.is_open_loop());
    }

    #[test]
    fn v2_parses_explicit_arrivals() {
        let t = Trace::from_text("R 0 2048 0\nW 2048 2048 1000000\n").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.arrivals, vec![Ps::ZERO, Ps::ps(1_000_000)]);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::from_text("X 0 4096").is_err());
        assert!(Trace::from_text("R zero 4096").is_err());
        assert!(Trace::from_text("R 0").is_err());
        assert!(Trace::from_text("R 0 0").is_err());
    }

    #[test]
    fn parse_rejects_bad_arrivals() {
        // Non-numeric, negative, and backwards-moving arrivals.
        assert!(Trace::from_text("R 0 2048 soon").is_err());
        assert!(Trace::from_text("R 0 2048 -5").is_err());
        assert!(Trace::from_text("R 0 2048 1000\nW 2048 2048 999").is_err());
        // Mixed v1/v2 rows, both orders.
        assert!(Trace::from_text("R 0 2048 0\nW 2048 2048").is_err());
        assert!(Trace::from_text("R 0 2048\nW 2048 2048 10").is_err());
        // Trailing junk beyond the arrival column.
        assert!(Trace::from_text("R 0 2048 5 9").is_err());
    }

    #[test]
    fn v3_closed_text_roundtrip() {
        let mut t = TraceGen::default().mixed_sequential(8, 0.5, 3);
        t.streams = (0..8)
            .map(|i| StreamTag {
                stream: i % 2,
                class: if i % 2 == 0 { CLASS_URGENT } else { CLASS_BULK },
            })
            .collect();
        let text = t.to_text();
        assert!(text.starts_with("# ddrnand trace v3"));
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(t.requests, back.requests);
        assert_eq!(t.streams, back.streams);
        assert!(back.arrivals.is_empty());
        assert!(back.is_multi_stream());
        assert_eq!(back.stream_count(), 2);
    }

    #[test]
    fn v3_open_text_roundtrip() {
        let gen = TraceGen::default();
        let mut t = gen.poisson_arrivals(gen.sequential(RequestKind::Read, 6), 40.0, 7);
        t.streams = vec![
            StreamTag {
                stream: 1,
                class: CLASS_NORMAL
            };
            6
        ];
        let text = t.to_text();
        assert!(text.starts_with("# ddrnand trace v3"));
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(t.requests, back.requests);
        assert_eq!(t.arrivals, back.arrivals);
        assert_eq!(t.streams, back.streams);
        assert_eq!(back.stream_count(), 2, "stream ids need not be dense");
    }

    #[test]
    fn v3_parse_rejects_bad_rows() {
        // Background class is reserved, stream must be numeric.
        assert!(Trace::from_text("R 0 2048 0 3").is_err());
        assert!(Trace::from_text("R 0 2048 tenant 1").is_err());
        // Shapes must agree across rows (v1 then v3, v3 then v2).
        assert!(Trace::from_text("R 0 2048\nW 2048 2048 0 1").is_err());
        assert!(Trace::from_text("R 0 2048 0 1\nW 2048 2048 50").is_err());
        // Open v3 still validates the arrival column.
        assert!(Trace::from_text("R 0 2048 1000 0 1\nW 2048 2048 999 0 1").is_err());
        // More than three extra columns.
        assert!(Trace::from_text("R 0 2048 5 0 1 9").is_err());
    }

    #[test]
    fn merge_streams_open_orders_by_arrival() {
        let gen = TraceGen::default();
        let a = gen.poisson_arrivals(gen.sequential(RequestKind::Read, 20), 30.0, 1);
        let b = gen.poisson_arrivals(gen.sequential(RequestKind::Write, 20), 60.0, 2);
        let m = Trace::merge_streams(&[(a.clone(), CLASS_URGENT), (b.clone(), CLASS_BULK)])
            .unwrap();
        assert_eq!(m.len(), 40);
        assert_eq!(m.streams.len(), 40);
        assert!(m.arrivals.windows(2).all(|w| w[0] <= w[1]));
        // Each stream's own sub-sequence is preserved in order.
        let of = |s: u16| -> Vec<Request> {
            m.requests
                .iter()
                .zip(&m.streams)
                .filter(|(_, t)| t.stream == s)
                .map(|(r, _)| *r)
                .collect()
        };
        assert_eq!(of(0), a.requests);
        assert_eq!(of(1), b.requests);
        assert_eq!(m.streams.iter().filter(|t| t.class == CLASS_URGENT).count(), 20);
    }

    #[test]
    fn merge_streams_closed_round_robins_and_rejects_mixed() {
        let gen = TraceGen::default();
        let a = gen.sequential(RequestKind::Read, 2);
        let b = gen.sequential(RequestKind::Write, 4);
        let m =
            Trace::merge_streams(&[(a.clone(), CLASS_NORMAL), (b.clone(), CLASS_NORMAL)]).unwrap();
        assert_eq!(m.len(), 6);
        assert!(m.arrivals.is_empty());
        let order: Vec<u16> = m.streams.iter().map(|t| t.stream).collect();
        assert_eq!(order, vec![0, 1, 0, 1, 1, 1], "round robin, then drain");
        // Mixed open/closed parts and background classes are rejected.
        let open = gen.poisson_arrivals(gen.sequential(RequestKind::Read, 2), 10.0, 1);
        assert!(Trace::merge_streams(&[(a.clone(), 0), (open, 0)]).is_err());
        assert!(Trace::merge_streams(&[(a, CLASS_BACKGROUND)]).is_err());
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let t = Trace::from_text("# hi\n\nR 0 2048\n  \nW 2048 2048\n").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.requests[1].kind, RequestKind::Write);
    }

    #[test]
    fn random_is_aligned_and_bounded() {
        let t = TraceGen::default().random(RequestKind::Read, 100, 1 << 30, 7);
        for r in &t.requests {
            assert_eq!(r.offset % 65536, 0);
            assert!(r.offset + r.bytes as u64 <= 1 << 30);
        }
    }

    #[test]
    fn mixed_fraction_roughly_holds() {
        let t = TraceGen::default().mixed_sequential(2000, 0.3, 9);
        let writes = t
            .requests
            .iter()
            .filter(|r| r.kind == RequestKind::Write)
            .count();
        let frac = writes as f64 / 2000.0;
        assert!((frac - 0.3).abs() < 0.05, "frac={frac}");
    }

    #[test]
    fn poisson_arrivals_hit_offered_load() {
        let gen = TraceGen::default();
        let t = gen.poisson_arrivals(gen.sequential(RequestKind::Write, 2000), 50.0, 3);
        assert_eq!(t.arrivals.len(), 2000);
        assert_eq!(t.arrivals[0], Ps::ZERO);
        assert!(t.arrivals.windows(2).all(|w| w[0] <= w[1]));
        let offered = t.offered_mbps().unwrap();
        assert!((offered - 50.0).abs() / 50.0 < 0.1, "offered={offered}");
    }

    #[test]
    fn bursty_arrivals_group_and_hit_offered_load() {
        let gen = TraceGen::default();
        let t = gen.bursty_arrivals(gen.sequential(RequestKind::Read, 2000), 80.0, 4, 5);
        assert_eq!(t.arrivals.len(), 2000);
        assert!(t.arrivals.windows(2).all(|w| w[0] <= w[1]));
        // Within each burst of 4, all arrivals share one instant.
        for g in t.arrivals.chunks(4) {
            assert!(g.iter().all(|&a| a == g[0]));
        }
        let offered = t.offered_mbps().unwrap();
        assert!((offered - 80.0).abs() / 80.0 < 0.1, "offered={offered}");
    }

    #[test]
    fn offered_mbps_none_for_closed_loop_and_degenerate() {
        let gen = TraceGen::default();
        assert!(gen.sequential(RequestKind::Read, 8).offered_mbps().is_none());
        let mut t = gen.sequential(RequestKind::Read, 2);
        t.arrivals = vec![Ps::ZERO, Ps::ZERO];
        assert!(t.offered_mbps().is_none());
    }
}
