//! Workload traces.
//!
//! The paper's experiments use "widely used sequential traces that consist
//! of 64-KB read/write data chunks" [30] (MMC system specification access
//! patterns). [`TraceGen`] produces those plus random and mixed workloads
//! for the extended experiments; [`Trace`] round-trips through a simple
//! text format so external traces can be replayed.

use crate::util::prng::Prng;

/// Read or write.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RequestKind {
    Read,
    Write,
}

impl RequestKind {
    pub fn name(self) -> &'static str {
        match self {
            RequestKind::Read => "read",
            RequestKind::Write => "write",
        }
    }
}

/// One host request: a contiguous byte extent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub kind: RequestKind,
    /// Byte offset into the logical volume.
    pub offset: u64,
    /// Length in bytes.
    pub bytes: u32,
}

/// An ordered workload.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn total_bytes(&self) -> u64 {
        self.requests.iter().map(|r| r.bytes as u64).sum()
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Serialize to the text trace format: `R|W <offset> <bytes>` per line,
    /// '#' comments allowed.
    pub fn to_text(&self) -> String {
        let mut s = String::with_capacity(self.requests.len() * 16);
        s.push_str("# ddrnand trace v1: <R|W> <offset-bytes> <length-bytes>\n");
        for r in &self.requests {
            let k = match r.kind {
                RequestKind::Read => 'R',
                RequestKind::Write => 'W',
            };
            s.push_str(&format!("{k} {} {}\n", r.offset, r.bytes));
        }
        s
    }

    /// Parse the text trace format.
    pub fn from_text(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let kind = match it.next() {
                Some("R") | Some("r") => RequestKind::Read,
                Some("W") | Some("w") => RequestKind::Write,
                other => return Err(format!("line {}: bad kind {other:?}", i + 1)),
            };
            let offset: u64 = it
                .next()
                .ok_or_else(|| format!("line {}: missing offset", i + 1))?
                .parse()
                .map_err(|e| format!("line {}: bad offset: {e}", i + 1))?;
            let bytes: u32 = it
                .next()
                .ok_or_else(|| format!("line {}: missing length", i + 1))?
                .parse()
                .map_err(|e| format!("line {}: bad length: {e}", i + 1))?;
            if bytes == 0 {
                return Err(format!("line {}: zero-length request", i + 1));
            }
            requests.push(Request {
                kind,
                offset,
                bytes,
            });
        }
        Ok(Trace { requests })
    }
}

/// Workload generators.
#[derive(Debug, Clone)]
pub struct TraceGen {
    /// Request size in bytes (64 KiB in the paper).
    pub request_bytes: u32,
}

impl Default for TraceGen {
    fn default() -> Self {
        TraceGen {
            request_bytes: 64 * 1024,
        }
    }
}

impl TraceGen {
    /// The paper's workload: `n` back-to-back sequential requests of one
    /// kind, starting at offset 0.
    pub fn sequential(&self, kind: RequestKind, n: usize) -> Trace {
        let requests = (0..n)
            .map(|i| Request {
                kind,
                offset: i as u64 * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace { requests }
    }

    /// Uniform-random offsets within `volume_bytes`, aligned to the request
    /// size.
    pub fn random(
        &self,
        kind: RequestKind,
        n: usize,
        volume_bytes: u64,
        seed: u64,
    ) -> Trace {
        let mut rng = Prng::new(seed);
        let slots = (volume_bytes / self.request_bytes as u64).max(1);
        let requests = (0..n)
            .map(|_| Request {
                kind,
                offset: rng.next_bounded(slots) * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace { requests }
    }

    /// Mixed read/write sequential stream with the given write fraction.
    pub fn mixed_sequential(&self, n: usize, write_fraction: f64, seed: u64) -> Trace {
        let mut rng = Prng::new(seed);
        let requests = (0..n)
            .map(|i| Request {
                kind: if rng.next_bool(write_fraction) {
                    RequestKind::Write
                } else {
                    RequestKind::Read
                },
                offset: i as u64 * self.request_bytes as u64,
                bytes: self.request_bytes,
            })
            .collect();
        Trace { requests }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_is_contiguous() {
        let t = TraceGen::default().sequential(RequestKind::Write, 4);
        assert_eq!(t.len(), 4);
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.offset, i as u64 * 65536);
            assert_eq!(r.bytes, 65536);
            assert_eq!(r.kind, RequestKind::Write);
        }
        assert_eq!(t.total_bytes(), 4 * 65536);
    }

    #[test]
    fn text_roundtrip() {
        let t = TraceGen::default().mixed_sequential(32, 0.5, 1);
        let text = t.to_text();
        let back = Trace::from_text(&text).unwrap();
        assert_eq!(t.requests, back.requests);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::from_text("X 0 4096").is_err());
        assert!(Trace::from_text("R zero 4096").is_err());
        assert!(Trace::from_text("R 0").is_err());
        assert!(Trace::from_text("R 0 0").is_err());
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let t = Trace::from_text("# hi\n\nR 0 2048\n  \nW 2048 2048\n").unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t.requests[1].kind, RequestKind::Write);
    }

    #[test]
    fn random_is_aligned_and_bounded() {
        let t = TraceGen::default().random(RequestKind::Read, 100, 1 << 30, 7);
        for r in &t.requests {
            assert_eq!(r.offset % 65536, 0);
            assert!(r.offset + r.bytes as u64 <= 1 << 30);
        }
    }

    #[test]
    fn mixed_fraction_roughly_holds() {
        let t = TraceGen::default().mixed_sequential(2000, 0.3, 9);
        let writes = t
            .requests
            .iter()
            .filter(|r| r.kind == RequestKind::Write)
            .count();
        let frac = writes as f64 / 2000.0;
        assert!((frac - 0.3).abs() < 0.05, "frac={frac}");
    }
}
