//! Host-link abstraction: the pluggable transport between host and SSD.
//!
//! The paper attaches the device over a single SATA2 stream; the
//! production-scale scenarios (multiple tenants with different priorities)
//! need an NVMe-style multi-queue front end instead. [`HostLink`] is the
//! seam: [`SataLink`](crate::host::sata::SataLink) is the bit-identical
//! default, [`MultiQueueLink`] adds N submission queues whose transfers
//! still serialize on one bandwidth-capped transport (the PCIe-lane
//! analogue) but are tracked per queue.
//!
//! Submission-side arbitration — which queue's head request the device
//! fetches next, under a per-queue depth — lives in [`SubmissionQueues`],
//! consumed by the closed-loop admission path of
//! [`crate::coordinator::ssd::SsdSim`]. Open-loop (arrival-driven) runs
//! bypass queue depths by design: the unbounded-queue overload regime is
//! exactly what the load sweeps measure.

use crate::host::sata::{SataGen, SataLink};
use crate::host::trace::{CLASS_NORMAL, NUM_CLASSES, StreamTag};
use crate::util::time::Ps;
use std::collections::VecDeque;

/// Which host-link model a config selects (`host.link` in TOML).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HostLinkKind {
    /// Single-stream SATA (the paper's interface; the default).
    Sata,
    /// NVMe-style multi-queue front end over the same serialized transport.
    MultiQueue,
}

impl HostLinkKind {
    pub fn name(self) -> &'static str {
        match self {
            HostLinkKind::Sata => "sata",
            HostLinkKind::MultiQueue => "multi_queue",
        }
    }

    pub fn parse(s: &str) -> Option<HostLinkKind> {
        match s {
            "sata" => Some(HostLinkKind::Sata),
            "multi_queue" => Some(HostLinkKind::MultiQueue),
            _ => None,
        }
    }
}

/// Submission-queue arbitration policy (`host.arbitration` in TOML).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueueArb {
    /// One request per non-empty eligible queue in turn.
    RoundRobin,
    /// Weighted round robin: each queue's share follows the per-class
    /// weight of its stream's priority class.
    Weighted,
}

impl QueueArb {
    pub fn name(self) -> &'static str {
        match self {
            QueueArb::RoundRobin => "round_robin",
            QueueArb::Weighted => "weighted",
        }
    }

    pub fn parse(s: &str) -> Option<QueueArb> {
        match s {
            "round_robin" => Some(QueueArb::RoundRobin),
            "weighted" => Some(QueueArb::Weighted),
            _ => None,
        }
    }
}

/// The host link as a DES resource. Implementations serialize transfers on
/// a shared bandwidth-capped transport; the `queue` argument attributes
/// the transfer to a submission queue (ignored by single-stream links).
pub trait HostLink {
    /// Reserve the transport starting no earlier than `now` for a payload
    /// of `bytes` from submission queue `queue` (plus command overhead if
    /// `with_cmd`); returns (start, done).
    fn reserve(&mut self, now: Ps, queue: u16, bytes: u64, with_cmd: bool) -> (Ps, Ps);

    /// Achieved utilization of the transport over a window.
    fn utilization(&self, elapsed: Ps) -> f64;

    /// Total payload bytes moved.
    fn bytes_moved(&self) -> u64;

    /// Is the serialized transport occupied at `now`? A read-only probe
    /// for the observer layer ([`crate::observe`]): a way idling while
    /// the host link is saturated is *link backpressure*, not
    /// queue-depth starvation, and the distinction needs this bit.
    fn busy_at(&self, now: Ps) -> bool;
}

/// NVMe-style multi-queue link: N submission queues sharing one serialized
/// transport. Timing is identical to a [`SataLink`] with the same
/// [`SataGen`] parameters (the `[sata]` section parameterizes whichever
/// link kind is selected); the difference is per-queue attribution here
/// and per-queue depth + arbitration in [`SubmissionQueues`].
#[derive(Debug, Clone)]
pub struct MultiQueueLink {
    pub gen: SataGen,
    busy_until: Ps,
    bytes_moved: u64,
    busy_time: Ps,
    /// Payload bytes moved per submission queue.
    pub queue_bytes: Vec<u64>,
}

impl MultiQueueLink {
    pub fn new(gen: SataGen, queues: u16) -> MultiQueueLink {
        MultiQueueLink {
            gen,
            busy_until: Ps::ZERO,
            bytes_moved: 0,
            busy_time: Ps::ZERO,
            queue_bytes: vec![0; queues.max(1) as usize],
        }
    }
}

impl HostLink for MultiQueueLink {
    fn reserve(&mut self, now: Ps, queue: u16, bytes: u64, with_cmd: bool) -> (Ps, Ps) {
        let start = self.busy_until.max(now);
        let mut dur = self.gen.transfer_time(bytes);
        if with_cmd {
            dur += self.gen.command_overhead;
        }
        self.busy_until = start + dur;
        self.bytes_moved += bytes;
        self.busy_time += dur;
        if let Some(q) = self.queue_bytes.get_mut(queue as usize) {
            *q += bytes;
        }
        (start, self.busy_until)
    }

    fn utilization(&self, elapsed: Ps) -> f64 {
        if elapsed.as_ps() <= 0 {
            return 0.0;
        }
        self.busy_time.as_ps() as f64 / elapsed.as_ps() as f64
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes_moved
    }

    fn busy_at(&self, now: Ps) -> bool {
        now < self.busy_until
    }
}

/// N submission queues with a per-queue depth and a queue-arbitration
/// policy — the closed-loop admission front end of the multi-queue host
/// path. The device "fetches" the next request with [`fetch`]: a queue is
/// eligible when it has un-issued requests and fewer than `depth`
/// outstanding; round robin takes eligible queues in turn, weighted round
/// robin gives each queue credit proportional to its priority class's
/// weight and refills when every eligible queue is out of credit (so no
/// queue with a positive weight can starve).
///
/// [`fetch`]: SubmissionQueues::fetch
#[derive(Debug, Clone)]
pub struct SubmissionQueues {
    /// Per-queue FIFOs of un-issued trace indices.
    pending: Vec<VecDeque<u32>>,
    outstanding: Vec<u32>,
    /// Priority class per queue (the class of its first tagged request).
    class: Vec<u8>,
    depth: u32,
    arb: QueueArb,
    weights: [u32; NUM_CLASSES],
    credits: Vec<u32>,
    rr_next: usize,
}

impl SubmissionQueues {
    pub fn new(
        queues: u16,
        depth: u32,
        arb: QueueArb,
        weights: [u32; NUM_CLASSES],
    ) -> SubmissionQueues {
        let n = queues.max(1) as usize;
        SubmissionQueues {
            pending: vec![VecDeque::new(); n],
            outstanding: vec![0; n],
            class: vec![CLASS_NORMAL; n],
            depth: depth.max(1),
            arb,
            weights,
            credits: vec![0; n],
            rr_next: 0,
        }
    }

    /// Fill the queues from a trace of `n` requests: request `i` goes to
    /// the queue named by its stream tag (queue 0 when the trace carries
    /// no stream track). Each queue's class is its first request's class.
    /// The caller has validated stream ids against the queue count.
    pub fn prime(&mut self, n: usize, streams: &[StreamTag]) {
        for q in &mut self.pending {
            q.clear();
        }
        self.outstanding.fill(0);
        self.class.fill(CLASS_NORMAL);
        let mut tagged = vec![false; self.pending.len()];
        for i in 0..n {
            let tag = streams.get(i).copied().unwrap_or(StreamTag {
                stream: 0,
                class: CLASS_NORMAL,
            });
            let qi = tag.stream as usize;
            assert!(
                qi < self.pending.len(),
                "stream {} exceeds the configured queue count {}",
                tag.stream,
                self.pending.len()
            );
            self.pending[qi].push_back(i as u32);
            if !tagged[qi] {
                tagged[qi] = true;
                self.class[qi] = tag.class;
            }
        }
        for (q, c) in self.credits.iter_mut().zip(&self.class) {
            *q = self.weights[(*c as usize).min(NUM_CLASSES - 1)];
        }
        self.rr_next = 0;
    }

    fn eligible(&self, q: usize) -> bool {
        !self.pending[q].is_empty() && self.outstanding[q] < self.depth
    }

    /// Pop the next request index to issue, honoring depth + arbitration.
    pub fn fetch(&mut self) -> Option<u32> {
        let n = self.pending.len();
        let grant = |this: &mut Self, q: usize| {
            let idx = this.pending[q].pop_front().expect("eligible queue");
            this.outstanding[q] += 1;
            this.rr_next = (q + 1) % n;
            idx
        };
        match self.arb {
            QueueArb::RoundRobin => {
                for off in 0..n {
                    let q = (self.rr_next + off) % n;
                    if self.eligible(q) {
                        return Some(grant(self, q));
                    }
                }
                None
            }
            QueueArb::Weighted => {
                // Two passes: spend remaining credit first; when every
                // eligible queue is spent, refill all and take one.
                for refill in [false, true] {
                    if refill {
                        if !(0..n).any(|q| self.eligible(q)) {
                            return None;
                        }
                        for (c, class) in self.credits.iter_mut().zip(&self.class) {
                            *c = self.weights[(*class as usize).min(NUM_CLASSES - 1)];
                        }
                    }
                    for off in 0..n {
                        let q = (self.rr_next + off) % n;
                        if self.eligible(q) && self.credits[q] > 0 {
                            self.credits[q] -= 1;
                            return Some(grant(self, q));
                        }
                    }
                }
                None
            }
        }
    }

    /// A request issued from `queue` completed.
    pub fn complete(&mut self, queue: u16) {
        let q = queue as usize;
        debug_assert!(self.outstanding[q] > 0, "completion without issue");
        self.outstanding[q] = self.outstanding[q].saturating_sub(1);
    }

    /// Outstanding requests in `queue` (issued, not yet completed).
    pub fn outstanding(&self, queue: u16) -> u32 {
        self.outstanding[queue as usize]
    }

    /// Any request left to issue?
    pub fn has_pending(&self) -> bool {
        self.pending.iter().any(|q| !q.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::trace::{CLASS_BULK, CLASS_URGENT};

    fn tags(classes: &[(u16, u8)]) -> Vec<StreamTag> {
        classes
            .iter()
            .map(|&(stream, class)| StreamTag { stream, class })
            .collect()
    }

    #[test]
    fn multi_queue_link_times_match_sata() {
        let gen = SataGen::sata2();
        let mut sata = SataLink::new(gen);
        let mut mq = MultiQueueLink::new(gen, 4);
        let a = sata.reserve(Ps::ZERO, 65536, true);
        let b = HostLink::reserve(&mut mq, Ps::ZERO, 2, 65536, true);
        assert_eq!(a, b, "same transport parameters, same timing");
        assert_eq!(mq.queue_bytes, vec![0, 0, 65536, 0]);
        assert_eq!(mq.bytes_moved(), 65536);
    }

    #[test]
    fn round_robin_fetch_respects_depth() {
        let mut sq = SubmissionQueues::new(2, 2, QueueArb::RoundRobin, [8, 4, 2, 1]);
        // Queue 0: requests 0,2,4; queue 1: requests 1,3,5.
        let t = tags(&[(0, 0), (1, 2), (0, 0), (1, 2), (0, 0), (1, 2)]);
        sq.prime(6, &t);
        // Alternating grants until both queues hit depth 2.
        assert_eq!(sq.fetch(), Some(0));
        assert_eq!(sq.fetch(), Some(1));
        assert_eq!(sq.fetch(), Some(2));
        assert_eq!(sq.fetch(), Some(3));
        assert_eq!(sq.fetch(), None, "both queues at depth");
        assert_eq!(sq.outstanding(0), 2);
        sq.complete(0);
        assert_eq!(sq.fetch(), Some(4));
        assert_eq!(sq.fetch(), None);
        sq.complete(1);
        assert_eq!(sq.fetch(), Some(5));
        assert!(!sq.has_pending());
    }

    #[test]
    fn weighted_fetch_follows_class_weights() {
        // Queue 0 urgent (weight 8), queue 1 bulk (weight 2); deep queues,
        // huge depth: grants per refill cycle follow 8:2.
        let mut sq = SubmissionQueues::new(2, 1000, QueueArb::Weighted, [8, 4, 2, 1]);
        let mut t = Vec::new();
        for i in 0..40u16 {
            t.push(StreamTag {
                stream: i % 2,
                class: if i % 2 == 0 { CLASS_URGENT } else { CLASS_BULK },
            });
        }
        sq.prime(40, &t);
        let mut grants = [0u32; 2];
        for _ in 0..20 {
            let idx = sq.fetch().unwrap();
            grants[(idx % 2) as usize] += 1;
        }
        assert_eq!(grants, [16, 4], "two full 8:2 cycles");
        // The bulk queue is never starved: it fetched in every cycle.
        assert!(grants[1] > 0);
    }

    #[test]
    fn untracked_trace_lands_in_queue_zero() {
        let mut sq = SubmissionQueues::new(4, 8, QueueArb::RoundRobin, [8, 4, 2, 1]);
        sq.prime(3, &[]);
        assert_eq!(sq.fetch(), Some(0));
        assert_eq!(sq.fetch(), Some(1));
        assert_eq!(sq.fetch(), Some(2));
        assert_eq!(sq.fetch(), None);
    }

    #[test]
    #[should_panic(expected = "exceeds the configured queue count")]
    fn prime_rejects_out_of_range_stream() {
        let mut sq = SubmissionQueues::new(2, 8, QueueArb::RoundRobin, [8, 4, 2, 1]);
        sq.prime(1, &tags(&[(5, 0)]));
    }

    #[test]
    fn kind_and_arb_parse_roundtrip() {
        for k in [HostLinkKind::Sata, HostLinkKind::MultiQueue] {
            assert_eq!(HostLinkKind::parse(k.name()), Some(k));
        }
        for a in [QueueArb::RoundRobin, QueueArb::Weighted] {
            assert_eq!(QueueArb::parse(a.name()), Some(a));
        }
        assert_eq!(HostLinkKind::parse("pcie9"), None);
        assert_eq!(QueueArb::parse("fifo"), None);
    }
}
