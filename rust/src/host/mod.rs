//! Host side: the pluggable host link (SATA / NVMe-style multi-queue) and
//! workload traces.

pub mod link;
pub mod sata;
pub mod trace;

pub use link::{HostLink, HostLinkKind, MultiQueueLink, QueueArb, SubmissionQueues};
pub use sata::{SataGen, SataLink};
pub use trace::{Request, RequestKind, StreamTag, Trace, TraceGen};
