//! Host side: the SATA link model and workload traces.

pub mod sata;
pub mod trace;

pub use sata::{SataGen, SataLink};
pub use trace::{Request, RequestKind, Trace, TraceGen};
