//! SATA host-interface model.
//!
//! The paper attaches the SSD over SATA2 ("SATA 3 Gbit/s", up to 300 MB/s
//! payload, footnote 1). We model the link as a serialized resource with a
//! payload bandwidth cap and a per-frame protocol overhead; Table 4's
//! (4-channel, 4-way) read rows saturate exactly this cap ("max").

use crate::util::time::Ps;

/// SATA generation / link parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SataGen {
    /// Payload bandwidth cap in MB/s.
    pub bandwidth_mbps: f64,
    /// Per-command protocol overhead (FIS exchange, command setup).
    pub command_overhead: Ps,
}

impl SataGen {
    /// SATA2 / 3 Gbit/s: 300 MB/s payload (the paper's host interface).
    pub fn sata2() -> SataGen {
        SataGen {
            bandwidth_mbps: 300.0,
            command_overhead: Ps::us(5),
        }
    }

    /// SATA1 / 1.5 Gbit/s: 150 MB/s.
    pub fn sata1() -> SataGen {
        SataGen {
            bandwidth_mbps: 150.0,
            command_overhead: Ps::us(5),
        }
    }

    /// SATA3 / 6 Gbit/s: 600 MB/s (for what-if ablations).
    pub fn sata3() -> SataGen {
        SataGen {
            bandwidth_mbps: 600.0,
            command_overhead: Ps::us(5),
        }
    }

    /// Payload transfer time for `bytes`, computed in checked integer
    /// arithmetic so the picosecond exactness the rest of the DES
    /// guarantees survives the host link. The rate is fixed to whole
    /// bytes/second (exact for every real link generation), the division
    /// rounds to nearest (matching the historical f64 path everywhere the
    /// f64 path was exact), and byte counts whose transfer would not fit
    /// the `Ps` range saturate to [`Ps::MAX`] explicitly instead of
    /// through a float cast.
    pub fn transfer_time(&self, bytes: u64) -> Ps {
        // Config validation rejects non-positive bandwidth; `max(1)` keeps
        // a hand-built degenerate struct from dividing by zero.
        let bps = ((self.bandwidth_mbps * 1e6) as u128).max(1);
        let num = bytes as u128 * 1_000_000_000_000u128;
        let ps = (num + bps / 2) / bps;
        Ps(i64::try_from(ps).unwrap_or(i64::MAX))
    }
}

/// The link as a DES resource: serialized, bandwidth-capped.
#[derive(Debug, Clone)]
pub struct SataLink {
    pub gen: SataGen,
    busy_until: Ps,
    pub bytes_moved: u64,
    pub busy_time: Ps,
}

impl SataLink {
    pub fn new(gen: SataGen) -> SataLink {
        SataLink {
            gen,
            busy_until: Ps::ZERO,
            bytes_moved: 0,
            busy_time: Ps::ZERO,
        }
    }

    /// Free the link and zero its statistics (sweep-worker reuse).
    pub fn reset(&mut self, gen: SataGen) {
        self.gen = gen;
        self.busy_until = Ps::ZERO;
        self.bytes_moved = 0;
        self.busy_time = Ps::ZERO;
    }

    pub fn free_at(&self, now: Ps) -> Ps {
        self.busy_until.max(now)
    }

    pub fn is_free(&self, now: Ps) -> bool {
        now >= self.busy_until
    }

    /// Reserve the link starting no earlier than `now` for a payload of
    /// `bytes` (plus command overhead if `with_cmd`); returns (start, done).
    pub fn reserve(&mut self, now: Ps, bytes: u64, with_cmd: bool) -> (Ps, Ps) {
        let start = self.free_at(now);
        let mut dur = self.gen.transfer_time(bytes);
        if with_cmd {
            dur += self.gen.command_overhead;
        }
        self.busy_until = start + dur;
        self.bytes_moved += bytes;
        self.busy_time += dur;
        (start, self.busy_until)
    }

    /// Achieved payload utilization of the cap over a window.
    pub fn utilization(&self, elapsed: Ps) -> f64 {
        if elapsed.as_ps() <= 0 {
            return 0.0;
        }
        self.busy_time.as_ps() as f64 / elapsed.as_ps() as f64
    }
}

impl crate::host::link::HostLink for SataLink {
    /// SATA has a single command stream: the submission-queue id is
    /// ignored and every transfer serializes on the one link.
    fn reserve(&mut self, now: Ps, _queue: u16, bytes: u64, with_cmd: bool) -> (Ps, Ps) {
        SataLink::reserve(self, now, bytes, with_cmd)
    }

    fn utilization(&self, elapsed: Ps) -> f64 {
        SataLink::utilization(self, elapsed)
    }

    fn bytes_moved(&self) -> u64 {
        self.bytes_moved
    }

    fn busy_at(&self, now: Ps) -> bool {
        !self.is_free(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sata2_transfer_times() {
        let g = SataGen::sata2();
        // 64 KiB at 300 MB/s = 218.45 us
        let t = g.transfer_time(65536);
        assert!((t.as_us_f64() - 218.45).abs() < 0.01, "t={t}");
        // 2048 B page chunk = 6.83 us
        let t = g.transfer_time(2048);
        assert!((t.as_us_f64() - 6.83).abs() < 0.01);
    }

    #[test]
    fn link_serializes() {
        let mut l = SataLink::new(SataGen::sata2());
        let (s1, d1) = l.reserve(Ps::ZERO, 2048, true);
        assert_eq!(s1, Ps::ZERO);
        let (s2, _) = l.reserve(Ps::ZERO, 2048, false);
        assert_eq!(s2, d1, "second transfer must wait for the first");
    }

    #[test]
    fn reserve_after_idle_starts_at_now() {
        let mut l = SataLink::new(SataGen::sata2());
        l.reserve(Ps::ZERO, 2048, false);
        let (s, _) = l.reserve(Ps::ms(1), 2048, false);
        assert_eq!(s, Ps::ms(1));
    }

    /// Regression: the payload time is exact integer picoseconds at the
    /// 300 MB/s cap (the old f64 path rounded through a double and
    /// saturated through a float cast for huge byte counts).
    #[test]
    fn transfer_time_exact_integer_ps_at_cap() {
        let g = SataGen::sata2();
        // 2048 B * 1e12 / 3e8 B/s = 6 826 666.67 ps, round-to-nearest.
        assert_eq!(g.transfer_time(2048), Ps::ps(6_826_667));
        // 64 KiB: 218 453 333.33 ps.
        assert_eq!(g.transfer_time(65536), Ps::ps(218_453_333));
        // 1 TiB: 3 665.04 s, still exact to the picosecond.
        assert_eq!(g.transfer_time(1 << 40), Ps::ps(3_665_038_759_253_333));
        assert_eq!(g.transfer_time(0), Ps::ZERO);
        // Beyond the Ps range the time saturates explicitly.
        assert_eq!(g.transfer_time(u64::MAX), Ps::MAX);
        // Exactness is additive: n pages cost exactly n * (page cost) to
        // within the per-call rounding half-ulp.
        let one = g.transfer_time(2048).as_ps();
        let eight = g.transfer_time(8 * 2048).as_ps();
        assert!((eight - 8 * one).abs() <= 8, "one={one} eight={eight}");
    }

    #[test]
    fn generations_ordered() {
        assert!(SataGen::sata1().transfer_time(4096) > SataGen::sata2().transfer_time(4096));
        assert!(SataGen::sata2().transfer_time(4096) > SataGen::sata3().transfer_time(4096));
    }
}
