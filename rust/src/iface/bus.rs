//! Event-duration model of one channel bus at a chosen operating point.
//!
//! Converts the closed-form analysis of [`super::timing`] into the concrete
//! durations the DES schedules: command/address phases, page data transfers
//! and status polls. One `BusTiming` exists per channel; all ways on the
//! channel share it (way interleaving multiplexes this bus, §2.2.1).

use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::util::time::Ps;

/// Cycle counts for NAND command sequences (ONFI-style, 8-bit bus):
/// command byte(s) + 5 address bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommandCycles {
    /// READ: 00h + 5 addr + 30h.
    pub read: u32,
    /// PROGRAM: 80h + 5 addr (+ data…) + 10h.
    pub program: u32,
    /// ERASE: 60h + 3 addr + D0h.
    pub erase: u32,
    /// STATUS: 70h + 1 data cycle.
    pub status: u32,
    /// Controller-side issue overhead per command, in interface-clock
    /// cycles (NAND_IF pipeline, FIFO (re)arming, D_CON settling). This is
    /// a calibration constant; see DESIGN.md §Calibration anchors.
    pub controller_overhead: u32,
}

impl Default for CommandCycles {
    fn default() -> Self {
        CommandCycles {
            read: 7,
            program: 7,
            erase: 5,
            status: 2,
            controller_overhead: 113,
        }
    }
}

/// Which kind of bus phase a grant occupies the channel with. The DES
/// tracks this in its per-channel grant context; the observer layer
/// ([`crate::observe`]) re-exports it onto timeline spans so a Perfetto
/// track shows *what* the bus was doing, not just that it was busy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusPhaseKind {
    /// Command + address cycles (READ/PROGRAM/ERASE issue; programs
    /// include the data-in burst in the same occupancy).
    Cmd,
    /// Read data-out burst (page register -> controller, + ECC).
    DataOut,
    /// Status poll (70h + status byte).
    Status,
}

impl BusPhaseKind {
    /// Stable lowercase name used as the timeline span label.
    pub fn name(self) -> &'static str {
        match self {
            BusPhaseKind::Cmd => "cmd",
            BusPhaseKind::DataOut => "data_out",
            BusPhaseKind::Status => "status",
        }
    }
}

/// Concrete bus-event durations for one (interface, NAND device) pairing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BusTiming {
    pub kind: InterfaceKind,
    /// Interface clock period (t_P at the operating point).
    pub t_cycle: Ps,
    /// Per-byte data transfer time (t_cycle for SDR, t_cycle/2 for DDR).
    pub t_data_byte: Ps,
    pub cycles: CommandCycles,
}

impl BusTiming {
    /// Derive from Table 2-style parameters at the paper's operating rule.
    pub fn from_params(params: &IfaceParams, kind: InterfaceKind) -> BusTiming {
        BusTiming {
            kind,
            t_cycle: Ps::from_ns_f64(params.operating_tp_ns(kind)),
            t_data_byte: Ps::from_ns_f64(params.byte_time_ns(kind)),
            cycles: CommandCycles::default(),
        }
    }

    /// Duration of `n` command/address cycles. Command and address bytes are
    /// always SDR (one per cycle) — the DDR packing applies to data only
    /// (Fig. 6: DVS toggles during data bursts).
    pub fn cmd_cycles(&self, n: u32) -> Ps {
        self.t_cycle.times(n as u64)
    }

    /// Bus occupancy of the READ command + address phase, including the
    /// controller issue overhead.
    pub fn read_cmd(&self) -> Ps {
        self.cmd_cycles(self.cycles.read + self.cycles.controller_overhead)
    }

    /// Bus occupancy of the PROGRAM command + address phase.
    pub fn program_cmd(&self) -> Ps {
        self.cmd_cycles(self.cycles.program + self.cycles.controller_overhead)
    }

    /// Bus occupancy of the ERASE command phase.
    pub fn erase_cmd(&self) -> Ps {
        self.cmd_cycles(self.cycles.erase + self.cycles.controller_overhead)
    }

    /// Bus occupancy of one status poll (70h + status byte).
    pub fn status_poll(&self) -> Ps {
        self.cmd_cycles(self.cycles.status)
    }

    /// Bus occupancy of a data burst of `bytes` bytes.
    pub fn data_transfer(&self, bytes: u32) -> Ps {
        self.t_data_byte.times(bytes as u64)
    }

    /// Operating frequency in MHz (for reports).
    pub fn freq_mhz(&self) -> f64 {
        // simlint: allow(float-on-time, "display-only MHz accessor; leaves ps via as_ns_f64")
        1e3 / self.t_cycle.as_ns_f64()
    }

    /// Shortest bus occupancy any cross-channel interaction can take: the
    /// minimum over all command/status phases (data bursts are never
    /// shorter than a status poll for real page sizes, and zero-byte bursts
    /// do not occur). This is the conservative lookahead bound used by the
    /// sharded executor (`[engine] window_ps = 0` derives it from here).
    pub fn min_phase(&self) -> Ps {
        self.status_poll()
            .min(self.read_cmd())
            .min(self.program_cmd())
            .min(self.erase_cmd())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timings() -> (BusTiming, BusTiming, BusTiming) {
        let p = IfaceParams::default();
        (
            BusTiming::from_params(&p, InterfaceKind::Conv),
            BusTiming::from_params(&p, InterfaceKind::SyncOnly),
            BusTiming::from_params(&p, InterfaceKind::Proposed),
        )
    }

    #[test]
    fn operating_points() {
        let (c, s, d) = timings();
        assert_eq!(c.t_cycle, Ps::ns(20));
        assert_eq!(c.t_data_byte, Ps::ns(20));
        // 83 MHz -> 12.048 ns
        assert_eq!(s.t_cycle, Ps::ps(12_048));
        assert_eq!(s.t_data_byte, Ps::ps(12_048));
        assert_eq!(d.t_cycle, Ps::ps(12_048));
        assert_eq!(d.t_data_byte, Ps::ps(6_024));
    }

    #[test]
    fn page_transfer_ratios() {
        // A 2112-byte SLC page: CONV 42.24us, SYNC 25.44us, DDR 12.72us —
        // DDR exactly halves SYNC_ONLY.
        let (c, s, d) = timings();
        let conv = c.data_transfer(2112);
        let sync = s.data_transfer(2112);
        let ddr = d.data_transfer(2112);
        assert_eq!(conv, Ps::ns(42_240));
        assert_eq!(sync.as_ps(), 2 * ddr.as_ps());
        assert!(conv > sync && sync > ddr);
    }

    #[test]
    fn cmd_phases_sdr_even_on_ddr() {
        let (_, s, d) = timings();
        // Same clock -> same command-phase duration despite DDR data.
        assert_eq!(s.read_cmd(), d.read_cmd());
        assert!(d.read_cmd() > d.status_poll());
    }

    #[test]
    fn min_phase_is_the_status_poll() {
        // With the default command cycles the status poll (2 cycles) is the
        // shortest phase on every interface — and it must be positive, or
        // the sharded executor could not advance.
        let (c, s, d) = timings();
        for t in [c, s, d] {
            assert!(t.min_phase() > Ps::ZERO);
            assert_eq!(t.min_phase(), t.status_poll());
        }
    }

    #[test]
    fn freq_reported() {
        let (c, _, d) = timings();
        assert!((c.freq_mhz() - 50.0).abs() < 1e-9);
        assert!((d.freq_mhz() - 83.0).abs() < 0.01);
    }
}
