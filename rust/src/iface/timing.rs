//! Closed-form interface timing analysis — Eqs. (1)–(9) of the paper.
//!
//! All equations operate in fractional nanoseconds (f64) because the paper's
//! Table 2 parameters are specified to 10 ps; the DES quantizes the derived
//! clock to integer picoseconds afterwards.

/// Which controller↔flash interface an SSD uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterfaceKind {
    /// Conventional asynchronous SDR (Section 3). "CONV" in the tables.
    Conv,
    /// Synchronous SDR with DVS, per Son et al. [23]. "SYNC_ONLY".
    SyncOnly,
    /// Proposed synchronous DDR with DVS + DLL (Section 4). "PROPOSED".
    Proposed,
}

impl InterfaceKind {
    pub const ALL: [InterfaceKind; 3] =
        [InterfaceKind::Conv, InterfaceKind::SyncOnly, InterfaceKind::Proposed];

    pub fn name(self) -> &'static str {
        match self {
            InterfaceKind::Conv => "CONV",
            InterfaceKind::SyncOnly => "SYNC_ONLY",
            InterfaceKind::Proposed => "PROPOSED",
        }
    }

    /// Data beats per interface clock cycle (2 for DDR).
    pub fn beats_per_cycle(self) -> u32 {
        match self {
            InterfaceKind::Proposed => 2,
            _ => 1,
        }
    }

    /// True for the interfaces that strobe data with DVS (synchronous read).
    pub fn has_dvs(self) -> bool {
        !matches!(self, InterfaceKind::Conv)
    }
}

impl std::fmt::Display for InterfaceKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The measured/specified timing parameters of Table 2 (in ns).
///
/// The first five come from synthesis (PrimeTime on a 130 nm library in the
/// paper; constants here), the rest from the NAND datasheets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IfaceParams {
    /// Signal propagation, controller FFs → flash strobe pads (CONV only).
    pub t_out_ns: f64,
    /// Data propagation, controller IO pad → RFIFO/WFIFO (CONV only).
    pub t_in_ns: f64,
    /// RFIFO/WFIFO setup time.
    pub t_s_ns: f64,
    /// RFIFO/WFIFO hold time.
    pub t_h_ns: f64,
    /// DVS-vs-IO board-level arrival skew at RFIFO (PROPOSED only).
    pub t_diff_ns: f64,
    /// RLAT → controller IO pad data transfer time (CONV only).
    pub t_rea_ns: f64,
    /// Page register ↔ latch per-byte time; device floor on t_P.
    pub t_byte_ns: f64,
    /// D_CON delay factor α in t_D = α·t_P, 0 ≤ α ≤ 1/2 (Eq. 1).
    pub alpha: f64,
    /// IO setup time w.r.t. DVS at the controller pad (Eq. 8 variant).
    pub t_ios_ns: f64,
    /// IO hold time w.r.t. DVS at the controller pad (Eq. 8 variant).
    pub t_ioh_ns: f64,
}

impl Default for IfaceParams {
    /// Table 2 of the paper.
    fn default() -> Self {
        IfaceParams {
            t_out_ns: 7.82,
            t_in_ns: 1.65,
            t_s_ns: 0.25,
            t_h_ns: 0.02,
            t_diff_ns: 4.69,
            t_rea_ns: 20.0,
            t_byte_ns: 12.0,
            alpha: 0.5,
            t_ios_ns: 2.75,
            t_ioh_ns: 2.75,
        }
    }
}

impl IfaceParams {
    /// Eq. (1): t_D = α·t_P.
    pub fn t_d_ns(&self, t_p_ns: f64) -> f64 {
        self.alpha * t_p_ns
    }

    /// Eq. (6): minimum clock period of the **conventional** interface,
    /// t_P,min = max{ (t_OUT + t_REA + t_IN + t_S) / (1 + α), t_BYTE }.
    pub fn conv_tp_min_ns(&self) -> f64 {
        let serial = (self.t_out_ns + self.t_rea_ns + self.t_in_ns + self.t_s_ns)
            / (1.0 + self.alpha);
        serial.max(self.t_byte_ns)
    }

    /// Eq. (8): minimum clock period of the **proposed** interface from the
    /// controller-pad constraints: t_P,min = max{ 2(t_IOS + t_IOH), t_BYTE }.
    pub fn proposed_tp_min_pad_ns(&self) -> f64 {
        (2.0 * (self.t_ios_ns + self.t_ioh_ns)).max(self.t_byte_ns)
    }

    /// Eq. (9): minimum clock period of the **proposed** interface from
    /// board-level parameters: t_P,min = max{ 2(t_S + t_H + t_DIFF), t_BYTE }.
    pub fn proposed_tp_min_board_ns(&self) -> f64 {
        (2.0 * (self.t_s_ns + self.t_h_ns + self.t_diff_ns)).max(self.t_byte_ns)
    }

    /// SYNC_ONLY ([23]) transfers on a single DVS edge; the strobe period is
    /// limited by the same pad path as PROPOSED but without the ×2 DDR
    /// packing, and by t_BYTE. The paper sets SYNC_ONLY to the same 83 MHz
    /// clock as PROPOSED (§5.3: "derived from PROPOSED by replacing DDR
    /// transfers with single-data-rate transfers").
    pub fn sync_only_tp_min_ns(&self) -> f64 {
        (self.t_s_ns + self.t_h_ns + self.t_diff_ns).max(self.t_byte_ns)
    }

    /// Minimum clock period for a given interface kind.
    pub fn tp_min_ns(&self, kind: InterfaceKind) -> f64 {
        match kind {
            InterfaceKind::Conv => self.conv_tp_min_ns(),
            InterfaceKind::SyncOnly => self.sync_only_tp_min_ns(),
            InterfaceKind::Proposed => self.proposed_tp_min_board_ns(),
        }
    }

    /// The paper's frequency setting rule (§5.2) with its failure modes
    /// surfaced: the operating frequency is t_P,min rounded **down** to a
    /// whole MHz (19.81 ns → 50 MHz, 12 ns → 83 MHz). Degenerate parameter
    /// sets (all-zero timings from a hand-edited TOML, negative deltas)
    /// produce a non-positive or non-finite t_P,min — the unchecked floor
    /// then yields 0 MHz or an absurd clock and a divide-by-zero in
    /// [`operating_tp_ns`](Self::operating_tp_ns); those return `Err` here.
    pub fn checked_operating_freq_mhz(&self, kind: InterfaceKind) -> Result<u32, String> {
        let tp = self.tp_min_ns(kind);
        if !tp.is_finite() || tp <= 0.0 {
            return Err(format!(
                "{kind}: t_P,min = {tp} ns is not a positive finite period \
                 (degenerate interface parameters)"
            ));
        }
        let freq = (1000.0 / tp).floor();
        if freq < 1.0 {
            return Err(format!(
                "{kind}: t_P,min = {tp:.2} ns rounds down to 0 MHz (period above 1 µs)"
            ));
        }
        Ok(freq as u32)
    }

    /// Unchecked convenience over
    /// [`checked_operating_freq_mhz`](Self::checked_operating_freq_mhz).
    /// Panics on degenerate parameters — config loading runs
    /// [`validate`](Self::validate) first, so a parameter set that reaches
    /// the simulator can never trip this.
    pub fn operating_freq_mhz(&self, kind: InterfaceKind) -> u32 {
        self.checked_operating_freq_mhz(kind)
            .expect("degenerate IfaceParams reached frequency derivation")
    }

    /// Operating clock period in ns from the whole-MHz frequency.
    pub fn operating_tp_ns(&self, kind: InterfaceKind) -> f64 {
        1000.0 / self.operating_freq_mhz(kind) as f64
    }

    /// Validate the parameter set: every timing must be finite and
    /// non-negative, the t_BYTE floor strictly positive, and each
    /// interface's derived operating frequency well-defined. Returns every
    /// problem found (empty = ok); `SsdConfig::validate` folds these into
    /// config-load errors, so degenerate TOML is rejected before any
    /// simulator is built.
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let fields = [
            ("t_out_ns", self.t_out_ns),
            ("t_in_ns", self.t_in_ns),
            ("t_s_ns", self.t_s_ns),
            ("t_h_ns", self.t_h_ns),
            ("t_diff_ns", self.t_diff_ns),
            ("t_rea_ns", self.t_rea_ns),
            ("t_byte_ns", self.t_byte_ns),
            ("alpha", self.alpha),
            ("t_ios_ns", self.t_ios_ns),
            ("t_ioh_ns", self.t_ioh_ns),
        ];
        for (name, v) in fields {
            if !v.is_finite() || v < 0.0 {
                errs.push(format!("params.{name} must be finite and >= 0, got {v}"));
            }
        }
        if !(self.t_byte_ns > 0.0) {
            errs.push(format!(
                "params.t_byte_ns must be > 0 (device floor on t_P), got {}",
                self.t_byte_ns
            ));
        }
        if errs.is_empty() {
            for kind in InterfaceKind::ALL {
                if let Err(e) = self.checked_operating_freq_mhz(kind) {
                    errs.push(e);
                }
            }
        }
        errs
    }

    /// Per-byte data transfer time on the bus at the operating point:
    /// one byte per cycle for SDR, one byte per half-cycle for DDR.
    pub fn byte_time_ns(&self, kind: InterfaceKind) -> f64 {
        self.operating_tp_ns(kind) / kind.beats_per_cycle() as f64
    }

    /// Eq. (2): DLL delay t_DLL = t_IOD,max − t_RWEBD,min + t_IOS.
    pub fn t_dll_ns(&self, t_iod_max_ns: f64, t_rwebd_min_ns: f64) -> f64 {
        t_iod_max_ns - t_rwebd_min_ns + self.t_ios_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_matches_paper_sect_5_2() {
        // §5.2: t_P,min = max{(7.82+20+1.65+0.25)/1.5, 12} = 19.81 ns @ α=0.5
        let p = IfaceParams::default();
        let tp = p.conv_tp_min_ns();
        assert!((tp - 19.81).abs() < 0.01, "tp={tp}");
        assert_eq!(p.operating_freq_mhz(InterfaceKind::Conv), 50);
    }

    #[test]
    fn proposed_matches_paper_sect_5_2() {
        // §5.2: t_P,min = max{(0.25+0.02+4.69)×2, 12} = 12 ns → 83 MHz
        let p = IfaceParams::default();
        let tp = p.proposed_tp_min_board_ns();
        assert!((tp - 12.0).abs() < 1e-9, "tp={tp}");
        assert_eq!(p.operating_freq_mhz(InterfaceKind::Proposed), 83);
    }

    #[test]
    fn sync_only_also_83mhz() {
        let p = IfaceParams::default();
        assert_eq!(p.operating_freq_mhz(InterfaceKind::SyncOnly), 83);
    }

    #[test]
    fn ddr_halves_byte_time() {
        let p = IfaceParams::default();
        let sdr = p.byte_time_ns(InterfaceKind::SyncOnly);
        let ddr = p.byte_time_ns(InterfaceKind::Proposed);
        assert!((sdr - 2.0 * ddr).abs() < 1e-9);
        // 83 MHz -> 12.048 ns SDR, 6.024 ns DDR
        assert!((sdr - 12.048).abs() < 0.001, "sdr={sdr}");
    }

    #[test]
    fn conv_byte_time_is_20ns() {
        let p = IfaceParams::default();
        assert!((p.byte_time_ns(InterfaceKind::Conv) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn tbyte_floor_binds_when_pad_path_is_fast() {
        // If the board were perfect (t_DIFF -> 0) the floor is t_BYTE (§6:
        // "only limited by t_BYTE").
        let p = IfaceParams {
            t_diff_ns: 0.0,
            ..IfaceParams::default()
        };
        assert!((p.proposed_tp_min_board_ns() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn alpha_sweep_monotone() {
        // Larger α gives the read path more slack -> smaller t_P,min (Eq. 6)
        let mut last = f64::INFINITY;
        for i in 0..=10 {
            let alpha = i as f64 * 0.05;
            let p = IfaceParams {
                alpha,
                ..IfaceParams::default()
            };
            let tp = p.conv_tp_min_ns();
            assert!(tp <= last + 1e-12, "not monotone at alpha={alpha}");
            last = tp;
        }
    }

    /// Regression: degenerate parameter sets (the all-zero TOML case, huge
    /// periods, NaN) must fail the checked derivation and `validate`
    /// instead of producing a 0 MHz clock and a later divide-by-zero.
    #[test]
    fn degenerate_params_rejected_not_divided_by() {
        // All-zero timings: t_P,min collapses to 0.
        let zero = IfaceParams {
            t_out_ns: 0.0,
            t_in_ns: 0.0,
            t_s_ns: 0.0,
            t_h_ns: 0.0,
            t_diff_ns: 0.0,
            t_rea_ns: 0.0,
            t_byte_ns: 0.0,
            alpha: 0.0,
            t_ios_ns: 0.0,
            t_ioh_ns: 0.0,
        };
        for kind in InterfaceKind::ALL {
            assert!(zero.checked_operating_freq_mhz(kind).is_err(), "{kind}");
        }
        assert!(!zero.validate().is_empty());
        // A period above 1 µs floors to 0 MHz: checked, not divided by.
        let slow = IfaceParams {
            t_byte_ns: 1500.0,
            ..IfaceParams::default()
        };
        assert!(slow
            .checked_operating_freq_mhz(InterfaceKind::Proposed)
            .unwrap_err()
            .contains("0 MHz"));
        assert!(!slow.validate().is_empty());
        // Negative and non-finite fields are named in the report.
        let neg = IfaceParams {
            t_rea_ns: -3.0,
            ..IfaceParams::default()
        };
        assert!(neg.validate().iter().any(|e| e.contains("t_rea_ns")));
        let nan = IfaceParams {
            t_diff_ns: f64::NAN,
            ..IfaceParams::default()
        };
        assert!(!nan.validate().is_empty());
        // The paper's parameters stay clean.
        assert!(IfaceParams::default().validate().is_empty());
        assert_eq!(
            IfaceParams::default().checked_operating_freq_mhz(InterfaceKind::Conv),
            Ok(50)
        );
    }

    #[test]
    fn dll_delay_eq2() {
        let p = IfaceParams::default();
        // t_DLL = t_IOD,max - t_RWEBD,min + t_IOS
        assert!((p.t_dll_ns(6.0, 1.5) - (6.0 - 1.5 + 2.75)).abs() < 1e-12);
    }

    #[test]
    fn faster_metal_layer_raises_frequency() {
        // §5.1/§6: with an extra metal layer t_BYTE decreases and the
        // proposed design's ceiling rises while CONV stays path-limited.
        let fast = IfaceParams {
            t_byte_ns: 6.0,
            ..IfaceParams::default()
        };
        assert!(fast.operating_freq_mhz(InterfaceKind::Proposed) > 83);
        assert_eq!(fast.operating_freq_mhz(InterfaceKind::Conv), 50);
    }
}
