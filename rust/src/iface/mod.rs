//! Controller ↔ NAND flash interface models — the paper's contribution.
//!
//! Three interfaces are modelled (§5.3):
//!
//! * [`InterfaceKind::Conv`] — conventional **asynchronous single-data-rate**
//!   interface (Fig. 3/4): WEB-paced writes, REB-paced reads with the
//!   serialized control→data round trip that inflates t_RC (Eq. 4–6).
//! * [`InterfaceKind::SyncOnly`] — the DVS-based **synchronous SDR**
//!   interface of \[23\]: data strobed by DVS, single edge per transfer.
//! * [`InterfaceKind::Proposed`] — the paper's **synchronous DDR** interface
//!   (Fig. 5/6): RWEB replaces WEB/REB, DVS replaces REB pin, duplicated
//!   FIFOs/latches clock data on both edges (Eq. 7–9).
//!
//! [`timing`] carries the closed-form minimum-clock-period analysis; [`bus`]
//! turns a chosen operating frequency into event durations for the DES;
//! [`pvt`] models process/voltage/temperature variation of the path delays.

pub mod bus;
pub mod pvt;
pub mod timing;

pub use bus::BusTiming;
pub use pvt::PvtModel;
pub use timing::{IfaceParams, InterfaceKind};
