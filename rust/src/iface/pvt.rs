//! Process/voltage/temperature (PVT) variation model.
//!
//! The paper motivates synchronous interfaces partly through PVT
//! (de)sensitization (§2.3.3, ref. [23]): in the conventional read path the
//! controller samples data on a delayed copy of its own clock, so any
//! variation of t_OUT + t_REA + t_IN eats directly into the setup margin.
//! With DVS, the strobe travels *with* the data, so only the board-level
//! skew t_DIFF varies.
//!
//! This module samples jittered path delays and reports setup-violation
//! probabilities; the same computation is implemented as the Pallas
//! `montecarlo` kernel (python/compile/kernels/montecarlo.py) and the two
//! are cross-checked in the integration tests.

use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::util::prng::Prng;

/// Relative 1-sigma variation applied to each path delay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PvtModel {
    /// Sigma as a fraction of nominal for on-chip paths (t_OUT, t_IN, t_REA).
    pub chip_sigma: f64,
    /// Sigma as a fraction of nominal for board paths (t_DIFF).
    pub board_sigma: f64,
}

impl Default for PvtModel {
    fn default() -> Self {
        // Worst-case 130nm corner spread; ±10% on-chip, ±5% board.
        PvtModel {
            chip_sigma: 0.10,
            board_sigma: 0.05,
        }
    }
}

/// One sampled corner of the timing parameters.
#[derive(Debug, Clone, Copy)]
pub struct PvtSample {
    pub t_out_ns: f64,
    pub t_in_ns: f64,
    pub t_rea_ns: f64,
    pub t_diff_ns: f64,
}

impl PvtModel {
    /// Draw one jittered corner around the nominal parameters.
    pub fn sample(&self, nominal: &IfaceParams, rng: &mut Prng) -> PvtSample {
        let j = |v: f64, sigma: f64, rng: &mut Prng| v * (1.0 + sigma * rng.next_gaussian());
        PvtSample {
            t_out_ns: j(nominal.t_out_ns, self.chip_sigma, rng),
            t_in_ns: j(nominal.t_in_ns, self.chip_sigma, rng),
            t_rea_ns: j(nominal.t_rea_ns, self.chip_sigma, rng),
            t_diff_ns: j(nominal.t_diff_ns, self.board_sigma, rng),
        }
    }

    /// Does the read path meet setup at clock period `tp_ns` under `s`?
    ///
    /// * CONV (Eq. 4): t_OUT + t_REA + t_IN + t_S must fit in (1+α)·t_P.
    /// * DVS interfaces (Eq. 9 form): 2(t_S + t_H + t_DIFF) ≤ t_P for DDR,
    ///   (t_S + t_H + t_DIFF) ≤ t_P for SDR — only the skew varies.
    pub fn read_path_meets(
        &self,
        kind: InterfaceKind,
        nominal: &IfaceParams,
        s: &PvtSample,
        tp_ns: f64,
    ) -> bool {
        match kind {
            InterfaceKind::Conv => {
                s.t_out_ns + s.t_rea_ns + s.t_in_ns + nominal.t_s_ns
                    <= (1.0 + nominal.alpha) * tp_ns + 1e-12
            }
            InterfaceKind::SyncOnly => {
                (nominal.t_s_ns + nominal.t_h_ns + s.t_diff_ns) <= tp_ns + 1e-12
            }
            InterfaceKind::Proposed => {
                2.0 * (nominal.t_s_ns + nominal.t_h_ns + s.t_diff_ns) <= tp_ns + 1e-12
            }
        }
    }

    /// Monte Carlo setup-violation probability at period `tp_ns`.
    pub fn violation_probability(
        &self,
        kind: InterfaceKind,
        nominal: &IfaceParams,
        tp_ns: f64,
        samples: u32,
        seed: u64,
    ) -> f64 {
        let mut rng = Prng::new(seed);
        let mut bad = 0u32;
        for _ in 0..samples {
            let s = self.sample(nominal, &mut rng);
            if !self.read_path_meets(kind, nominal, &s, tp_ns) {
                bad += 1;
            }
        }
        bad as f64 / samples as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nominal_corners_pass_at_operating_points() {
        let p = IfaceParams::default();
        let pvt = PvtModel {
            chip_sigma: 0.0,
            board_sigma: 0.0,
        };
        let mut rng = Prng::new(1);
        let s = pvt.sample(&p, &mut rng);
        assert!(pvt.read_path_meets(InterfaceKind::Conv, &p, &s, p.operating_tp_ns(InterfaceKind::Conv)));
        assert!(pvt.read_path_meets(
            InterfaceKind::Proposed,
            &p,
            &s,
            p.operating_tp_ns(InterfaceKind::Proposed)
        ));
    }

    #[test]
    fn conv_is_more_pvt_sensitive_than_proposed() {
        // Shrink the margin: run both at a period 2% above their own
        // nominal minimum and compare violation probabilities under the
        // same variation. CONV accumulates three varying paths; PROPOSED
        // only the board skew — the paper's desensitization claim.
        let p = IfaceParams::default();
        let pvt = PvtModel::default();
        let conv_tp = p.conv_tp_min_ns() * 1.02;
        let prop_tp = p.proposed_tp_min_board_ns() * 1.02;
        let conv_viol = pvt.violation_probability(InterfaceKind::Conv, &p, conv_tp, 20_000, 42);
        let prop_viol =
            pvt.violation_probability(InterfaceKind::Proposed, &p, prop_tp, 20_000, 42);
        assert!(
            conv_viol > prop_viol,
            "conv={conv_viol} prop={prop_viol}"
        );
        assert!(conv_viol > 0.05, "conv path should show real sensitivity");
    }

    #[test]
    fn violation_monotone_in_period() {
        let p = IfaceParams::default();
        let pvt = PvtModel::default();
        let v_tight = pvt.violation_probability(InterfaceKind::Conv, &p, 18.0, 10_000, 7);
        let v_loose = pvt.violation_probability(InterfaceKind::Conv, &p, 24.0, 10_000, 7);
        assert!(v_tight > v_loose);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = IfaceParams::default();
        let pvt = PvtModel::default();
        let a = pvt.violation_probability(InterfaceKind::Conv, &p, 19.81, 5_000, 99);
        let b = pvt.violation_probability(InterfaceKind::Conv, &p, 19.81, 5_000, 99);
        assert_eq!(a, b);
    }
}
