//! Design-space exploration (§5.3.2's motivating question: given a capacity
//! budget, which channel/way configuration should an SSD use?).
//!
//! The explorer enumerates candidate designs, evaluates them through the
//! AOT-compiled analytic model (PJRT) — or the pure-Rust mirror when
//! artifacts are absent — and reports ranked results and the
//! bandwidth/energy/area Pareto front. The DES cross-validates the winners.

use crate::analytic::{self, DesignPoint};
use crate::config::SsdConfig;
use crate::host::trace::RequestKind;
use crate::iface::timing::InterfaceKind;
use crate::nand::datasheet::CellType;
use crate::runtime::Runtime;
use anyhow::Result;

/// One candidate design and its evaluated metrics.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub iface: InterfaceKind,
    pub cell: CellType,
    pub channels: u16,
    pub ways: u16,
    /// t_BYTE override (ns) for the metal-layer ablation; None = datasheet.
    pub t_byte_ns: Option<f64>,
    pub read_bw: f64,
    pub write_bw: f64,
    pub read_nj_b: f64,
    pub write_nj_b: f64,
}

impl Candidate {
    /// Area proxy: channels dominate controller area (each needs a NAND_IF
    /// + ECC block and pins, §2.2.1); ways add die but share the interface.
    pub fn area_proxy(&self) -> f64 {
        self.channels as f64 + 0.15 * (self.channels as f64 * self.ways as f64)
    }

    /// Scalar figure of merit: harmonic-mean bandwidth per area.
    pub fn merit(&self) -> f64 {
        let hm = 2.0 / (1.0 / self.read_bw + 1.0 / self.write_bw);
        hm / self.area_proxy()
    }

    fn cfg(&self) -> SsdConfig {
        let mut cfg = SsdConfig {
            iface: self.iface,
            cell: self.cell,
            channels: self.channels,
            ways: self.ways,
            ..SsdConfig::default()
        };
        if let Some(tb) = self.t_byte_ns {
            cfg.params.t_byte_ns = tb;
        }
        cfg
    }
}

/// The exploration space.
#[derive(Debug, Clone)]
pub struct Space {
    pub ifaces: Vec<InterfaceKind>,
    pub cells: Vec<CellType>,
    /// (channels, ways) pairs.
    pub configs: Vec<(u16, u16)>,
    /// t_BYTE values to sweep (ns); empty = datasheet only.
    pub t_byte_sweep: Vec<f64>,
}

impl Default for Space {
    /// The paper's space: all interfaces × both cells × the constant-
    /// capacity configs of Table 4 plus the way sweep of Table 3.
    fn default() -> Space {
        Space {
            ifaces: InterfaceKind::ALL.to_vec(),
            cells: vec![CellType::Slc, CellType::Mlc],
            configs: vec![
                (1, 1),
                (1, 2),
                (1, 4),
                (1, 8),
                (1, 16),
                (2, 8),
                (4, 4),
                (2, 16),
                (4, 8),
            ],
            t_byte_sweep: vec![],
        }
    }
}

impl Space {
    pub fn enumerate(&self) -> Vec<Candidate> {
        let mut out = Vec::new();
        let tbytes: Vec<Option<f64>> = if self.t_byte_sweep.is_empty() {
            vec![None]
        } else {
            self.t_byte_sweep.iter().map(|&v| Some(v)).collect()
        };
        for &iface in &self.ifaces {
            for &cell in &self.cells {
                for &(channels, ways) in &self.configs {
                    for &t_byte_ns in &tbytes {
                        out.push(Candidate {
                            iface,
                            cell,
                            channels,
                            ways,
                            t_byte_ns,
                            read_bw: 0.0,
                            write_bw: 0.0,
                            read_nj_b: 0.0,
                            write_nj_b: 0.0,
                        });
                    }
                }
            }
        }
        out
    }
}

/// How candidates were evaluated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT JAX/Pallas artifact through PJRT.
    Hlo,
    /// Pure-Rust analytic mirror.
    Native,
}

/// Evaluate all candidates; uses the HLO runtime when provided.
pub fn evaluate(
    space: &Space,
    runtime: Option<&Runtime>,
) -> Result<(Vec<Candidate>, Backend)> {
    let mut cands = space.enumerate();
    let points: Vec<DesignPoint> = cands
        .iter()
        .map(|c| DesignPoint::from_config(&c.cfg()))
        .collect();
    let backend = match runtime {
        Some(rt) => {
            // The artifact grid is 4096 rows; chunk if ever larger.
            let mut offset = 0;
            for chunk in points.chunks(crate::runtime::PERF_N) {
                let outs = rt.perf_batch(chunk)?;
                for (i, o) in outs.into_iter().enumerate() {
                    let c = &mut cands[offset + i];
                    c.read_bw = o[0];
                    c.write_bw = o[1];
                    c.read_nj_b = o[2];
                    c.write_nj_b = o[3];
                }
                offset += chunk.len();
            }
            Backend::Hlo
        }
        None => {
            for (c, p) in cands.iter_mut().zip(&points) {
                c.read_bw = analytic::bandwidth_mbps(p, RequestKind::Read);
                c.write_bw = analytic::bandwidth_mbps(p, RequestKind::Write);
                c.read_nj_b = analytic::energy_nj_per_byte(p, RequestKind::Read);
                c.write_nj_b = analytic::energy_nj_per_byte(p, RequestKind::Write);
            }
            Backend::Native
        }
    };
    Ok((cands, backend))
}

/// Rank by figure of merit, best first.
pub fn rank(mut cands: Vec<Candidate>) -> Vec<Candidate> {
    cands.sort_by(|a, b| b.merit().partial_cmp(&a.merit()).unwrap());
    cands
}

/// Pareto front over (read_bw ↑, write_bw ↑, area ↓, write energy ↓).
pub fn pareto_front(cands: &[Candidate]) -> Vec<Candidate> {
    let dominates = |a: &Candidate, b: &Candidate| {
        let ge = a.read_bw >= b.read_bw
            && a.write_bw >= b.write_bw
            && a.area_proxy() <= b.area_proxy()
            && a.write_nj_b <= b.write_nj_b;
        let gt = a.read_bw > b.read_bw
            || a.write_bw > b.write_bw
            || a.area_proxy() < b.area_proxy()
            || a.write_nj_b < b.write_nj_b;
        ge && gt
    };
    cands
        .iter()
        .filter(|c| !cands.iter().any(|o| dominates(o, c)))
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumerate_counts() {
        let s = Space::default();
        assert_eq!(s.enumerate().len(), 3 * 2 * 9);
        let mut s2 = s.clone();
        s2.t_byte_sweep = vec![12.0, 8.0, 4.0];
        assert_eq!(s2.enumerate().len(), 3 * 2 * 9 * 3);
    }

    #[test]
    fn native_evaluation_ranks_proposed_on_top() {
        let (cands, backend) = evaluate(&Space::default(), None).unwrap();
        assert_eq!(backend, Backend::Native);
        let ranked = rank(cands);
        // Best merit design should use the PROPOSED interface (it wins
        // bandwidth at equal area everywhere).
        assert_eq!(ranked[0].iface, InterfaceKind::Proposed);
    }

    #[test]
    fn pareto_front_nonempty_and_consistent() {
        let (cands, _) = evaluate(&Space::default(), None).unwrap();
        let front = pareto_front(&cands);
        assert!(!front.is_empty());
        assert!(front.len() < cands.len());
        // Every front member must be undominated: re-check.
        for f in &front {
            assert!(front.iter().filter(|o| o.read_bw > f.read_bw
                && o.write_bw > f.write_bw
                && o.area_proxy() < f.area_proxy()
                && o.write_nj_b < f.write_nj_b).count() == 0);
        }
    }

    #[test]
    fn tbyte_sweep_raises_proposed_ceiling() {
        // A2 ablation: shrinking t_BYTE (extra metal layer) must raise
        // PROPOSED read bandwidth while CONV stays path-limited.
        let mut s = Space {
            ifaces: vec![InterfaceKind::Proposed, InterfaceKind::Conv],
            cells: vec![CellType::Slc],
            configs: vec![(1, 16)],
            t_byte_sweep: vec![12.0, 6.0],
        };
        s.cells = vec![CellType::Slc];
        let (cands, _) = evaluate(&s, None).unwrap();
        let find = |iface, tb| {
            cands
                .iter()
                .find(|c| c.iface == iface && c.t_byte_ns == Some(tb))
                .unwrap()
                .read_bw
        };
        assert!(find(InterfaceKind::Proposed, 6.0) > 1.1 * find(InterfaceKind::Proposed, 12.0));
        let conv_gain = find(InterfaceKind::Conv, 6.0) / find(InterfaceKind::Conv, 12.0);
        assert!(conv_gain < 1.05, "CONV stays t_RC-limited: {conv_gain}");
    }
}
