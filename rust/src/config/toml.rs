//! Minimal TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported: `[table]` / `[a.b]` headers, `key = value` pairs with string,
//! integer, float, boolean and flat-array values, `#` comments. This covers
//! every configuration file the project ships; unsupported syntax produces
//! a descriptive error rather than silent misparsing.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key → value. Table headers prefix keys,
/// so `[sim]\nways = 4` yields `"sim.ways"`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }
    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }
    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }
    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }
}

/// Parse a document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut prefix = String::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated table header", ln + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty table name", ln + 1));
            }
            prefix = format!("{name}.");
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", ln + 1));
        }
        let val = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", ln + 1))?;
        doc.entries.insert(format!("{prefix}{key}"), val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            inner.split(',').map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Array(items?));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_tables() {
        let doc = parse(
            r#"
# comment
name = "slc-16way"
ways = 16
alpha = 0.5
cache = false

[sata]
bandwidth = 300.0
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("slc-16way"));
        assert_eq!(doc.get_int("ways"), Some(16));
        assert_eq!(doc.get_float("alpha"), Some(0.5));
        assert_eq!(doc.get_bool("cache"), Some(false));
        assert_eq!(doc.get_float("sata.bandwidth"), Some(300.0));
    }

    #[test]
    fn arrays() {
        let doc = parse("ways = [1, 2, 4, 8, 16]").unwrap();
        match doc.get("ways").unwrap() {
            Value::Array(v) => {
                let ints: Vec<i64> = v.iter().map(|x| x.as_int().unwrap()).collect();
                assert_eq!(ints, vec![1, 2, 4, 8, 16]);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get_float("x"), Some(3.0));
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = parse(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn underscored_ints() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc.get_int("n"), Some(1_000_000));
    }

    #[test]
    fn errors_are_located() {
        let err = parse("[unclosed").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = parse("x 5").unwrap_err();
        assert!(err.contains("expected key = value"));
        let err = parse("x = @@").unwrap_err();
        assert!(err.contains("cannot parse value"));
    }

    #[test]
    fn dotted_table_names() {
        let doc = parse("[a.b]\nc = 1").unwrap();
        assert_eq!(doc.get_int("a.b.c"), Some(1));
    }
}
