//! SSD configuration schema, presets and TOML loading.

pub mod toml;

use crate::controller::cache::CacheConfig;
use crate::controller::sched::SchedKind;
use crate::host::link::{HostLinkKind, QueueArb};
use crate::host::sata::SataGen;
use crate::host::trace::NUM_CLASSES;
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::nand::datasheet::{CellType, NandTiming};
use crate::util::time::Ps;

/// Default per-class weights (urgent, normal, bulk, background), shared by
/// the host-side weighted queue arbitration and the `WeightedQos` way
/// scheduler.
pub const DEFAULT_CLASS_WEIGHTS: [u32; NUM_CLASSES] = [8, 4, 2, 1];

/// Which FTL mapping scheme to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtlKind {
    /// Page-level mapping with striped allocation (default; maximal
    /// interleaving on sequential workloads).
    PageMap,
    /// BAST-style hybrid log-block mapping [9].
    Hybrid,
}

/// Arrival process for open-loop (arrival-driven) workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Exponential inter-arrival gaps — a memoryless offered load.
    Poisson,
    /// Back-to-back groups of [`LoadConfig::burst`] requests whose group
    /// starts form a Poisson process at the same mean byte rate.
    Bursty,
}

/// Open-loop workload knobs (`[load]` in TOML). With `offered_mbps`
/// unset the workload is closed loop (queue-depth driven), the paper's
/// regime; setting it turns the run arrival-driven so latency under
/// sustained load is measurable (EXPERIMENTS.md §Load, `ddrnand
/// sweep-load`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadConfig {
    /// Offered load in MB/s (decimal); `None` = closed loop.
    pub offered_mbps: Option<f64>,
    /// Arrival process shape.
    pub arrival: ArrivalKind,
    /// Requests per burst (only used by [`ArrivalKind::Bursty`]).
    pub burst: u32,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            offered_mbps: None,
            arrival: ArrivalKind::Poisson,
            burst: 4,
        }
    }
}

/// Steady-state subsystem knobs (`[steady]` in TOML). Disabled by default:
/// with `enabled = false` every run behaves bit-identically to the
/// fresh-drive simulator (golden-tested), and the tuning defaults
/// reproduce the historical FTL constants exactly.
///
/// When enabled, the campaign switches to the sustained regime the paper's
/// fresh-drive tables cannot measure: the FTL is sized by `over_provision`
/// instead of `utilization`, the drive is preconditioned (logical space
/// filled, mapping-only, no simulated time), the workload becomes uniform
/// random over the logical volume so every write invalidates an old page,
/// and the coordinator feeds the chip's measured P/E spread back into
/// wear leveling (E7, `ddrnand sweep-steady`, EXPERIMENTS.md
/// §Steady-State).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SteadyConfig {
    /// Master switch for the steady-state regime.
    pub enabled: bool,
    /// Fraction of physical capacity reserved as GC headroom; the exported
    /// logical capacity is physical × (1 − over_provision). Only consulted
    /// when `enabled` (otherwise `utilization` sizes the FTL).
    pub over_provision: f64,
    /// GC triggers when a chip's free blocks fall to this threshold (≥ 2:
    /// relocation overflow headroom).
    pub gc_threshold_blocks: u32,
    /// FTL-internal static wear-leveling P/E-spread threshold.
    pub static_wl_threshold: u32,
    /// Coordinator-driven wear leveling: after each erase completes, if
    /// that chip's *measured* P/E spread (`Chip::wear_spread`) exceeds this,
    /// the FTL is asked to relocate its coldest full block. 0 disables the
    /// hook (the default — fresh-drive runs stay untouched).
    pub wear_level_spread: u32,
    /// Sequentially fill the logical space (mapping only, costless in
    /// simulated time) before the measured run, so GC reaches steady state
    /// inside the measured window.
    pub precondition: bool,
}

impl Default for SteadyConfig {
    fn default() -> Self {
        SteadyConfig {
            enabled: false,
            over_provision: 0.07,
            gc_threshold_blocks: 2,
            static_wl_threshold: 8,
            wear_level_spread: 0,
            precondition: true,
        }
    }
}

impl SteadyConfig {
    /// The GC headroom rule, shared by config validation, the E7 driver
    /// and the CLI pre-check (one source of truth): the over-provisioned
    /// spare must cover the GC trigger threshold plus one relocation
    /// block, or GC live-locks instead of reclaiming.
    pub fn gc_headroom_ok(&self, blocks_per_chip: u32) -> bool {
        blocks_per_chip as f64 * self.over_provision
            >= (self.gc_threshold_blocks + 1) as f64
    }

    /// The FTL-facing tuning view of this section. When the section is
    /// disabled, the historical defaults are returned regardless of the
    /// other fields — a dormant `[steady]` block (whose tuning values
    /// validation deliberately does not check) can never perturb
    /// fresh-drive behaviour (the bit-identity guarantee).
    pub fn tuning(&self) -> crate::controller::ftl::steady::GcTuning {
        if self.enabled {
            crate::controller::ftl::steady::GcTuning {
                gc_threshold_blocks: self.gc_threshold_blocks,
                static_wl_threshold: self.static_wl_threshold,
            }
        } else {
            crate::controller::ftl::steady::GcTuning::default()
        }
    }
}

/// Tiered-flash subsystem knobs (`[tiering]` in TOML). Disabled by
/// default: with `enabled = false` every run behaves bit-identically to
/// the homogeneous-array simulator (golden-tested).
///
/// When enabled, the drive becomes the combined SLC/MLC architecture of
/// multi-tiered SSD proposals (Batni & Safaei): a fraction of the chips
/// forms an **SLC write-buffer tier** — the base (MLC) geometry driven
/// with SLC-mode program/read latencies — in front of the remaining
/// **MLC capacity tier**. All host writes land in the SLC tier; when an
/// SLC chip runs low on free blocks, its *oldest* full block (fill-order
/// FIFO = coldest data) is migrated to the MLC tier as real DES copy-back
/// jobs that contend with host traffic, exactly like GC and wear
/// leveling do. Each tier may run its own controller↔flash interface
/// kind (E8, `ddrnand sweep-tiered`, EXPERIMENTS.md §Tiering).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TieringConfig {
    /// Master switch for the tiered-flash subsystem.
    pub enabled: bool,
    /// Fraction of chips assigned to the SLC tier, in (0, 1]. At least one
    /// chip is always SLC; a fraction of 1 makes every chip SLC-mode (no
    /// capacity tier, migration off).
    pub slc_fraction: f64,
    /// Interface kind of the SLC tier's channels; `None` = the top-level
    /// `iface`.
    pub slc_iface: Option<InterfaceKind>,
    /// Interface kind of the MLC tier's channels; `None` = the top-level
    /// `iface`.
    pub mlc_iface: Option<InterfaceKind>,
    /// Migration triggers when an SLC-tier chip's free blocks fall to this
    /// threshold. Must sit above the GC trigger so migration, not GC
    /// churn, is the SLC tier's primary reclamation path.
    pub migrate_free_blocks: u32,
}

impl Default for TieringConfig {
    fn default() -> Self {
        TieringConfig {
            enabled: false,
            slc_fraction: 0.25,
            slc_iface: None,
            mlc_iface: None,
            migrate_free_blocks: 4,
        }
    }
}

impl TieringConfig {
    /// Number of SLC-tier chips for an array of `chips` (0 when the
    /// subsystem is disabled). Shared by simulator construction and the
    /// sweep-reuse fingerprint so the two can never disagree.
    pub fn slc_chips(&self, chips: u32) -> u32 {
        if !self.enabled {
            0
        } else {
            ((chips as f64 * self.slc_fraction).round() as u32).clamp(1, chips)
        }
    }
}

/// Host-interface knobs (`[host]` in TOML). The default — a single SATA
/// stream — is bit-identical to the pre-multi-queue simulator
/// (golden-tested); selecting `multi_queue` switches the front end to N
/// NVMe-style submission queues with a per-queue depth and pluggable
/// queue arbitration (DESIGN.md §7, `ddrnand sweep-qos`). The `[sata]`
/// section's bandwidth/overhead parameters drive whichever link kind is
/// selected.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostConfig {
    /// Which link model fronts the device.
    pub link: HostLinkKind,
    /// Submission-queue count (multi-queue only). Stream ids in a trace
    /// must be below this.
    pub queues: u16,
    /// Per-queue depth for closed-loop admission (multi-queue only; the
    /// single-stream link uses the top-level `queue_depth`).
    pub queue_depth: u32,
    /// Queue-arbitration policy for closed-loop fetch.
    pub arbitration: QueueArb,
    /// Per-class weights (urgent, normal, bulk, background) consumed by
    /// weighted queue arbitration: a queue's share follows its stream's
    /// class weight.
    pub weights: [u32; NUM_CLASSES],
}

impl Default for HostConfig {
    fn default() -> Self {
        HostConfig {
            link: HostLinkKind::Sata,
            queues: 4,
            queue_depth: 8,
            arbitration: QueueArb::RoundRobin,
            weights: DEFAULT_CLASS_WEIGHTS,
        }
    }
}

impl HostConfig {
    /// The reuse-fingerprint view of this section: dormant fields are
    /// normalized away so a `[host]` block that selects the default SATA
    /// link can never fragment sweep reuse (mirrors the `[steady]` /
    /// `[tiering]` dormancy rule).
    pub fn reuse_sig(&self) -> (HostLinkKind, u16) {
        match self.link {
            HostLinkKind::Sata => (HostLinkKind::Sata, 0),
            HostLinkKind::MultiQueue => (HostLinkKind::MultiQueue, self.queues),
        }
    }
}

/// Way-scheduling / QoS knobs (`[qos]` in TOML). The default round-robin
/// policy is bit-identical to the historical hard-coded arbiter
/// (oracle-tested in `rust/tests/qos.rs`); see
/// [`crate::controller::sched`] for the policies.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosConfig {
    /// The way-scheduling policy every channel runs.
    pub scheduler: SchedKind,
    /// Per-class weights (urgent, normal, bulk, background) consumed by
    /// the `weighted_qos` policy. All must be positive: a zero weight
    /// would starve its class.
    pub weights: [u32; NUM_CLASSES],
}

impl Default for QosConfig {
    fn default() -> Self {
        QosConfig {
            scheduler: SchedKind::RoundRobin,
            weights: DEFAULT_CLASS_WEIGHTS,
        }
    }
}

impl QosConfig {
    /// The reuse-fingerprint view of this section (dormant weights are
    /// normalized away unless the weighted policy consumes them).
    pub fn reuse_sig(&self) -> (SchedKind, [u32; NUM_CLASSES]) {
        match self.scheduler {
            SchedKind::WeightedQos => (self.scheduler, self.weights),
            _ => (self.scheduler, DEFAULT_CLASS_WEIGHTS),
        }
    }
}

/// Execution-engine knobs (`[engine]` in TOML). The default — one thread,
/// derived window — runs the classic single-threaded engine and is
/// bit-identical to every prior release; any windowed setting dispatches
/// through the channel-sharded executor (one shard per channel, global
/// state serialized into a per-window commit step). Sharded results
/// depend on the window width — FTL job release is quantized to window
/// boundaries — but never on the thread count: reports are byte-identical
/// at threads 1/2/4 (golden-tested in `rust/tests/sharded_engine.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Worker threads for one simulation run. 1 = the classic engine
    /// (unless `window_ps` forces the sharded executor). Values beyond
    /// the channel count are clamped — one shard per channel — with a
    /// CLI note, never an error.
    pub threads: u16,
    /// Conservative window width in picoseconds. 0 derives the lookahead
    /// from the interface timing (the minimum bus phase,
    /// [`crate::iface::bus::BusTiming::min_phase`]).
    pub window_ps: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threads: 1, window_ps: 0 }
    }
}

impl EngineConfig {
    /// Whether the channel-sharded executor is selected at all.
    pub fn windowed(&self) -> bool {
        self.threads > 1 || self.window_ps > 0
    }

    /// The reuse-fingerprint view of this section. `threads = 0` is
    /// normalized to 1 so an explicit `[engine]` block spelling out the
    /// default can never fragment sweep reuse.
    pub fn reuse_sig(&self) -> (u16, u64) {
        (self.threads.max(1), self.window_ps)
    }
}

/// Bottleneck-observability knobs (`[observe]` in TOML; see
/// [`crate::observe`]). Disabled by default — and *bit-identical when
/// enabled*: the observer is strictly read-only over simulation state, so
/// the only thing `enabled` changes in a report is the presence of the
/// `observe` block (golden-tested in `rust/tests/observe.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ObserveConfig {
    /// Collect per-resource occupancy + stall-cause accounting.
    pub enabled: bool,
    /// Additionally buffer a Chrome trace-event timeline (Perfetto-
    /// loadable). Memory grows with event count — meant for small runs
    /// (`ddrnand analyze --trace`), not million-request campaigns.
    pub timeline: bool,
}

impl ObserveConfig {
    /// The reuse-fingerprint view of this section. `timeline` without
    /// `enabled` is normalized away so a dormant `[observe]` block can
    /// never fragment sweep reuse.
    pub fn reuse_sig(&self) -> (bool, bool) {
        (self.enabled, self.enabled && self.timeline)
    }
}

/// Which mapping-tier variant translates host addresses (`[mapping]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapMode {
    /// The whole mapping table is DRAM-resident; translation is free
    /// (the historical behaviour, and the default).
    Resident,
    /// DFTL-style demand paging: a map-cache miss defers the host op
    /// behind a real flash read of the translation page.
    Demand,
    /// FMMU-style hardware automation: the miss still issues the flash
    /// read (bus/way contention is real) but overlaps it with the host
    /// array access instead of deferring.
    Fmmu,
}

impl MapMode {
    pub fn parse(s: &str) -> Option<MapMode> {
        match s {
            "resident" => Some(MapMode::Resident),
            "demand" => Some(MapMode::Demand),
            "fmmu" => Some(MapMode::Fmmu),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MapMode::Resident => "resident",
            MapMode::Demand => "demand",
            MapMode::Fmmu => "fmmu",
        }
    }
}

/// Demand-paged mapping-tier knobs (`[mapping]` in TOML; see
/// [`crate::controller::ftl::demand`]). Resident by default: runs are
/// bit-identical to the fully-resident simulator (golden-tested) — and so
/// is any cache sized to hold every translation page, which initializes
/// warm and can never miss.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MappingConfig {
    /// Mapping-tier variant.
    pub mode: MapMode,
    /// Translation pages the map cache can hold.
    pub cache_pages: u64,
    /// lpn→ppn entries per translation page (the paging granularity).
    pub entries_per_page: u32,
}

impl Default for MappingConfig {
    fn default() -> Self {
        MappingConfig {
            mode: MapMode::Resident,
            cache_pages: 4096,
            entries_per_page: 1024,
        }
    }
}

impl MappingConfig {
    /// The reuse-fingerprint view of this section: a resident (dormant)
    /// block normalizes its sizing knobs away, so spelling out the default
    /// can never fragment sweep reuse (the `[steady]`/`[tiering]`/`[host]`
    /// dormancy rule).
    pub fn reuse_sig(&self) -> (MapMode, u64, u32) {
        match self.mode {
            MapMode::Resident => (MapMode::Resident, 0, 0),
            _ => (self.mode, self.cache_pages, self.entries_per_page),
        }
    }
}

/// Full configuration of one simulated SSD.
#[derive(Debug, Clone)]
pub struct SsdConfig {
    /// Controller↔flash interface under test.
    pub iface: InterfaceKind,
    /// Flash cell type (selects datasheet timing).
    pub cell: CellType,
    /// Number of channels (channel striping degree).
    pub channels: u16,
    /// Ways per channel (way interleaving degree).
    pub ways: u16,
    /// Blocks per chip (capacity knob for FTL experiments; the paper's
    /// bandwidth runs need only enough to hold the trace).
    pub blocks_per_chip: u32,
    /// Interface timing parameters (Table 2).
    pub params: IfaceParams,
    /// NAND timing override; `None` uses the datasheet values for `cell`.
    pub nand: Option<NandTiming>,
    /// Host link.
    pub sata: SataGen,
    /// Host queue depth (outstanding requests; SATA2 NCQ allows up to 32).
    pub queue_depth: u32,
    /// DRAM cache configuration.
    pub cache: CacheConfig,
    /// FTL scheme.
    pub ftl: FtlKind,
    /// Logical capacity as a fraction of physical (over-provisioning).
    pub utilization: f64,
    /// Extra controller-side bus occupancy after each program completes
    /// (status polling + FTL metadata); calibration constant.
    pub program_status_overhead: Ps,
    /// PRNG seed for workload/ordering decisions.
    pub seed: u64,
    /// Open-loop workload knobs (closed loop when unset).
    pub load: LoadConfig,
    /// Steady-state (sustained-load GC/wear-leveling) knobs; disabled by
    /// default, in which case runs are bit-identical to the fresh-drive
    /// simulator.
    pub steady: SteadyConfig,
    /// Tiered SLC/MLC flash knobs; disabled by default, in which case runs
    /// are bit-identical to the homogeneous-array simulator.
    pub tiering: TieringConfig,
    /// Host-interface knobs; the SATA default is bit-identical to the
    /// pre-multi-queue simulator.
    pub host: HostConfig,
    /// Way-scheduling / QoS knobs; the round-robin default is
    /// bit-identical to the historical arbiter.
    pub qos: QosConfig,
    /// Execution-engine knobs; the single-threaded default is bit-identical
    /// to every prior release. Windowed settings select the channel-sharded
    /// executor: window width is a fidelity knob, thread count never is.
    pub engine: EngineConfig,
    /// Bottleneck-observability knobs; disabled by default, and read-only
    /// over simulation state when enabled (observe-on runs stay
    /// bit-identical).
    pub observe: ObserveConfig,
    /// Demand-paged mapping-tier knobs; resident by default, in which
    /// case runs are bit-identical to the fully-resident simulator.
    pub mapping: MappingConfig,
}

impl Default for SsdConfig {
    fn default() -> Self {
        SsdConfig {
            iface: InterfaceKind::Proposed,
            cell: CellType::Slc,
            channels: 1,
            ways: 1,
            blocks_per_chip: 4096,
            params: IfaceParams::default(),
            nand: None,
            sata: SataGen::sata2(),
            queue_depth: 4,
            cache: CacheConfig::default(),
            ftl: FtlKind::PageMap,
            utilization: 0.9,
            program_status_overhead: Ps::us(2),
            seed: 0xDD12_7A5D,
            load: LoadConfig::default(),
            steady: SteadyConfig::default(),
            tiering: TieringConfig::default(),
            host: HostConfig::default(),
            qos: QosConfig::default(),
            engine: EngineConfig::default(),
            observe: ObserveConfig::default(),
            mapping: MappingConfig::default(),
        }
    }
}

impl SsdConfig {
    /// The paper's single-channel way-interleaving sweep point (Fig. 8).
    pub fn paper_way_sweep(iface: InterfaceKind, cell: CellType, ways: u16) -> SsdConfig {
        SsdConfig {
            iface,
            cell,
            channels: 1,
            ways,
            ..SsdConfig::default()
        }
    }

    /// The paper's constant-capacity channel sweep point (Fig. 9):
    /// channels × ways = 16.
    pub fn paper_channel_sweep(
        iface: InterfaceKind,
        cell: CellType,
        channels: u16,
    ) -> SsdConfig {
        assert!(16 % channels == 0, "channels must divide 16");
        SsdConfig {
            iface,
            cell,
            channels,
            ways: 16 / channels,
            ..SsdConfig::default()
        }
    }

    /// Effective NAND timing.
    pub fn nand_timing(&self) -> NandTiming {
        self.nand.unwrap_or_else(|| NandTiming::for_cell(self.cell))
    }

    /// Total chips in the array.
    pub fn chips(&self) -> u32 {
        self.channels as u32 * self.ways as u32
    }

    /// Exported logical capacity in pages for a given physical page count:
    /// sized by `steady.over_provision` in the steady-state regime, by
    /// `utilization` otherwise. Shared by simulator construction and the
    /// sweep-reuse fingerprint so the two can never disagree.
    pub fn logical_pages(&self, total_pages: u64) -> u64 {
        let fraction = if self.steady.enabled {
            1.0 - self.steady.over_provision
        } else {
            self.utilization
        };
        (total_pages as f64 * fraction) as u64
    }

    /// Validate invariants; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.channels == 0 {
            errs.push("channels must be >= 1".into());
        }
        if self.ways == 0 {
            errs.push("ways must be >= 1".into());
        }
        if self.blocks_per_chip < 4 {
            errs.push("blocks_per_chip must be >= 4 (need GC headroom)".into());
        }
        if !(0.0..=1.0).contains(&self.utilization) {
            errs.push("utilization must be in [0,1]".into());
        }
        if self.queue_depth == 0 {
            errs.push("queue_depth must be >= 1".into());
        }
        // Geometry arithmetic and capacity sizing must be checked here,
        // not asserted at FTL construction: a config that passes
        // validation may never panic when built (regression-tested in
        // this module). The checked chain catches products that would
        // wrap u64; the capacity check catches f64 sizing that rounds the
        // logical page count past the physical array.
        let total_pages = (self.chips() as u64)
            .checked_mul(self.blocks_per_chip as u64)
            .and_then(|b| b.checked_mul(self.nand_timing().pages_per_block as u64));
        match total_pages {
            None => errs.push(
                "geometry overflows: channels x ways x blocks_per_chip x pages_per_block \
                 exceeds u64"
                    .into(),
            ),
            Some(total) => {
                if self.logical_pages(total) > total {
                    errs.push(format!(
                        "logical capacity ({} pages) exceeds physical ({} pages): lower \
                         utilization or raise over-provisioning",
                        self.logical_pages(total),
                        total
                    ));
                }
            }
        }
        if self.mapping.mode != MapMode::Resident {
            if self.ftl != FtlKind::PageMap {
                errs.push("mapping.mode requires ftl = \"page_map\"".into());
            }
            if self.tiering.enabled {
                errs.push(
                    "mapping.mode cannot combine with tiering.enabled (the tiered FTL \
                     keeps its own resident tables)"
                        .into(),
                );
            }
            if self.mapping.cache_pages == 0 {
                errs.push("mapping.cache_pages must be >= 1".into());
            }
            if self.mapping.entries_per_page == 0 {
                errs.push("mapping.entries_per_page must be >= 1".into());
            }
            if let Some(total) = total_pages {
                let tpages = self
                    .logical_pages(total)
                    .div_ceil(self.mapping.entries_per_page.max(1) as u64);
                if tpages >= u32::MAX as u64 {
                    errs.push(format!(
                        "mapping: {tpages} translation pages overflow the cache directory \
                         (raise entries_per_page)"
                    ));
                }
            }
        }
        if !(0.0..=0.5).contains(&self.params.alpha) {
            errs.push("alpha must be in [0, 1/2] (Eq. 1)".into());
        }
        // Degenerate timing parameters (all-zero TOML, negative deltas)
        // would otherwise surface as a 0 MHz clock and a divide-by-zero
        // deep in the bus model.
        errs.extend(self.params.validate());
        // A non-positive link rate would divide by zero (or stall forever)
        // in the integer transfer-time arithmetic.
        if !(self.sata.bandwidth_mbps > 0.0 && self.sata.bandwidth_mbps.is_finite()) {
            errs.push("sata.bandwidth_mbps must be a positive number".into());
        }
        if self.host.link == HostLinkKind::MultiQueue {
            if self.host.queues == 0 {
                errs.push("host.queues must be >= 1".into());
            }
            if self.host.queues > 4096 {
                errs.push("host.queues must be <= 4096".into());
            }
            if self.host.queue_depth == 0 {
                errs.push("host.queue_depth must be >= 1".into());
            }
            if self.host.arbitration == QueueArb::Weighted
                && self.host.weights.contains(&0)
            {
                errs.push(
                    "host.weights must all be >= 1 (a zero weight starves its class)".into(),
                );
            }
        }
        if self.qos.scheduler == SchedKind::WeightedQos && self.qos.weights.contains(&0) {
            errs.push("qos.weights must all be >= 1 (a zero weight starves its class)".into());
        }
        if self.engine.threads == 0 {
            errs.push("engine.threads must be >= 1".into());
        }
        if self.engine.threads > 256 {
            errs.push("engine.threads must be <= 256".into());
        }
        if self.observe.timeline && !self.observe.enabled {
            errs.push(
                "observe.timeline requires observe.enabled = true (a timeline without \
                 the occupancy accounting it annotates has nothing to validate against)"
                    .into(),
            );
        }
        if let Some(mbps) = self.load.offered_mbps {
            if !(mbps > 0.0 && mbps.is_finite()) {
                errs.push("load.offered_mbps must be a positive number".into());
            }
        }
        if self.load.burst == 0 {
            errs.push("load.burst must be >= 1".into());
        }
        if self.steady.enabled {
            if self.ftl == FtlKind::Hybrid {
                errs.push(
                    "steady.enabled requires ftl = \"page_map\" (the hybrid FTL's \
                     log-block reserve fixes its own exported capacity)"
                        .into(),
                );
            }
            if !(self.steady.over_provision > 0.0 && self.steady.over_provision < 0.5) {
                errs.push("steady.over_provision must be in (0, 0.5)".into());
            }
            if self.steady.gc_threshold_blocks < 2 {
                errs.push("steady.gc_threshold_blocks must be >= 2 (relocation headroom)".into());
            }
            if !self.steady.gc_headroom_ok(self.blocks_per_chip) {
                errs.push(
                    "steady.over_provision too small for blocks_per_chip: GC needs spare \
                     blocks beyond the trigger threshold"
                        .into(),
                );
            }
        }
        if self.tiering.enabled {
            if self.cell != CellType::Mlc {
                errs.push(
                    "tiering.enabled requires cell = \"mlc\" (the SLC tier is the MLC \
                     geometry driven with SLC-mode latencies)"
                        .into(),
                );
            }
            if self.ftl != FtlKind::PageMap {
                errs.push("tiering.enabled requires ftl = \"page_map\"".into());
            }
            if self.chips() < 2 {
                errs.push("tiering needs at least 2 chips (channels x ways >= 2)".into());
            }
            if !(self.tiering.slc_fraction > 0.0 && self.tiering.slc_fraction <= 1.0) {
                errs.push("tiering.slc_fraction must be in (0, 1]".into());
            }
            let gc_floor = self.steady.tuning().gc_threshold_blocks;
            if self.tiering.migrate_free_blocks <= gc_floor {
                errs.push(format!(
                    "tiering.migrate_free_blocks must exceed the GC trigger threshold \
                     ({gc_floor}) so migration, not GC churn, reclaims the SLC tier"
                ));
            }
            if self.tiering.migrate_free_blocks >= self.blocks_per_chip {
                errs.push("tiering.migrate_free_blocks must be < blocks_per_chip".into());
            }
            // Capacity feasibility in the worst case (fully-valid data,
            // nothing for GC to reclaim — a sequential preconditioning
            // fill): migration refuses to fill an MLC chip past its
            // reserve (GC floor + 2 blocks), and the SLC tier can park
            // blocks down to its own GC floor + 1. If the exported
            // logical volume exceeds what both tiers can hold under those
            // rules, the run would panic mid-fill with "over-provisioning
            // exhausted" — reject it at config load instead.
            let nand = self.nand_timing();
            let ppb = nand.pages_per_block as u64;
            let blocks = self.blocks_per_chip as u64;
            let chips = self.chips() as u64;
            let slc = self.tiering.slc_chips(self.chips()) as u64;
            let mlc = chips - slc;
            let gc = self.steady.tuning().gc_threshold_blocks as u64;
            let park_blocks =
                slc * blocks.saturating_sub(gc + 1) + mlc * blocks.saturating_sub(gc + 2);
            let logical = self.logical_pages(chips * blocks * ppb);
            if logical > park_blocks * ppb {
                errs.push(format!(
                    "tiering: logical capacity ({} pages) exceeds what the tiers can \
                     hold with fully-valid data ({} pages: SLC parks to its GC floor, \
                     migration stops at the MLC reserve) — raise over-provisioning, \
                     lower utilization, or grow the MLC tier",
                    logical,
                    park_blocks * ppb
                ));
            }
        }
        errs
    }

    /// Load from the TOML subset. Unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> Result<SsdConfig, String> {
        let doc = toml::parse(text)?;
        let mut cfg = SsdConfig::default();
        let iface_of = |key: &str, val: &toml::Value| -> Result<InterfaceKind, String> {
            match val.as_str() {
                Some("conv") | Some("CONV") => Ok(InterfaceKind::Conv),
                Some("sync_only") | Some("SYNC_ONLY") => Ok(InterfaceKind::SyncOnly),
                Some("proposed") | Some("PROPOSED") => Ok(InterfaceKind::Proposed),
                other => Err(format!("bad {key} {other:?}")),
            }
        };
        for (key, val) in &doc.entries {
            match key.as_str() {
                "iface" => cfg.iface = iface_of(key, val)?,
                "cell" => {
                    cfg.cell = match val.as_str() {
                        Some("slc") | Some("SLC") => CellType::Slc,
                        Some("mlc") | Some("MLC") => CellType::Mlc,
                        other => return Err(format!("bad cell {other:?}")),
                    }
                }
                "channels" => cfg.channels = req_u16(key, val)?,
                "ways" => cfg.ways = req_u16(key, val)?,
                "blocks_per_chip" => cfg.blocks_per_chip = req_u32(key, val)?,
                "queue_depth" => cfg.queue_depth = req_u32(key, val)?,
                "utilization" => cfg.utilization = req_f64(key, val)?,
                "seed" => cfg.seed = req_u64(key, val)?,
                "ftl" => {
                    cfg.ftl = match val.as_str() {
                        Some("page_map") => FtlKind::PageMap,
                        Some("hybrid") => FtlKind::Hybrid,
                        other => return Err(format!("bad ftl {other:?}")),
                    }
                }
                "params.alpha" => cfg.params.alpha = req_f64(key, val)?,
                "params.t_byte_ns" => cfg.params.t_byte_ns = req_f64(key, val)?,
                "params.t_diff_ns" => cfg.params.t_diff_ns = req_f64(key, val)?,
                "params.t_rea_ns" => cfg.params.t_rea_ns = req_f64(key, val)?,
                "params.t_out_ns" => cfg.params.t_out_ns = req_f64(key, val)?,
                "params.t_in_ns" => cfg.params.t_in_ns = req_f64(key, val)?,
                "sata.bandwidth_mbps" => cfg.sata.bandwidth_mbps = req_f64(key, val)?,
                "sata.command_overhead_us" => {
                    cfg.sata.command_overhead = Ps::from_us_f64(req_f64(key, val)?)
                }
                "load.offered_mbps" => cfg.load.offered_mbps = Some(req_f64(key, val)?),
                "load.arrival" => {
                    cfg.load.arrival = match val.as_str() {
                        Some("poisson") => ArrivalKind::Poisson,
                        Some("bursty") => ArrivalKind::Bursty,
                        other => return Err(format!("bad load.arrival {other:?}")),
                    }
                }
                "load.burst" => cfg.load.burst = req_u32(key, val)?,
                "steady.enabled" => {
                    cfg.steady.enabled =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "steady.over_provision" => cfg.steady.over_provision = req_f64(key, val)?,
                "steady.gc_threshold_blocks" => {
                    cfg.steady.gc_threshold_blocks = req_u32(key, val)?
                }
                "steady.static_wl_threshold" => {
                    cfg.steady.static_wl_threshold = req_u32(key, val)?
                }
                "steady.wear_level_spread" => {
                    cfg.steady.wear_level_spread = req_u32(key, val)?
                }
                "steady.precondition" => {
                    cfg.steady.precondition =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "tiering.enabled" => {
                    cfg.tiering.enabled =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "tiering.slc_fraction" => cfg.tiering.slc_fraction = req_f64(key, val)?,
                "tiering.slc_iface" => cfg.tiering.slc_iface = Some(iface_of(key, val)?),
                "tiering.mlc_iface" => cfg.tiering.mlc_iface = Some(iface_of(key, val)?),
                "tiering.migrate_free_blocks" => {
                    cfg.tiering.migrate_free_blocks = req_u32(key, val)?
                }
                "cache.capacity_pages" => cfg.cache.capacity_pages = req_u32(key, val)?,
                "cache.write_back" => {
                    cfg.cache.write_back =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "host.link" => {
                    cfg.host.link = val
                        .as_str()
                        .and_then(HostLinkKind::parse)
                        .ok_or_else(|| format!("bad host.link {val:?} (sata|multi_queue)"))?
                }
                "host.queues" => cfg.host.queues = req_u16(key, val)?,
                "host.queue_depth" => cfg.host.queue_depth = req_u32(key, val)?,
                "host.arbitration" => {
                    cfg.host.arbitration = val
                        .as_str()
                        .and_then(QueueArb::parse)
                        .ok_or_else(|| {
                            format!("bad host.arbitration {val:?} (round_robin|weighted)")
                        })?
                }
                "host.weights" => cfg.host.weights = req_weights(key, val)?,
                "qos.way_scheduler" => {
                    cfg.qos.scheduler = val.as_str().and_then(SchedKind::parse).ok_or_else(
                        || {
                            format!(
                                "bad qos.way_scheduler {val:?} \
                                 (round_robin|read_priority|weighted_qos)"
                            )
                        },
                    )?
                }
                "qos.weights" => cfg.qos.weights = req_weights(key, val)?,
                "engine.threads" => cfg.engine.threads = req_u16(key, val)?,
                "engine.window_ps" => cfg.engine.window_ps = req_u64(key, val)?,
                "observe.enabled" => {
                    cfg.observe.enabled =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "observe.timeline" => {
                    cfg.observe.timeline =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                "mapping.mode" => {
                    cfg.mapping.mode = val
                        .as_str()
                        .and_then(MapMode::parse)
                        .ok_or_else(|| {
                            format!("bad mapping.mode {val:?} (resident|demand|fmmu)")
                        })?
                }
                "mapping.cache_pages" => cfg.mapping.cache_pages = req_u64(key, val)?,
                "mapping.entries_per_page" => {
                    cfg.mapping.entries_per_page = req_u32(key, val)?
                }
                other => return Err(format!("unknown config key: {other}")),
            }
        }
        let errs = cfg.validate();
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        Ok(cfg)
    }
}

fn req_f64(key: &str, v: &toml::Value) -> Result<f64, String> {
    v.as_float().ok_or_else(|| format!("{key}: want number"))
}
fn req_u64(key: &str, v: &toml::Value) -> Result<u64, String> {
    v.as_int()
        .filter(|&i| i >= 0)
        .map(|i| i as u64)
        .ok_or_else(|| format!("{key}: want non-negative integer"))
}
fn req_u32(key: &str, v: &toml::Value) -> Result<u32, String> {
    req_u64(key, v)?
        .try_into()
        .map_err(|_| format!("{key}: out of range"))
}
fn req_u16(key: &str, v: &toml::Value) -> Result<u16, String> {
    req_u64(key, v)?
        .try_into()
        .map_err(|_| format!("{key}: out of range"))
}
/// Per-class weight vector: exactly [`NUM_CLASSES`] non-negative integers
/// (e.g. `weights = [8, 4, 2, 1]`); positivity is checked by `validate`
/// only where the weights are actually consumed.
fn req_weights(key: &str, v: &toml::Value) -> Result<[u32; NUM_CLASSES], String> {
    let toml::Value::Array(items) = v else {
        return Err(format!("{key}: want an array of {NUM_CLASSES} integers"));
    };
    if items.len() != NUM_CLASSES {
        return Err(format!(
            "{key}: want exactly {NUM_CLASSES} per-class weights, got {}",
            items.len()
        ));
    }
    let mut out = [0u32; NUM_CLASSES];
    for (slot, item) in out.iter_mut().zip(items) {
        *slot = item
            .as_int()
            .filter(|&i| (0..=1_000_000).contains(&i))
            .ok_or_else(|| format!("{key}: weights must be integers in 0..=1000000"))?
            as u32;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(SsdConfig::default().validate().is_empty());
    }

    #[test]
    fn paper_presets() {
        let c = SsdConfig::paper_way_sweep(InterfaceKind::Conv, CellType::Slc, 16);
        assert_eq!(c.channels, 1);
        assert_eq!(c.ways, 16);
        let c = SsdConfig::paper_channel_sweep(InterfaceKind::Proposed, CellType::Mlc, 4);
        assert_eq!((c.channels, c.ways), (4, 4));
        assert_eq!(c.chips(), 16);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = SsdConfig::from_toml(
            r#"
iface = "proposed"
cell = "mlc"
channels = 2
ways = 8
queue_depth = 8
[sata]
bandwidth_mbps = 600.0
[cache]
capacity_pages = 1024
"#,
        )
        .unwrap();
        assert_eq!(cfg.iface, InterfaceKind::Proposed);
        assert_eq!(cfg.cell, CellType::Mlc);
        assert_eq!((cfg.channels, cfg.ways), (2, 8));
        assert_eq!(cfg.sata.bandwidth_mbps, 600.0);
        assert_eq!(cfg.cache.capacity_pages, 1024);
    }

    #[test]
    fn load_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
iface = "proposed"
[load]
offered_mbps = 120.5
arrival = "bursty"
burst = 8
"#,
        )
        .unwrap();
        assert_eq!(cfg.load.offered_mbps, Some(120.5));
        assert_eq!(cfg.load.arrival, ArrivalKind::Bursty);
        assert_eq!(cfg.load.burst, 8);
        // Closed loop by default.
        assert_eq!(SsdConfig::default().load.offered_mbps, None);
        // Bad values rejected.
        assert!(SsdConfig::from_toml("[load]\noffered_mbps = -3.0").is_err());
        assert!(SsdConfig::from_toml("[load]\nburst = 0").is_err());
        assert!(SsdConfig::from_toml("[load]\narrival = \"uniform\"").is_err());
    }

    #[test]
    fn steady_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
blocks_per_chip = 128
[steady]
enabled = true
over_provision = 0.07
gc_threshold_blocks = 3
static_wl_threshold = 6
wear_level_spread = 16
precondition = false
"#,
        )
        .unwrap();
        assert!(cfg.steady.enabled);
        assert_eq!(cfg.steady.over_provision, 0.07);
        assert_eq!(cfg.steady.gc_threshold_blocks, 3);
        assert_eq!(cfg.steady.static_wl_threshold, 6);
        assert_eq!(cfg.steady.wear_level_spread, 16);
        assert!(!cfg.steady.precondition);
        // Disabled by default, and the tuning defaults are the historical
        // constants (bit-identity anchor).
        let d = SsdConfig::default();
        assert!(!d.steady.enabled);
        assert_eq!(d.steady.tuning().gc_threshold_blocks, 2);
        assert_eq!(d.steady.tuning().static_wl_threshold, 8);
        // A dormant section's tuning values must not leak into disabled
        // runs: tuning() hands back the defaults until enabled.
        let mut dormant = SsdConfig::default();
        dormant.steady.gc_threshold_blocks = 0;
        dormant.steady.static_wl_threshold = 0;
        assert!(dormant.validate().is_empty(), "dormant tuning not validated");
        assert_eq!(dormant.steady.tuning().gc_threshold_blocks, 2);
        assert_eq!(dormant.steady.tuning().static_wl_threshold, 8);
        dormant.steady.enabled = true;
        assert!(!dormant.validate().is_empty(), "enabled tuning is validated");
        // The hybrid FTL sizes its own capacity; steady sizing is rejected.
        assert!(SsdConfig::from_toml(
            "ftl = \"hybrid\"\nblocks_per_chip = 128\n[steady]\nenabled = true"
        )
        .is_err());
        // Bad values rejected (only when the section is enabled).
        assert!(
            SsdConfig::from_toml("[steady]\nenabled = true\nover_provision = 0.9").is_err()
        );
        assert!(SsdConfig::from_toml(
            "blocks_per_chip = 128\n[steady]\nenabled = true\ngc_threshold_blocks = 1"
        )
        .is_err());
        // 7% of 16 blocks cannot cover threshold+1 spare blocks.
        assert!(SsdConfig::from_toml(
            "blocks_per_chip = 16\n[steady]\nenabled = true\nover_provision = 0.07"
        )
        .is_err());
        assert!(SsdConfig::from_toml("[steady]\nover_provision = 0.9").is_ok());
    }

    #[test]
    fn tiering_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
cell = "mlc"
channels = 2
ways = 4
[tiering]
enabled = true
slc_fraction = 0.5
slc_iface = "proposed"
mlc_iface = "conv"
migrate_free_blocks = 5
"#,
        )
        .unwrap();
        assert!(cfg.tiering.enabled);
        assert_eq!(cfg.tiering.slc_fraction, 0.5);
        assert_eq!(cfg.tiering.slc_iface, Some(InterfaceKind::Proposed));
        assert_eq!(cfg.tiering.mlc_iface, Some(InterfaceKind::Conv));
        assert_eq!(cfg.tiering.migrate_free_blocks, 5);
        assert_eq!(cfg.tiering.slc_chips(cfg.chips()), 4);
        // Disabled by default and dormant sections cost nothing.
        let d = SsdConfig::default();
        assert!(!d.tiering.enabled);
        assert_eq!(d.tiering.slc_chips(d.chips()), 0);
        assert!(SsdConfig::from_toml("[tiering]\nslc_fraction = 0.9").is_ok());
        // The SLC tier always gets at least one chip, never all of them
        // unless asked.
        let t = TieringConfig {
            enabled: true,
            slc_fraction: 0.01,
            ..TieringConfig::default()
        };
        assert_eq!(t.slc_chips(4), 1);
        let t = TieringConfig {
            enabled: true,
            slc_fraction: 1.0,
            ..TieringConfig::default()
        };
        assert_eq!(t.slc_chips(4), 4);
        // Bad values rejected (only when enabled).
        let tiered = |body: &str| {
            SsdConfig::from_toml(&format!("cell = \"mlc\"\nways = 4\n{body}"))
        };
        assert!(tiered("[tiering]\nenabled = true").is_ok());
        assert!(tiered("[tiering]\nenabled = true\nslc_fraction = 0.0").is_err());
        assert!(tiered("[tiering]\nenabled = true\nslc_fraction = 1.5").is_err());
        assert!(tiered("[tiering]\nenabled = true\nmigrate_free_blocks = 2").is_err());
        assert!(tiered("[tiering]\nenabled = true\nslc_iface = \"quantum\"").is_err());
        // The SLC tier needs the MLC geometry, a page-map FTL and >= 2 chips.
        assert!(SsdConfig::from_toml("cell = \"slc\"\nways = 4\n[tiering]\nenabled = true")
            .is_err());
        assert!(SsdConfig::from_toml(
            "cell = \"mlc\"\nways = 4\nftl = \"hybrid\"\n[tiering]\nenabled = true"
        )
        .is_err());
        assert!(SsdConfig::from_toml("cell = \"mlc\"\n[tiering]\nenabled = true").is_err());
        // Capacity feasibility: a tiny SLC tier on a tight volume cannot
        // park fully-valid data — 8 chips x 32 blocks at 10% OP exports
        // 230.4 blocks, but 1 SLC chip (parks 29) + 7 MLC chips (absorb
        // 28 each) hold only 225. Must be a load error, not a mid-run
        // panic.
        let err = SsdConfig::from_toml(
            "cell = \"mlc\"\nways = 8\nblocks_per_chip = 32\n\
             [steady]\nenabled = true\nover_provision = 0.1\n\
             [tiering]\nenabled = true\nslc_fraction = 0.125",
        )
        .unwrap_err();
        assert!(err.contains("logical capacity"), "{err}");
        // The same partition with more blocks per chip fits (the reserve
        // is a fixed block count, so it amortizes).
        assert!(SsdConfig::from_toml(
            "cell = \"mlc\"\nways = 8\nblocks_per_chip = 64\n\
             [steady]\nenabled = true\nover_provision = 0.1\n\
             [tiering]\nenabled = true\nslc_fraction = 0.125",
        )
        .is_ok());
    }

    #[test]
    fn host_and_qos_sections_parse_and_validate() {
        let cfg = SsdConfig::from_toml(
            r#"
ways = 4
[host]
link = "multi_queue"
queues = 2
queue_depth = 16
arbitration = "weighted"
weights = [9, 4, 2, 1]
[qos]
way_scheduler = "weighted_qos"
weights = [6, 3, 2, 1]
"#,
        )
        .unwrap();
        assert_eq!(cfg.host.link, HostLinkKind::MultiQueue);
        assert_eq!(cfg.host.queues, 2);
        assert_eq!(cfg.host.queue_depth, 16);
        assert_eq!(cfg.host.arbitration, QueueArb::Weighted);
        assert_eq!(cfg.host.weights, [9, 4, 2, 1]);
        assert_eq!(cfg.qos.scheduler, SchedKind::WeightedQos);
        assert_eq!(cfg.qos.weights, [6, 3, 2, 1]);
        // Defaults: single SATA stream, round-robin arbiter.
        let d = SsdConfig::default();
        assert_eq!(d.host.link, HostLinkKind::Sata);
        assert_eq!(d.qos.scheduler, SchedKind::RoundRobin);
        assert!(d.validate().is_empty());
        // Bad values rejected.
        assert!(SsdConfig::from_toml("[host]\nlink = \"warp\"").is_err());
        assert!(
            SsdConfig::from_toml("[host]\nlink = \"multi_queue\"\nqueues = 0").is_err()
        );
        assert!(
            SsdConfig::from_toml("[host]\nlink = \"multi_queue\"\nqueue_depth = 0").is_err()
        );
        assert!(SsdConfig::from_toml("[host]\narbitration = \"lifo\"").is_err());
        assert!(SsdConfig::from_toml("[host]\nweights = [1, 2, 3]").is_err());
        assert!(SsdConfig::from_toml("[qos]\nway_scheduler = \"random\"").is_err());
        assert!(SsdConfig::from_toml(
            "[qos]\nway_scheduler = \"weighted_qos\"\nweights = [8, 0, 2, 1]"
        )
        .is_err());
        // Dormant sections are not over-validated: zero weights are fine
        // while nothing consumes them (the bit-identity dormancy rule)...
        let dormant =
            SsdConfig::from_toml("[qos]\nweights = [0, 0, 0, 0]").unwrap();
        assert!(dormant.validate().is_empty());
        // ...and they normalize out of the reuse fingerprint.
        assert_eq!(dormant.qos.reuse_sig(), SsdConfig::default().qos.reuse_sig());
        let mut h = SsdConfig::default();
        h.host.queues = 99;
        assert_eq!(h.host.reuse_sig(), SsdConfig::default().host.reuse_sig());
    }

    #[test]
    fn engine_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
[engine]
threads = 4
window_ps = 500000
"#,
        )
        .unwrap();
        assert_eq!(cfg.engine.threads, 4);
        assert_eq!(cfg.engine.window_ps, 500_000);
        assert!(cfg.engine.windowed());
        // Default: classic single-threaded engine, derived window.
        let d = SsdConfig::default();
        assert_eq!(d.engine, EngineConfig { threads: 1, window_ps: 0 });
        assert!(!d.engine.windowed());
        // Bad values rejected.
        assert!(SsdConfig::from_toml("[engine]\nthreads = 0").is_err());
        assert!(SsdConfig::from_toml("[engine]\nthreads = 1000").is_err());
        assert!(SsdConfig::from_toml("[engine]\nwindow_ps = -5").is_err());
        // An explicit default block normalizes out of the fingerprint.
        let explicit =
            SsdConfig::from_toml("[engine]\nthreads = 1\nwindow_ps = 0").unwrap();
        assert_eq!(explicit.engine.reuse_sig(), d.engine.reuse_sig());
    }

    #[test]
    fn observe_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
[observe]
enabled = true
timeline = true
"#,
        )
        .unwrap();
        assert!(cfg.observe.enabled);
        assert!(cfg.observe.timeline);
        // Default: observation off, and absent from reports.
        let d = SsdConfig::default();
        assert_eq!(d.observe, ObserveConfig::default());
        assert!(!d.observe.enabled);
        // Bad values rejected: non-bool, and a timeline without the
        // accounting it annotates.
        assert!(SsdConfig::from_toml("[observe]\nenabled = 3").is_err());
        assert!(SsdConfig::from_toml("[observe]\ntimeline = true").is_err());
        // A dormant block normalizes out of the fingerprint: `timeline`
        // is meaningless while disabled and must not fragment reuse.
        let dormant =
            SsdConfig::from_toml("[observe]\nenabled = false\ntimeline = false").unwrap();
        assert_eq!(dormant.observe.reuse_sig(), d.observe.reuse_sig());
        let mut t = d.observe;
        t.timeline = true;
        assert_eq!(t.reuse_sig(), d.observe.reuse_sig());
    }

    #[test]
    fn mapping_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
ways = 4
[mapping]
mode = "demand"
cache_pages = 64
entries_per_page = 512
"#,
        )
        .unwrap();
        assert_eq!(cfg.mapping.mode, MapMode::Demand);
        assert_eq!(cfg.mapping.cache_pages, 64);
        assert_eq!(cfg.mapping.entries_per_page, 512);
        assert_eq!(
            SsdConfig::from_toml("[mapping]\nmode = \"fmmu\"").unwrap().mapping.mode,
            MapMode::Fmmu
        );
        // Resident by default; a dormant block normalizes its sizing
        // knobs out of the reuse fingerprint.
        let d = SsdConfig::default();
        assert_eq!(d.mapping.mode, MapMode::Resident);
        let dormant = SsdConfig::from_toml(
            "[mapping]\nmode = \"resident\"\ncache_pages = 7\nentries_per_page = 3",
        )
        .unwrap();
        assert_eq!(dormant.mapping.reuse_sig(), d.mapping.reuse_sig());
        // Dormant sizing knobs are not over-validated...
        assert!(SsdConfig::from_toml("[mapping]\ncache_pages = 0").is_ok());
        // ...but active ones are.
        assert!(
            SsdConfig::from_toml("[mapping]\nmode = \"demand\"\ncache_pages = 0").is_err()
        );
        assert!(SsdConfig::from_toml(
            "[mapping]\nmode = \"demand\"\nentries_per_page = 0"
        )
        .is_err());
        assert!(SsdConfig::from_toml("[mapping]\nmode = \"virtual\"").is_err());
        // The tier pages the page-map FTL's table and cannot combine with
        // the tiered FTL's resident scheme.
        assert!(
            SsdConfig::from_toml("ftl = \"hybrid\"\n[mapping]\nmode = \"demand\"").is_err()
        );
        assert!(SsdConfig::from_toml(
            "cell = \"mlc\"\nways = 4\n[tiering]\nenabled = true\n\
             [mapping]\nmode = \"fmmu\""
        )
        .is_err());
    }

    /// Regression (was a construction-time panic): geometry products that
    /// wrap u64 must be config-load errors, not debug-overflow panics or
    /// silently-wrapped capacities deep in `PageMapFtl::new`.
    #[test]
    fn overflowing_geometry_rejected_at_load() {
        let err = SsdConfig::from_toml(
            "channels = 65535\nways = 65535\nblocks_per_chip = 4000000000",
        )
        .unwrap_err();
        assert!(err.contains("geometry overflows"), "{err}");
        // The same shape through validate() directly (no TOML involved).
        let mut c = SsdConfig::default();
        c.channels = u16::MAX;
        c.ways = u16::MAX;
        c.blocks_per_chip = u32::MAX;
        assert!(c.validate().iter().any(|e| e.contains("geometry overflows")));
    }

    /// Regression (was `assert!(logical_pages <= total_pages)` inside
    /// `PageMapFtl::new`): capacity sizing that exceeds the physical array
    /// must surface as a validation error.
    #[test]
    fn oversized_logical_capacity_rejected_at_load() {
        let mut c = SsdConfig::default();
        c.utilization = 1.5; // already invalid on its own...
        assert!(!c.validate().is_empty());
        // ...and the capacity check reports independently of the range
        // check, so any sizing path that rounds past physical is caught.
        let total = c.chips() as u64
            * c.blocks_per_chip as u64
            * c.nand_timing().pages_per_block as u64;
        assert!(c.logical_pages(total) > total);
        assert!(c
            .validate()
            .iter()
            .any(|e| e.contains("exceeds physical")));
    }

    #[test]
    fn degenerate_sata_bandwidth_rejected_at_load() {
        assert!(SsdConfig::from_toml("[sata]\nbandwidth_mbps = 0.0").is_err());
        assert!(SsdConfig::from_toml("[sata]\nbandwidth_mbps = -300.0").is_err());
    }

    /// Regression: the all-zero interface-parameter TOML must be rejected
    /// at load, before any simulator derives a 0 MHz clock from it.
    #[test]
    fn degenerate_iface_params_rejected_at_load() {
        let err = SsdConfig::from_toml(
            "[params]\nt_out_ns = 0.0\nt_in_ns = 0.0\nt_rea_ns = 0.0\nt_byte_ns = 0.0\n\
             t_diff_ns = 0.0",
        )
        .unwrap_err();
        assert!(err.contains("t_byte_ns"), "{err}");
        assert!(SsdConfig::from_toml("[params]\nt_rea_ns = -5.0").is_err());
        // A period above 1 us floors to 0 MHz: caught by validation.
        assert!(SsdConfig::from_toml("[params]\nt_byte_ns = 2000.0").is_err());
    }

    #[test]
    fn logical_pages_follows_regime() {
        let mut c = SsdConfig::default();
        c.utilization = 0.9;
        assert_eq!(c.logical_pages(1000), 900);
        c.steady.enabled = true;
        c.steady.over_provision = 0.07;
        assert_eq!(c.logical_pages(1000), 930);
    }

    #[test]
    fn unknown_key_rejected() {
        let err = SsdConfig::from_toml("wayz = 4").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(SsdConfig::from_toml("channels = 0").is_err());
        assert!(SsdConfig::from_toml("utilization = 1.5").is_err());
        assert!(SsdConfig::from_toml(r#"iface = "quantum""#).is_err());
    }

    #[test]
    fn nand_timing_follows_cell() {
        let mut c = SsdConfig::default();
        c.cell = CellType::Mlc;
        assert_eq!(c.nand_timing(), NandTiming::mlc());
        c.nand = Some(NandTiming::slc());
        assert_eq!(c.nand_timing(), NandTiming::slc());
    }
}
