//! SSD configuration schema, presets and TOML loading.

pub mod toml;

use crate::controller::cache::CacheConfig;
use crate::host::sata::SataGen;
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::nand::datasheet::{CellType, NandTiming};
use crate::util::time::Ps;

/// Which FTL mapping scheme to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtlKind {
    /// Page-level mapping with striped allocation (default; maximal
    /// interleaving on sequential workloads).
    PageMap,
    /// BAST-style hybrid log-block mapping [9].
    Hybrid,
}

/// Arrival process for open-loop (arrival-driven) workloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Exponential inter-arrival gaps — a memoryless offered load.
    Poisson,
    /// Back-to-back groups of [`LoadConfig::burst`] requests whose group
    /// starts form a Poisson process at the same mean byte rate.
    Bursty,
}

/// Open-loop workload knobs (`[load]` in TOML). With `offered_mbps`
/// unset the workload is closed loop (queue-depth driven), the paper's
/// regime; setting it turns the run arrival-driven so latency under
/// sustained load is measurable (EXPERIMENTS.md §Load, `ddrnand
/// sweep-load`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadConfig {
    /// Offered load in MB/s (decimal); `None` = closed loop.
    pub offered_mbps: Option<f64>,
    /// Arrival process shape.
    pub arrival: ArrivalKind,
    /// Requests per burst (only used by [`ArrivalKind::Bursty`]).
    pub burst: u32,
}

impl Default for LoadConfig {
    fn default() -> Self {
        LoadConfig {
            offered_mbps: None,
            arrival: ArrivalKind::Poisson,
            burst: 4,
        }
    }
}

/// Full configuration of one simulated SSD.
#[derive(Debug, Clone)]
pub struct SsdConfig {
    /// Controller↔flash interface under test.
    pub iface: InterfaceKind,
    /// Flash cell type (selects datasheet timing).
    pub cell: CellType,
    /// Number of channels (channel striping degree).
    pub channels: u16,
    /// Ways per channel (way interleaving degree).
    pub ways: u16,
    /// Blocks per chip (capacity knob for FTL experiments; the paper's
    /// bandwidth runs need only enough to hold the trace).
    pub blocks_per_chip: u32,
    /// Interface timing parameters (Table 2).
    pub params: IfaceParams,
    /// NAND timing override; `None` uses the datasheet values for `cell`.
    pub nand: Option<NandTiming>,
    /// Host link.
    pub sata: SataGen,
    /// Host queue depth (outstanding requests; SATA2 NCQ allows up to 32).
    pub queue_depth: u32,
    /// DRAM cache configuration.
    pub cache: CacheConfig,
    /// FTL scheme.
    pub ftl: FtlKind,
    /// Logical capacity as a fraction of physical (over-provisioning).
    pub utilization: f64,
    /// Extra controller-side bus occupancy after each program completes
    /// (status polling + FTL metadata); calibration constant.
    pub program_status_overhead: Ps,
    /// PRNG seed for workload/ordering decisions.
    pub seed: u64,
    /// Open-loop workload knobs (closed loop when unset).
    pub load: LoadConfig,
}

impl Default for SsdConfig {
    fn default() -> Self {
        SsdConfig {
            iface: InterfaceKind::Proposed,
            cell: CellType::Slc,
            channels: 1,
            ways: 1,
            blocks_per_chip: 4096,
            params: IfaceParams::default(),
            nand: None,
            sata: SataGen::sata2(),
            queue_depth: 4,
            cache: CacheConfig::default(),
            ftl: FtlKind::PageMap,
            utilization: 0.9,
            program_status_overhead: Ps::us(2),
            seed: 0xDD12_7A5D,
            load: LoadConfig::default(),
        }
    }
}

impl SsdConfig {
    /// The paper's single-channel way-interleaving sweep point (Fig. 8).
    pub fn paper_way_sweep(iface: InterfaceKind, cell: CellType, ways: u16) -> SsdConfig {
        SsdConfig {
            iface,
            cell,
            channels: 1,
            ways,
            ..SsdConfig::default()
        }
    }

    /// The paper's constant-capacity channel sweep point (Fig. 9):
    /// channels × ways = 16.
    pub fn paper_channel_sweep(
        iface: InterfaceKind,
        cell: CellType,
        channels: u16,
    ) -> SsdConfig {
        assert!(16 % channels == 0, "channels must divide 16");
        SsdConfig {
            iface,
            cell,
            channels,
            ways: 16 / channels,
            ..SsdConfig::default()
        }
    }

    /// Effective NAND timing.
    pub fn nand_timing(&self) -> NandTiming {
        self.nand.unwrap_or_else(|| NandTiming::for_cell(self.cell))
    }

    /// Total chips in the array.
    pub fn chips(&self) -> u32 {
        self.channels as u32 * self.ways as u32
    }

    /// Validate invariants; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.channels == 0 {
            errs.push("channels must be >= 1".into());
        }
        if self.ways == 0 {
            errs.push("ways must be >= 1".into());
        }
        if self.blocks_per_chip < 4 {
            errs.push("blocks_per_chip must be >= 4 (need GC headroom)".into());
        }
        if !(0.0..=1.0).contains(&self.utilization) {
            errs.push("utilization must be in [0,1]".into());
        }
        if self.queue_depth == 0 {
            errs.push("queue_depth must be >= 1".into());
        }
        if !(0.0..=0.5).contains(&self.params.alpha) {
            errs.push("alpha must be in [0, 1/2] (Eq. 1)".into());
        }
        if let Some(mbps) = self.load.offered_mbps {
            if !(mbps > 0.0 && mbps.is_finite()) {
                errs.push("load.offered_mbps must be a positive number".into());
            }
        }
        if self.load.burst == 0 {
            errs.push("load.burst must be >= 1".into());
        }
        errs
    }

    /// Load from the TOML subset. Unknown keys are rejected to catch typos.
    pub fn from_toml(text: &str) -> Result<SsdConfig, String> {
        let doc = toml::parse(text)?;
        let mut cfg = SsdConfig::default();
        for (key, val) in &doc.entries {
            match key.as_str() {
                "iface" => {
                    cfg.iface = match val.as_str() {
                        Some("conv") | Some("CONV") => InterfaceKind::Conv,
                        Some("sync_only") | Some("SYNC_ONLY") => InterfaceKind::SyncOnly,
                        Some("proposed") | Some("PROPOSED") => InterfaceKind::Proposed,
                        other => return Err(format!("bad iface {other:?}")),
                    }
                }
                "cell" => {
                    cfg.cell = match val.as_str() {
                        Some("slc") | Some("SLC") => CellType::Slc,
                        Some("mlc") | Some("MLC") => CellType::Mlc,
                        other => return Err(format!("bad cell {other:?}")),
                    }
                }
                "channels" => cfg.channels = req_u16(key, val)?,
                "ways" => cfg.ways = req_u16(key, val)?,
                "blocks_per_chip" => cfg.blocks_per_chip = req_u32(key, val)?,
                "queue_depth" => cfg.queue_depth = req_u32(key, val)?,
                "utilization" => cfg.utilization = req_f64(key, val)?,
                "seed" => cfg.seed = req_u64(key, val)?,
                "ftl" => {
                    cfg.ftl = match val.as_str() {
                        Some("page_map") => FtlKind::PageMap,
                        Some("hybrid") => FtlKind::Hybrid,
                        other => return Err(format!("bad ftl {other:?}")),
                    }
                }
                "params.alpha" => cfg.params.alpha = req_f64(key, val)?,
                "params.t_byte_ns" => cfg.params.t_byte_ns = req_f64(key, val)?,
                "params.t_diff_ns" => cfg.params.t_diff_ns = req_f64(key, val)?,
                "params.t_rea_ns" => cfg.params.t_rea_ns = req_f64(key, val)?,
                "params.t_out_ns" => cfg.params.t_out_ns = req_f64(key, val)?,
                "params.t_in_ns" => cfg.params.t_in_ns = req_f64(key, val)?,
                "sata.bandwidth_mbps" => cfg.sata.bandwidth_mbps = req_f64(key, val)?,
                "sata.command_overhead_us" => {
                    cfg.sata.command_overhead = Ps::from_us_f64(req_f64(key, val)?)
                }
                "load.offered_mbps" => cfg.load.offered_mbps = Some(req_f64(key, val)?),
                "load.arrival" => {
                    cfg.load.arrival = match val.as_str() {
                        Some("poisson") => ArrivalKind::Poisson,
                        Some("bursty") => ArrivalKind::Bursty,
                        other => return Err(format!("bad load.arrival {other:?}")),
                    }
                }
                "load.burst" => cfg.load.burst = req_u32(key, val)?,
                "cache.capacity_pages" => cfg.cache.capacity_pages = req_u32(key, val)?,
                "cache.write_back" => {
                    cfg.cache.write_back =
                        val.as_bool().ok_or_else(|| format!("{key}: want bool"))?
                }
                other => return Err(format!("unknown config key: {other}")),
            }
        }
        let errs = cfg.validate();
        if !errs.is_empty() {
            return Err(errs.join("; "));
        }
        Ok(cfg)
    }
}

fn req_f64(key: &str, v: &toml::Value) -> Result<f64, String> {
    v.as_float().ok_or_else(|| format!("{key}: want number"))
}
fn req_u64(key: &str, v: &toml::Value) -> Result<u64, String> {
    v.as_int()
        .filter(|&i| i >= 0)
        .map(|i| i as u64)
        .ok_or_else(|| format!("{key}: want non-negative integer"))
}
fn req_u32(key: &str, v: &toml::Value) -> Result<u32, String> {
    req_u64(key, v)?
        .try_into()
        .map_err(|_| format!("{key}: out of range"))
}
fn req_u16(key: &str, v: &toml::Value) -> Result<u16, String> {
    req_u64(key, v)?
        .try_into()
        .map_err(|_| format!("{key}: out of range"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        assert!(SsdConfig::default().validate().is_empty());
    }

    #[test]
    fn paper_presets() {
        let c = SsdConfig::paper_way_sweep(InterfaceKind::Conv, CellType::Slc, 16);
        assert_eq!(c.channels, 1);
        assert_eq!(c.ways, 16);
        let c = SsdConfig::paper_channel_sweep(InterfaceKind::Proposed, CellType::Mlc, 4);
        assert_eq!((c.channels, c.ways), (4, 4));
        assert_eq!(c.chips(), 16);
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = SsdConfig::from_toml(
            r#"
iface = "proposed"
cell = "mlc"
channels = 2
ways = 8
queue_depth = 8
[sata]
bandwidth_mbps = 600.0
[cache]
capacity_pages = 1024
"#,
        )
        .unwrap();
        assert_eq!(cfg.iface, InterfaceKind::Proposed);
        assert_eq!(cfg.cell, CellType::Mlc);
        assert_eq!((cfg.channels, cfg.ways), (2, 8));
        assert_eq!(cfg.sata.bandwidth_mbps, 600.0);
        assert_eq!(cfg.cache.capacity_pages, 1024);
    }

    #[test]
    fn load_section_parses_and_validates() {
        let cfg = SsdConfig::from_toml(
            r#"
iface = "proposed"
[load]
offered_mbps = 120.5
arrival = "bursty"
burst = 8
"#,
        )
        .unwrap();
        assert_eq!(cfg.load.offered_mbps, Some(120.5));
        assert_eq!(cfg.load.arrival, ArrivalKind::Bursty);
        assert_eq!(cfg.load.burst, 8);
        // Closed loop by default.
        assert_eq!(SsdConfig::default().load.offered_mbps, None);
        // Bad values rejected.
        assert!(SsdConfig::from_toml("[load]\noffered_mbps = -3.0").is_err());
        assert!(SsdConfig::from_toml("[load]\nburst = 0").is_err());
        assert!(SsdConfig::from_toml("[load]\narrival = \"uniform\"").is_err());
    }

    #[test]
    fn unknown_key_rejected() {
        let err = SsdConfig::from_toml("wayz = 4").unwrap_err();
        assert!(err.contains("unknown config key"), "{err}");
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(SsdConfig::from_toml("channels = 0").is_err());
        assert!(SsdConfig::from_toml("utilization = 1.5").is_err());
        assert!(SsdConfig::from_toml(r#"iface = "quantum""#).is_err());
    }

    #[test]
    fn nand_timing_follows_cell() {
        let mut c = SsdConfig::default();
        c.cell = CellType::Mlc;
        assert_eq!(c.nand_timing(), NandTiming::mlc());
        c.nand = Some(NandTiming::slc());
        assert_eq!(c.nand_timing(), NandTiming::slc());
    }
}
