//! Energy model (§5.3.3).
//!
//! The paper measures the *controller's* average power per interface and
//! divides by achieved bandwidth to get energy per byte (Fig. 10/Table 5).
//! Per-interface controller power is constant in the paper's data — the
//! nJ/B × MB/s product is flat across way counts — so the model is a
//! per-interface active-power constant (synthesis at 50 MHz vs 83 MHz, plus
//! the DLL/duplicated-FIFO overhead of PROPOSED), with the crossover in
//! Fig. 10 emerging from the bandwidth differences.

use crate::iface::timing::InterfaceKind;
use crate::util::time::Ps;

/// Controller power model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerModel {
    /// Active controller power in milliwatts while the SSD is operating.
    pub controller_mw: f64,
    /// NAND array energy per programmed page in nJ (extension; not part of
    /// the paper's controller-only comparison).
    pub nand_prog_nj_per_page: f64,
    /// NAND array energy per read page in nJ.
    pub nand_read_nj_per_page: f64,
}

impl PowerModel {
    /// Calibrated from Table 5: nJ/B × MB/s ≈ 22.5 mW (CONV), 42 mW
    /// (SYNC_ONLY), 46.5 mW (PROPOSED). The 83 MHz designs burn more power
    /// than the 50 MHz CONV; PROPOSED adds the DLL and duplicated FIFOs
    /// over SYNC_ONLY.
    pub fn for_interface(kind: InterfaceKind) -> PowerModel {
        let controller_mw = match kind {
            InterfaceKind::Conv => 22.5,
            InterfaceKind::SyncOnly => 42.0,
            InterfaceKind::Proposed => 46.5,
        };
        PowerModel {
            controller_mw,
            nand_prog_nj_per_page: 33.0, // ~1.65 uA*3.3V*... representative
            nand_read_nj_per_page: 10.0,
        }
    }

    /// Controller power of a tiered drive whose two NAND_IF clusters run
    /// (possibly) different interface kinds: the controller clocks the
    /// faster domain, so the active power is the larger of the two
    /// per-interface constants. With equal kinds this is exactly
    /// [`for_interface`](Self::for_interface).
    pub fn for_tiered(slc_iface: InterfaceKind, mlc_iface: InterfaceKind) -> PowerModel {
        let a = PowerModel::for_interface(slc_iface);
        let b = PowerModel::for_interface(mlc_iface);
        PowerModel {
            controller_mw: a.controller_mw.max(b.controller_mw),
            ..a
        }
    }
}

/// Accumulated energy over a simulation run.
#[derive(Debug, Clone, Default)]
pub struct EnergyMeter {
    pub controller_nj: f64,
    pub nand_nj: f64,
    /// Subset of `nand_nj` spent on GC/wear-leveling copy-back programs —
    /// the energy face of write amplification (steady-state accounting;
    /// zero on fresh-drive runs).
    pub gc_nj: f64,
    /// Subset of `nand_nj` spent on SLC→MLC tier-migration programs
    /// (disjoint from `gc_nj`; zero when tiering is disabled).
    pub mig_nj: f64,
    pub bytes: u64,
}

impl EnergyMeter {
    /// Account controller energy for an elapsed window.
    pub fn add_window(&mut self, model: &PowerModel, elapsed: Ps) {
        // mW × s = mJ; ×1e6 -> nJ.
        self.controller_nj += model.controller_mw * elapsed.as_secs_f64() * 1e6;
    }

    pub fn add_nand_program(&mut self, model: &PowerModel, pages: u64) {
        self.nand_nj += model.nand_prog_nj_per_page * pages as f64;
    }

    pub fn add_nand_read(&mut self, model: &PowerModel, pages: u64) {
        self.nand_nj += model.nand_read_nj_per_page * pages as f64;
    }

    /// Attribute `pages` already-counted programs to GC/wear-leveling
    /// copy-back. Call *in addition to*
    /// [`add_nand_program`](Self::add_nand_program): this splits the
    /// already-metered energy, it does not add more.
    pub fn add_gc_program(&mut self, model: &PowerModel, pages: u64) {
        self.gc_nj += model.nand_prog_nj_per_page * pages as f64;
    }

    /// Attribute `pages` already-counted programs to SLC→MLC tier
    /// migration. Like [`add_gc_program`](Self::add_gc_program), this
    /// splits already-metered energy — call in addition to
    /// [`add_nand_program`](Self::add_nand_program).
    pub fn add_mig_program(&mut self, model: &PowerModel, pages: u64) {
        self.mig_nj += model.nand_prog_nj_per_page * pages as f64;
    }

    /// Fraction of NAND array energy spent on GC/WL copy-back programs
    /// (0 when no NAND energy was spent).
    pub fn gc_share(&self) -> f64 {
        if self.nand_nj == 0.0 {
            0.0
        } else {
            self.gc_nj / self.nand_nj
        }
    }

    /// Fraction of NAND array energy spent on tier-migration programs
    /// (0 when no NAND energy was spent).
    pub fn mig_share(&self) -> f64 {
        if self.nand_nj == 0.0 {
            0.0
        } else {
            self.mig_nj / self.nand_nj
        }
    }

    pub fn add_bytes(&mut self, bytes: u64) {
        self.bytes += bytes;
    }

    /// The paper's metric: controller energy per transferred byte (nJ/B).
    pub fn controller_nj_per_byte(&self) -> f64 {
        if self.bytes == 0 {
            0.0
        } else {
            self.controller_nj / self.bytes as f64
        }
    }

    /// Total (controller + NAND) energy per byte — extension metric.
    pub fn total_nj_per_byte(&self) -> f64 {
        if self.bytes == 0 {
            0.0
        } else {
            (self.controller_nj + self.nand_nj) / self.bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_ordering_matches_paper() {
        let c = PowerModel::for_interface(InterfaceKind::Conv).controller_mw;
        let s = PowerModel::for_interface(InterfaceKind::SyncOnly).controller_mw;
        let p = PowerModel::for_interface(InterfaceKind::Proposed).controller_mw;
        assert!(c < s && s < p);
    }

    #[test]
    fn energy_per_byte_is_power_over_bandwidth() {
        // At BW MB/s, E/B = P_mw / BW (nJ/B). Check the identity through
        // the meter: move `bw` MB in one second.
        let model = PowerModel::for_interface(InterfaceKind::Proposed);
        let mut m = EnergyMeter::default();
        let bw_mbps = 97.35; // Table 3 SLC write 16-way PROPOSED
        m.add_window(&model, Ps::ms(1000));
        m.add_bytes((bw_mbps * 1e6) as u64);
        let e = m.controller_nj_per_byte();
        assert!((e - 46.5 / 97.35).abs() < 1e-3, "e={e}");
        // Table 5 16-way write PROPOSED: 0.48 nJ/B
        assert!((e - 0.48).abs() < 0.01, "e={e}");
    }

    #[test]
    fn conv_16way_write_matches_table5() {
        let model = PowerModel::for_interface(InterfaceKind::Conv);
        let mut m = EnergyMeter::default();
        m.add_window(&model, Ps::ms(1000));
        m.add_bytes((39.76 * 1e6) as u64);
        assert!((m.controller_nj_per_byte() - 0.57).abs() < 0.01);
    }

    #[test]
    fn nand_energy_accumulates() {
        let model = PowerModel::for_interface(InterfaceKind::Conv);
        let mut m = EnergyMeter::default();
        m.add_nand_program(&model, 10);
        m.add_nand_read(&model, 10);
        assert!((m.nand_nj - 430.0).abs() < 1e-9);
    }

    /// GC attribution splits already-counted program energy; the share is
    /// gc programs over all NAND energy and never exceeds 1.
    #[test]
    fn gc_share_splits_program_energy() {
        let model = PowerModel::for_interface(InterfaceKind::Conv);
        let mut m = EnergyMeter::default();
        assert_eq!(m.gc_share(), 0.0);
        m.add_nand_program(&model, 10); // 4 of which are GC copy-back
        m.add_gc_program(&model, 4);
        assert!((m.nand_nj - 330.0).abs() < 1e-9, "split must not add");
        assert!((m.gc_share() - 0.4).abs() < 1e-12, "share={}", m.gc_share());
        assert!(m.gc_share() <= 1.0);
    }

    /// Tiered controller power is the max of the two tier interfaces, and
    /// collapses to the plain per-interface model when the tiers agree.
    #[test]
    fn tiered_power_takes_faster_domain() {
        let same = PowerModel::for_tiered(InterfaceKind::Conv, InterfaceKind::Conv);
        assert_eq!(same, PowerModel::for_interface(InterfaceKind::Conv));
        let mixed = PowerModel::for_tiered(InterfaceKind::Conv, InterfaceKind::Proposed);
        assert_eq!(
            mixed.controller_mw,
            PowerModel::for_interface(InterfaceKind::Proposed).controller_mw
        );
    }

    /// Migration energy splits like GC energy and the two shares are
    /// disjoint.
    #[test]
    fn mig_share_splits_program_energy() {
        let model = PowerModel::for_interface(InterfaceKind::Conv);
        let mut m = EnergyMeter::default();
        m.add_nand_program(&model, 10);
        m.add_gc_program(&model, 2);
        m.add_mig_program(&model, 3);
        assert!((m.nand_nj - 330.0).abs() < 1e-9, "splits must not add");
        assert!((m.gc_share() - 0.2).abs() < 1e-12);
        assert!((m.mig_share() - 0.3).abs() < 1e-12);
        assert!(m.gc_share() + m.mig_share() <= 1.0);
    }

    #[test]
    fn zero_bytes_no_nan() {
        let m = EnergyMeter::default();
        assert_eq!(m.controller_nj_per_byte(), 0.0);
        assert_eq!(m.total_nj_per_byte(), 0.0);
    }
}
