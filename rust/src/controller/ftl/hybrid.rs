//! Hybrid log-block FTL (BAST-style, after Kim et al. [9]).
//!
//! Logical blocks are block-mapped to *data blocks*; writes are appended to
//! a small pool of page-mapped *log blocks*. When no log block is available,
//! the FTL merges a (log, data) pair: valid pages from both are copied into
//! a free block, then both are erased. §2.3.2: "data is always written to
//! log blocks first. When all log blocks are used up, the FTL moves the data
//! from log blocks to data blocks."

use crate::controller::ftl::{Ftl, FtlOp};
use crate::nand::geometry::Geometry;

const INVALID: u64 = u64::MAX;

/// Sentinel in [`LogBlock::slots`]: this page offset is not logged here.
const NO_SLOT: u32 = u32::MAX;

/// Per-log-block state: which logical block it serves and what it holds.
struct LogBlock {
    /// Physical block id (linear across the SSD).
    pblock: u64,
    /// Logical block it logs for.
    lbn: u64,
    /// next free page slot.
    write_ptr: u32,
    /// page-offset-in-lblock -> slot in this log block (latest wins),
    /// `NO_SLOT` when unlogged. An indexed `Vec` rather than a
    /// `HashMap<u32, u32>`: offsets are dense in `0..pages_per_block`, and
    /// the PR 9 determinism audit converts hash containers on FTL paths to
    /// order-free structures (simlint rule R1 — the old map was keyed-only,
    /// so this is bit-identical by construction).
    slots: Vec<u32>,
}

impl LogBlock {
    /// Latest logged slot for page offset `off`, if any.
    fn slot(&self, off: u32) -> Option<u32> {
        match self.slots[off as usize] {
            NO_SLOT => None,
            s => Some(s),
        }
    }
}

/// Hybrid (block + log) mapping FTL.
///
/// Physical blocks are addressed linearly (`pblock` in
/// `0..blocks_per_chip × chips`); pages inside a logical block stripe across
/// chips exactly like the page-map FTL, so interleaving behaviour is
/// comparable.
pub struct HybridFtl {
    geom: Geometry,
    /// Logical block -> data physical block (or INVALID).
    data_map: Vec<u64>,
    /// Active log blocks.
    logs: Vec<LogBlock>,
    /// Free physical blocks.
    free_blocks: Vec<u64>,
    /// Max number of simultaneous log blocks.
    pub max_logs: usize,
    merges: u64,
    relocations: u64,
    erases: u64,
    free_pages: u64,
}

impl HybridFtl {
    pub fn new(geom: Geometry, max_logs: usize) -> HybridFtl {
        let total_blocks = geom.blocks_per_chip as u64 * geom.chips() as u64;
        let logical_blocks = total_blocks - max_logs as u64 - 2; // spare for merges
        HybridFtl {
            data_map: vec![INVALID; logical_blocks as usize],
            logs: Vec::new(),
            free_blocks: (0..total_blocks).rev().collect(),
            max_logs,
            merges: 0,
            relocations: 0,
            erases: 0,
            free_pages: geom.total_pages(),
            geom,
        }
    }

    pub fn logical_pages(&self) -> u64 {
        self.data_map.len() as u64 * self.geom.pages_per_block as u64
    }

    pub fn merges(&self) -> u64 {
        self.merges
    }

    /// Free physical blocks remaining. Merges allocate one block before
    /// erasing two, so this floor must stay ≥ 1 at every step (the spare
    /// reserved in `new`); the property tests enforce it.
    pub fn free_block_count(&self) -> usize {
        self.free_blocks.len()
    }

    /// ppn of page `page` within physical block `pblock`.
    ///
    /// Physical block b lives on chip (b % chips) as block (b / chips);
    /// the ppn uses the canonical [`Geometry`] striped layout so the
    /// coordinator's ppn→(channel, way) resolution is uniform across FTLs.
    fn ppn(&self, pblock: u64, page: u32) -> u64 {
        let chips = self.geom.chips() as u64;
        let chip = (pblock % chips) as usize;
        let block = (pblock / chips) as u32;
        let (channel, way) = self.geom.chip_addr(chip);
        self.geom.ppn(crate::nand::geometry::PageAddr {
            channel,
            way,
            block,
            page,
        })
    }

    fn chip_of(&self, pblock: u64) -> usize {
        (pblock % self.geom.chips() as u64) as usize
    }

    fn alloc_block(&mut self) -> u64 {
        self.free_blocks.pop().expect("hybrid FTL out of free blocks")
    }

    /// Merge the oldest log block with its data block.
    fn merge_oldest(&mut self, out: &mut Vec<FtlOp>) {
        let log = self.logs.remove(0);
        let lbn = log.lbn;
        let data = self.data_map[lbn as usize];
        let new_block = self.alloc_block();
        // Copy each page offset: prefer the log's copy, else the data block's.
        for off in 0..self.geom.pages_per_block {
            let src = if let Some(slot) = log.slot(off) {
                Some(self.ppn(log.pblock, slot))
            } else if data != INVALID {
                Some(self.ppn(data, off))
            } else {
                None
            };
            if let Some(src_ppn) = src {
                out.push(FtlOp::ReadPage { ppn: src_ppn });
                out.push(FtlOp::ProgramPage {
                    ppn: self.ppn(new_block, off),
                });
                self.relocations += 1;
            }
        }
        // Erase log + old data.
        out.push(FtlOp::EraseBlock {
            chip: self.chip_of(log.pblock),
            block: (log.pblock / self.geom.chips() as u64) as u32,
        });
        self.free_blocks.push(log.pblock);
        self.erases += 1;
        if data != INVALID {
            out.push(FtlOp::EraseBlock {
                chip: self.chip_of(data),
                block: (data / self.geom.chips() as u64) as u32,
            });
            self.free_blocks.push(data);
            self.erases += 1;
        }
        self.data_map[lbn as usize] = new_block;
        self.merges += 1;
    }

    fn log_for(&mut self, lbn: u64, out: &mut Vec<FtlOp>) -> usize {
        if let Some(i) = self
            .logs
            .iter()
            .position(|l| l.lbn == lbn && l.write_ptr < self.geom.pages_per_block)
        {
            return i;
        }
        // A full log for this lbn must merge before a new one opens.
        if let Some(i) = self.logs.iter().position(|l| l.lbn == lbn) {
            let log = self.logs.remove(i);
            self.logs.insert(0, log); // make it the merge victim
            self.merge_oldest(out);
        } else if self.logs.len() >= self.max_logs {
            self.merge_oldest(out);
        }
        let pblock = self.alloc_block();
        self.logs.push(LogBlock {
            pblock,
            lbn,
            write_ptr: 0,
            slots: vec![NO_SLOT; self.geom.pages_per_block as usize],
        });
        self.logs.len() - 1
    }
}

impl Ftl for HybridFtl {
    fn translate(&self, lpn: u64) -> Option<u64> {
        let ppb = self.geom.pages_per_block as u64;
        let lbn = lpn / ppb;
        let off = (lpn % ppb) as u32;
        // Log blocks take precedence (latest copy).
        for l in self.logs.iter().rev() {
            if l.lbn == lbn {
                if let Some(slot) = l.slot(off) {
                    return Some(self.ppn(l.pblock, slot));
                }
            }
        }
        let data = *self.data_map.get(lbn as usize)?;
        (data != INVALID).then(|| self.ppn(data, off))
    }

    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64 {
        let ppb = self.geom.pages_per_block as u64;
        let lbn = lpn / ppb;
        let off = (lpn % ppb) as u32;
        assert!((lbn as usize) < self.data_map.len(), "lpn out of range");
        let li = self.log_for(lbn, out);
        let (slot, pblock) = {
            let l = &mut self.logs[li];
            let slot = l.write_ptr;
            l.write_ptr += 1;
            l.slots[off as usize] = slot;
            (slot, l.pblock)
        };
        let target = self.ppn(pblock, slot);
        self.free_pages = self.free_pages.saturating_sub(1);
        target
    }

    fn reset(&mut self) {
        self.data_map.fill(INVALID);
        self.logs.clear();
        let total_blocks = self.geom.blocks_per_chip as u64 * self.geom.chips() as u64;
        self.free_blocks.clear();
        self.free_blocks.extend((0..total_blocks).rev());
        self.merges = 0;
        self.relocations = 0;
        self.erases = 0;
        self.free_pages = self.geom.total_pages();
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }
    fn logical_capacity(&self) -> u64 {
        self.logical_pages()
    }
    fn free_pages(&self) -> u64 {
        self.free_pages
    }
    fn relocations(&self) -> u64 {
        self.relocations
    }
    fn erases(&self) -> u64 {
        self.erases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry {
            channels: 2,
            ways: 2,
            blocks_per_chip: 16,
            pages_per_block: 8,
            page_bytes: 2048,
        }
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut f = HybridFtl::new(geom(), 4);
        assert_eq!(f.translate(5), None);
        let p = f.plan_write(5).target_ppn;
        assert_eq!(f.translate(5), Some(p));
    }

    #[test]
    fn rewrite_goes_to_new_slot() {
        let mut f = HybridFtl::new(geom(), 4);
        let p1 = f.plan_write(5).target_ppn;
        let p2 = f.plan_write(5).target_ppn;
        assert_ne!(p1, p2);
        assert_eq!(f.translate(5), Some(p2));
    }

    #[test]
    fn log_exhaustion_triggers_merge() {
        let mut f = HybridFtl::new(geom(), 2);
        // Touch 3 different logical blocks -> third write must merge.
        let mut merged = false;
        for lbn in 0..3u64 {
            let plan = f.plan_write(lbn * 8);
            merged |= !plan.background.is_empty();
        }
        assert!(merged, "exceeding max_logs must trigger a merge");
        assert!(f.merges() >= 1);
    }

    #[test]
    fn merge_preserves_all_data() {
        let mut f = HybridFtl::new(geom(), 2);
        // Fill logical block 0 fully, then cause merges via other blocks.
        for off in 0..8u64 {
            f.plan_write(off);
        }
        for lbn in 1..6u64 {
            f.plan_write(lbn * 8);
        }
        // Every page of lbn 0 still resolves.
        for off in 0..8u64 {
            assert!(f.translate(off).is_some(), "lost page {off}");
        }
    }

    #[test]
    fn full_log_same_block_remerges() {
        let mut f = HybridFtl::new(geom(), 2);
        // 9 writes to the same logical page: log block holds 8, 9th merges.
        for _ in 0..9 {
            f.plan_write(0);
        }
        assert!(f.merges() >= 1);
        assert!(f.translate(0).is_some());
    }

    #[test]
    fn reset_restores_factory_state_and_determinism() {
        let run = |f: &mut HybridFtl| -> Vec<u64> {
            (0..40).map(|lpn| f.plan_write(lpn).target_ppn).collect()
        };
        let mut fresh = HybridFtl::new(geom(), 4);
        let expect = run(&mut fresh);
        let mut reused = HybridFtl::new(geom(), 4);
        for lpn in 0..100u64 {
            reused.plan_write(lpn % 30);
        }
        reused.reset();
        assert_eq!(reused.free_pages(), geom().total_pages());
        assert_eq!(reused.merges(), 0);
        assert_eq!(reused.translate(0), None);
        assert_eq!(run(&mut reused), expect);
    }

    #[test]
    fn sequential_fill_no_data_loss() {
        let mut f = HybridFtl::new(geom(), 4);
        let n = 20 * 8;
        for lpn in 0..n {
            f.plan_write(lpn);
        }
        for lpn in 0..n {
            assert!(f.translate(lpn).is_some(), "lpn {lpn} lost");
        }
        assert!(f.merges() > 0);
    }
}
