//! Demand-paged mapping tier (DFTL / FMMU direction).
//!
//! The fully-resident [`super::page_map::PageMapFtl`] assumes translation
//! is free: every lookup hits an in-DRAM table. At multi-TB capacities
//! that table itself lives in flash, split into *translation pages* of
//! `entries_per_page` lpn→ppn entries, and the controller keeps only a
//! cache of them in DRAM — so a host access whose covering translation
//! page is not cached costs a **real flash read** before (demand mode) or
//! alongside (FMMU mode) the data access, and evicting a dirtied
//! translation page costs a flash program. Both become first-class DES
//! jobs here: [`crate::controller::ftl::FtlOp::MapReadPage`] /
//! [`MapProgramPage`](crate::controller::ftl::FtlOp::MapProgramPage),
//! issued by the coordinator at the background class and contending for
//! channel/way/bus with everything else.
//!
//! Two implementation points (the `[mapping]` TOML section picks one):
//!
//! * **`demand`** — DFTL-style firmware paging: a missed host op is
//!   *deferred* until its fill read completes (the coordinator parks it in
//!   a waiter list keyed on the map page). Misses serialize translation
//!   before array access, the classic DFTL penalty.
//! * **`fmmu`** — a hardware-automated map unit ("FMMU: A Hardware-
//!   Automated Flash Map Management Unit for Scalable SSDs", PAPERS.md)
//!   that overlaps translation with the array access: the fill read still
//!   occupies bus/way (contention is real) but the host op proceeds
//!   immediately.
//!
//! ## Scope of the timing model
//!
//! The tier is a *timing* model layered over the exact mapping state,
//! which stays in the inner [`PageMapFtl`]'s packed-lazy tables (host RAM
//! already scales with the touched footprint; see
//! [`packed`](super::packed)). Translation page `t` lives at physical
//! page `ppn == t` — translation pages number at most
//! `logical_pages / entries_per_page`, far below the physical page count,
//! and the identity keeps fills/write-backs trivially invertible while
//! striping map traffic across channels exactly like data (the geometry
//! stripes ppns channel-first). Map write-backs re-program the same ppn
//! without an erase: the block-lifecycle cost of the map area is not
//! modeled, only its bus/way/chip occupancy and the induced host-visible
//! latency. GC-internal relocations update mapping entries without
//! touching the cache — modeled map traffic is host-access-driven, the
//! dominant term the FMMU paper measures.

use crate::controller::ftl::page_map::PageMapFtl;
use crate::controller::ftl::steady::GcTuning;
use crate::controller::ftl::{Ftl, FtlOp, MapAccess};
use crate::nand::geometry::Geometry;

const NIL: u32 = u32::MAX;

const ABSENT: u8 = 0;
/// Fill read in flight; entry pinned (never evicted) until it lands.
const FILL_CLEAN: u8 = 1;
/// Fill in flight and a write already dirtied the entry.
const FILL_DIRTY: u8 = 2;
const RES_CLEAN: u8 = 3;
const RES_DIRTY: u8 = 4;

/// Outcome of one [`MapCache::access`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheAccess {
    /// Translation page resident — no flash traffic.
    Hit,
    /// Miss on a page whose fill read is already in flight: no new fill,
    /// but the access still pays the miss (demand mode parks it behind
    /// the same fill).
    MissInFlight,
    /// Miss that starts a fill read; `writeback` names the dirty
    /// translation page displaced to make room, if any.
    MissFill { writeback: Option<u64> },
}

/// LRU cache directory over translation pages.
///
/// Intrusive doubly-linked LRU over `u32` indices (the config validator
/// bounds the translation-page count below `u32::MAX`); the directory
/// costs 9 bytes per translation page — ~5 MB for a 2-TB drive — while
/// the *cached capacity* is `capacity` pages. A capacity covering every
/// translation page initializes fully resident ("warm"): zero misses,
/// zero evictions, bit-identical event streams to the resident FTL
/// (golden-tested in `rust/tests/mapping.rs`).
#[derive(Debug)]
pub struct MapCache {
    capacity: u64,
    warm: bool,
    state: Vec<u8>,
    prev: Vec<u32>,
    next: Vec<u32>,
    /// MRU end / LRU end of the resident list (filling pages are pinned
    /// outside the list).
    head: u32,
    tail: u32,
    /// Resident + filling entries (may transiently exceed `capacity` when
    /// every resident page is pinned by an in-flight fill).
    occupied: u64,
}

impl MapCache {
    pub fn new(capacity: u64, tpages: u64) -> MapCache {
        assert!(
            tpages < u32::MAX as u64,
            "translation-page count {tpages} overflows the cache directory"
        );
        let warm = capacity >= tpages;
        let mut c = MapCache {
            capacity,
            warm,
            state: Vec::new(),
            prev: Vec::new(),
            next: Vec::new(),
            head: NIL,
            tail: NIL,
            occupied: 0,
        };
        c.init(tpages);
        c
    }

    fn init(&mut self, tpages: u64) {
        let n = tpages as usize;
        self.state.clear();
        self.state
            .resize(n, if self.warm { RES_CLEAN } else { ABSENT });
        self.prev.clear();
        self.next.clear();
        if !self.warm {
            self.prev.resize(n, NIL);
            self.next.resize(n, NIL);
        }
        self.head = NIL;
        self.tail = NIL;
        self.occupied = if self.warm { tpages } else { 0 };
    }

    /// Return to the just-initialized state (workspace reuse).
    pub fn reset(&mut self) {
        let tpages = self.state.len() as u64;
        self.init(tpages);
    }

    /// Is the cache sized to hold every translation page (and therefore
    /// guaranteed miss-free)?
    pub fn is_warm(&self) -> bool {
        self.warm
    }

    /// Resident or in-flight translation pages.
    pub fn occupied(&self) -> u64 {
        self.occupied
    }

    fn unlink(&mut self, t: u32) {
        let (p, n) = (self.prev[t as usize], self.next[t as usize]);
        if p == NIL {
            self.head = n;
        } else {
            self.next[p as usize] = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.prev[n as usize] = p;
        }
        self.prev[t as usize] = NIL;
        self.next[t as usize] = NIL;
    }

    fn push_front(&mut self, t: u32) {
        self.prev[t as usize] = NIL;
        self.next[t as usize] = self.head;
        if self.head != NIL {
            self.prev[self.head as usize] = t;
        }
        self.head = t;
        if self.tail == NIL {
            self.tail = t;
        }
    }

    /// Evict the LRU resident page; returns it if it was dirty (needs a
    /// write-back program). `None` with no eviction can only happen when
    /// every resident page is pinned by an in-flight fill.
    fn evict_lru(&mut self) -> Option<u64> {
        if self.tail == NIL {
            return None;
        }
        let t = self.tail;
        self.unlink(t);
        let dirty = self.state[t as usize] == RES_DIRTY;
        self.state[t as usize] = ABSENT;
        self.occupied -= 1;
        dirty.then_some(t as u64)
    }

    /// Look up translation page `t` for a host access; `write` dirties it.
    pub fn access(&mut self, t: u64, write: bool) -> CacheAccess {
        let i = t as usize;
        if self.warm {
            if write {
                self.state[i] = RES_DIRTY;
            }
            return CacheAccess::Hit;
        }
        match self.state[i] {
            RES_CLEAN | RES_DIRTY => {
                self.unlink(t as u32);
                self.push_front(t as u32);
                if write {
                    self.state[i] = RES_DIRTY;
                }
                CacheAccess::Hit
            }
            FILL_CLEAN | FILL_DIRTY => {
                if write {
                    self.state[i] = FILL_DIRTY;
                }
                CacheAccess::MissInFlight
            }
            _ => {
                let writeback = if self.occupied >= self.capacity {
                    self.evict_lru()
                } else {
                    None
                };
                self.state[i] = if write { FILL_DIRTY } else { FILL_CLEAN };
                self.occupied += 1;
                CacheAccess::MissFill { writeback }
            }
        }
    }

    /// The fill read for translation page `t` completed.
    pub fn fill_done(&mut self, t: u64) {
        let i = t as usize;
        debug_assert!(
            self.state[i] == FILL_CLEAN || self.state[i] == FILL_DIRTY,
            "fill_done on translation page {t} not in flight"
        );
        self.state[i] = if self.state[i] == FILL_DIRTY {
            RES_DIRTY
        } else {
            RES_CLEAN
        };
        self.push_front(t as u32);
    }
}

/// [`PageMapFtl`] wrapped with a demand-paged mapping tier: identical
/// mapping decisions, plus [`Ftl::map_access`]/[`Ftl::map_fill_done`]
/// hooks that surface map-cache misses as flash traffic.
pub struct DemandPagedFtl {
    inner: PageMapFtl,
    cache: MapCache,
    entries_per_page: u64,
    /// FMMU mode: overlap translation with array access (never defer).
    fmmu: bool,
}

impl DemandPagedFtl {
    pub fn new(
        geom: Geometry,
        logical_pages: u64,
        cache_pages: u64,
        entries_per_page: u64,
        fmmu: bool,
    ) -> DemandPagedFtl {
        assert!(entries_per_page >= 1, "need at least one entry per page");
        let tpages = logical_pages.div_ceil(entries_per_page).max(1);
        assert!(
            tpages <= geom.total_pages(),
            "translation pages exceed physical pages"
        );
        DemandPagedFtl {
            inner: PageMapFtl::new(geom, logical_pages),
            cache: MapCache::new(cache_pages, tpages),
            entries_per_page,
            fmmu,
        }
    }

    pub fn cache(&self) -> &MapCache {
        &self.cache
    }
}

impl Ftl for DemandPagedFtl {
    fn translate(&self, lpn: u64) -> Option<u64> {
        self.inner.translate(lpn)
    }

    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64 {
        self.inner.plan_write_into(lpn, out)
    }

    fn set_gc_tuning(&mut self, tuning: GcTuning) {
        self.inner.set_gc_tuning(tuning);
    }

    fn plan_wear_level_into(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> bool {
        self.inner.plan_wear_level_into(chip, out)
    }

    fn map_access(&mut self, lpn: u64, write: bool, out: &mut Vec<FtlOp>) -> MapAccess {
        let t = lpn / self.entries_per_page;
        let defer = !self.fmmu;
        match self.cache.access(t, write) {
            CacheAccess::Hit => MapAccess::Hit,
            CacheAccess::MissInFlight => MapAccess::Miss { map_ppn: t, defer },
            CacheAccess::MissFill { writeback } => {
                // Write-back first: the program leaves before the fill so
                // the displaced dirty page is never overtaken by its
                // replacement on the same chip queue.
                if let Some(wb) = writeback {
                    out.push(FtlOp::MapProgramPage { ppn: wb });
                }
                out.push(FtlOp::MapReadPage { ppn: t });
                MapAccess::Miss { map_ppn: t, defer }
            }
        }
    }

    fn map_fill_done(&mut self, map_ppn: u64) {
        self.cache.fill_done(map_ppn);
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.cache.reset();
    }

    fn geometry(&self) -> &Geometry {
        self.inner.geometry()
    }
    fn logical_capacity(&self) -> u64 {
        self.inner.logical_capacity()
    }
    fn free_pages(&self) -> u64 {
        self.inner.free_pages()
    }
    fn relocations(&self) -> u64 {
        self.inner.relocations()
    }
    fn erases(&self) -> u64 {
        self.inner.erases()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn geom() -> Geometry {
        Geometry {
            channels: 2,
            ways: 2,
            blocks_per_chip: 8,
            pages_per_block: 16,
            page_bytes: 2048,
        }
    }

    #[test]
    fn cache_hits_after_fill_and_evicts_lru() {
        let mut c = MapCache::new(2, 8);
        assert!(!c.is_warm());
        assert_eq!(c.access(0, false), CacheAccess::MissFill { writeback: None });
        c.fill_done(0);
        assert_eq!(c.access(0, false), CacheAccess::Hit);
        assert_eq!(c.access(1, false), CacheAccess::MissFill { writeback: None });
        c.fill_done(1);
        // Cache full {0, 1}; 0 is LRU (1 filled last). A third page
        // evicts 0 — clean, so no write-back.
        assert_eq!(c.access(2, false), CacheAccess::MissFill { writeback: None });
        c.fill_done(2);
        assert_eq!(c.access(0, false), CacheAccess::MissFill { writeback: None });
        c.fill_done(0);
        assert_eq!(c.occupied(), 2);
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = MapCache::new(1, 4);
        assert_eq!(c.access(3, true), CacheAccess::MissFill { writeback: None });
        c.fill_done(3);
        // Page 3 is dirty; filling another must write it back.
        assert_eq!(
            c.access(1, false),
            CacheAccess::MissFill {
                writeback: Some(3)
            }
        );
        c.fill_done(1);
        // Page 1 stayed clean: next eviction is silent.
        assert_eq!(c.access(2, false), CacheAccess::MissFill { writeback: None });
    }

    #[test]
    fn in_flight_fills_dedup_and_pin() {
        let mut c = MapCache::new(1, 4);
        assert_eq!(c.access(0, false), CacheAccess::MissFill { writeback: None });
        // Same page again before the fill lands: no second fill.
        assert_eq!(c.access(0, true), CacheAccess::MissInFlight);
        // A different page while the only slot is pinned: fill starts,
        // nothing evictable, occupancy transiently exceeds capacity.
        assert_eq!(c.access(1, false), CacheAccess::MissFill { writeback: None });
        assert_eq!(c.occupied(), 2);
        c.fill_done(0);
        // The in-flight write dirtied page 0, so its eviction writes back.
        c.fill_done(1);
        assert_eq!(
            c.access(2, false),
            CacheAccess::MissFill {
                writeback: Some(0)
            }
        );
    }

    #[test]
    fn warm_cache_never_misses() {
        let mut c = MapCache::new(8, 8);
        assert!(c.is_warm());
        for t in 0..8 {
            assert_eq!(c.access(t, t % 2 == 0), CacheAccess::Hit);
        }
        c.reset();
        assert_eq!(c.access(7, false), CacheAccess::Hit);
    }

    /// Randomized oracle: the demand-paged FTL makes bit-identical mapping
    /// decisions to the fully-resident one — the cache is a timing layer,
    /// never a correctness layer.
    #[test]
    fn mapping_oracle_matches_resident_ftl() {
        for seed in [1u64, 7, 42] {
            let mut resident = PageMapFtl::new(geom(), 128);
            let mut demand = DemandPagedFtl::new(geom(), 128, 2, 16, false);
            let mut rng = Prng::new(seed);
            let mut map_ops = Vec::new();
            for _ in 0..1500 {
                let lpn = rng.next_bounded(128);
                // Drive the cache like the coordinator would; complete
                // fills immediately (timing is irrelevant to mapping).
                map_ops.clear();
                if let MapAccess::Miss { map_ppn, .. } =
                    demand.map_access(lpn, true, &mut map_ops)
                {
                    if map_ops
                        .iter()
                        .any(|op| matches!(op, FtlOp::MapReadPage { .. }))
                    {
                        demand.map_fill_done(map_ppn);
                    }
                }
                let a = resident.plan_write(lpn);
                let b = demand.plan_write(lpn);
                assert_eq!(a.target_ppn, b.target_ppn, "seed {seed} lpn {lpn}");
                assert_eq!(a.background, b.background, "seed {seed} lpn {lpn}");
            }
            for lpn in 0..128 {
                assert_eq!(resident.translate(lpn), demand.translate(lpn));
            }
            assert_eq!(resident.erases(), demand.erases());
        }
    }

    #[test]
    fn miss_emits_fill_and_dirty_writeback_ops() {
        let mut f = DemandPagedFtl::new(geom(), 128, 1, 16, true);
        let mut out = Vec::new();
        // lpn 5 → translation page 0: cold miss, fill only.
        let a = f.map_access(5, true, &mut out);
        assert!(matches!(
            a,
            MapAccess::Miss {
                map_ppn: 0,
                defer: false
            }
        ));
        assert_eq!(out, vec![FtlOp::MapReadPage { ppn: 0 }]);
        f.map_fill_done(0);
        // lpn 20 → page 1: evicts dirty page 0, write-back then fill.
        out.clear();
        f.map_access(20, false, &mut out);
        assert_eq!(
            out,
            vec![
                FtlOp::MapProgramPage { ppn: 0 },
                FtlOp::MapReadPage { ppn: 1 }
            ]
        );
    }

    #[test]
    fn demand_mode_defers_fmmu_does_not() {
        let mut out = Vec::new();
        let mut d = DemandPagedFtl::new(geom(), 128, 1, 16, false);
        assert!(matches!(
            d.map_access(0, false, &mut out),
            MapAccess::Miss { defer: true, .. }
        ));
        out.clear();
        let mut h = DemandPagedFtl::new(geom(), 128, 1, 16, true);
        assert!(matches!(
            h.map_access(0, false, &mut out),
            MapAccess::Miss { defer: false, .. }
        ));
    }

    #[test]
    fn reset_restores_cold_cache() {
        let mut f = DemandPagedFtl::new(geom(), 128, 2, 16, false);
        let mut out = Vec::new();
        f.map_access(0, true, &mut out);
        if let MapAccess::Miss { map_ppn, .. } = f.map_access(0, true, &mut out) {
            let _ = map_ppn;
        }
        f.map_fill_done(0);
        f.plan_write(0);
        f.reset();
        assert_eq!(f.translate(0), None);
        assert_eq!(f.cache().occupied(), 0);
        out.clear();
        assert!(matches!(
            f.map_access(0, false, &mut out),
            MapAccess::Miss { .. }
        ));
    }
}
