//! Page-level mapping FTL with striped allocation, greedy GC and
//! wear-aware free-block selection.
//!
//! Block allocation, victim selection and wear bookkeeping live in the
//! steady-state layer ([`crate::controller::ftl::steady`]); this module
//! owns the mapping tables (lpn ↔ ppn) and drives the copy-back loops
//! that keep them consistent across collections.

use crate::controller::ftl::packed::PackedLazyArray;
use crate::controller::ftl::steady::{ChipAllocator, GcTuning};
use crate::controller::ftl::{Ftl, FtlOp};
use crate::nand::geometry::{Geometry, PageAddr};

const INVALID: u64 = u64::MAX;

/// Page-mapping FTL.
///
/// Sequential logical pages stripe across channels, then ways (via
/// [`Geometry::page_addr`] on the allocation counter), which is what makes
/// way interleaving and channel striping effective on the paper's
/// sequential traces.
pub struct PageMapFtl {
    geom: Geometry,
    /// Exported logical capacity in pages.
    logical: u64,
    /// lpn -> ppn. Packed to the geometry's ppn width and allocated
    /// lazily in segments, so multi-TB drives cost host RAM proportional
    /// to the *touched* logical footprint, not capacity (see
    /// [`crate::controller::ftl::packed`]).
    map: PackedLazyArray,
    /// ppn -> lpn (reverse map, for GC). Same packed-lazy storage.
    rmap: PackedLazyArray,
    chips: Vec<ChipAllocator>,
    /// Next chip for striped allocation (round robin).
    next_chip: usize,
    /// GC/wear-leveling thresholds (the `[steady]` TOML section; defaults
    /// reproduce the historical constants bit-identically).
    pub tuning: GcTuning,
    /// Re-entrancy guard: relocations allocate pages, which must not
    /// recursively trigger another GC cycle mid-reclaim.
    in_gc: bool,
    free_pages: u64,
    relocations: u64,
    erases: u64,
}

impl PageMapFtl {
    /// `logical_pages` is the exported capacity (must leave spare blocks for
    /// GC; typical over-provisioning is ≥ 2 blocks/chip). Out-of-range
    /// capacities are rejected at config load by
    /// [`crate::config::SsdConfig::validate`]; the assert below is defense
    /// in depth for direct construction.
    pub fn new(geom: Geometry, logical_pages: u64) -> PageMapFtl {
        let chips = (0..geom.chips())
            .map(|_| ChipAllocator::new(geom.blocks_per_chip))
            .collect();
        assert!(
            logical_pages <= geom.total_pages(),
            "logical capacity exceeds physical"
        );
        PageMapFtl {
            logical: logical_pages,
            map: PackedLazyArray::new(logical_pages, geom.total_pages()),
            rmap: PackedLazyArray::new(geom.total_pages(), logical_pages),
            chips,
            next_chip: 0,
            tuning: GcTuning::default(),
            in_gc: false,
            free_pages: geom.total_pages(),
            geom,
            relocations: 0,
            erases: 0,
        }
    }

    fn compose_ppn(&self, chip: usize, block: u32, page: u32) -> u64 {
        let (channel, way) = self.geom.chip_addr(chip);
        self.geom.ppn(PageAddr {
            channel,
            way,
            block,
            page,
        })
    }

    fn decompose(&self, ppn: u64) -> (usize, u32, u32) {
        let a = self.geom.page_addr(ppn);
        (self.geom.chip_of(a.channel, a.way), a.block, a.page)
    }

    /// Allocate the next physical page on `chip`, rolling the active block
    /// and triggering GC as needed. Appends any GC ops to `out`.
    fn alloc_on_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> u64 {
        // GC first if we're about to run dry (never re-entrantly: the
        // threshold keeps one spare block for in-flight relocations). Only
        // reclaim when some victim actually holds garbage — erasing
        // fully-valid blocks just churns (and a fresh sequential fill
        // legitimately has none to give back).
        let mut attempts = 0u32;
        while !self.in_gc
            && self.chips[chip].free_len() <= self.tuning.gc_threshold_blocks
            && self.chips[chip].reclaimable(self.geom.pages_per_block)
        {
            // Bound the attempts so pathological (~100% utilized)
            // configurations fail loudly instead of live-locking.
            attempts += 1;
            assert!(
                attempts <= self.geom.blocks_per_chip,
                "GC cannot reclaim space: utilization too high for over-provisioning"
            );
            self.in_gc = true;
            self.gc_chip(chip, out);
            self.in_gc = false;
        }
        let (block, page) = self.chips[chip].alloc_page(self.geom.pages_per_block);
        self.free_pages -= 1;
        self.compose_ppn(chip, block, page)
    }

    /// Greedy GC on one chip: victim = full block with fewest valid pages;
    /// relocate its valid pages into freshly allocated ones, then erase.
    fn gc_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        let vblock = self.chips[chip]
            .take_gc_victim()
            .expect("gc called with no full blocks");
        self.relocate_block(chip, vblock, out);
    }

    /// Copy-back loop shared by GC and wear leveling: relocate every valid
    /// page of `vblock` into freshly allocated ones (updating both maps),
    /// then erase it back into the free pool. The caller has already
    /// removed `vblock` from the full-block list.
    fn relocate_block(&mut self, chip: usize, vblock: u32, out: &mut Vec<FtlOp>) {
        for page in 0..self.geom.pages_per_block {
            let src = self.compose_ppn(chip, vblock, page);
            let lpn = self.rmap.get(src);
            if lpn != INVALID {
                out.push(FtlOp::ReadPage { ppn: src });
                let dst = self.alloc_on_chip(chip, out);
                out.push(FtlOp::ProgramPage { ppn: dst });
                self.map.set(lpn, dst);
                self.rmap.set(dst, lpn);
                self.rmap.set(src, INVALID);
                let (_, dblock, _) = self.decompose(dst);
                self.chips[chip].valid[dblock as usize] += 1;
                self.chips[chip].valid[vblock as usize] -= 1;
                self.relocations += 1;
            }
        }
        debug_assert_eq!(self.chips[chip].valid[vblock as usize], 0);
        out.push(FtlOp::EraseBlock {
            chip,
            block: vblock,
        });
        self.chips[chip].note_erased(vblock);
        self.free_pages += self.geom.pages_per_block as u64;
        self.erases += 1;
    }

    /// Static wear leveling: if the chip's P/E spread exceeds the
    /// threshold, forcibly relocate the coldest (lowest-wear) full block so
    /// it re-enters the free pool. Keeps cold data from pinning low-wear
    /// blocks forever (§2.2.1: wear leveling "plays a critical role to
    /// maintain the initial performance and capacity of an SSD over time").
    fn maybe_static_wl(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        if self.in_gc {
            return;
        }
        let Some(vblock) = self.chips[chip].take_wl_victim(self.tuning.static_wl_threshold)
        else {
            return;
        };
        self.in_gc = true;
        self.relocate_block(chip, vblock, out);
        self.in_gc = false;
    }

    /// Max-min wear spread across all blocks of all chips.
    pub fn wear_spread(&self) -> u32 {
        let all = self.chips.iter().flat_map(|c| c.wear.iter().copied());
        let max = all.clone().max().unwrap_or(0);
        let min = all.min().unwrap_or(0);
        max - min
    }

    /// Total valid (live) pages across all chips — must equal the number of
    /// currently-mapped lpns at all times (GC conservation invariant; used
    /// by the property tests).
    pub fn valid_pages_total(&self) -> u64 {
        self.chips
            .iter()
            .map(|c| c.valid.iter().map(|&v| v as u64).sum::<u64>())
            .sum()
    }

    /// Smallest per-chip free-block count (the GC floor the threshold
    /// defends; used by the property tests).
    pub fn min_free_blocks(&self) -> u32 {
        self.chips.iter().map(|c| c.free_len()).min().unwrap_or(0)
    }
}

impl Ftl for PageMapFtl {
    fn translate(&self, lpn: u64) -> Option<u64> {
        if lpn >= self.logical {
            return None;
        }
        let p = self.map.get(lpn);
        (p != INVALID).then_some(p)
    }

    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64 {
        assert!(lpn < self.logical, "lpn out of range");
        // Invalidate the old location.
        let old = self.map.get(lpn);
        if old != INVALID {
            self.rmap.set(old, INVALID);
            let (chip, block, _) = self.decompose(old);
            self.chips[chip].valid[block as usize] -= 1;
        }
        // Stripe: round-robin chip selection in geometry order. The static
        // wear-leveling check is O(blocks); amortize it to block-roll
        // boundaries (perf pass, EXPERIMENTS.md §Perf — it was 31% of the
        // write path when run per page).
        let chip = self.next_chip;
        self.next_chip = (self.next_chip + 1) % self.chips.len();
        if self.chips[chip].next_page == 0 {
            self.maybe_static_wl(chip, out);
        }
        let ppn = self.alloc_on_chip(chip, out);
        self.map.set(lpn, ppn);
        self.rmap.set(ppn, lpn);
        let (c, block, _) = self.decompose(ppn);
        self.chips[c].valid[block as usize] += 1;
        ppn
    }

    fn set_gc_tuning(&mut self, tuning: GcTuning) {
        self.tuning = tuning;
    }

    fn plan_wear_level_into(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> bool {
        if self.in_gc || chip >= self.chips.len() {
            return false;
        }
        // The coordinator decided *when* (the chip's measured P/E spread
        // crossed the `[steady]` limit); pick the coldest full block that
        // strictly lags the chip maximum, so a uniformly-worn chip is
        // never churned.
        let Some(vblock) = self.chips[chip].take_wl_victim(0) else {
            return false;
        };
        self.in_gc = true;
        self.relocate_block(chip, vblock, out);
        self.in_gc = false;
        true
    }

    fn reset(&mut self) {
        self.map.reset();
        self.rmap.reset();
        let blocks = self.geom.blocks_per_chip;
        for c in &mut self.chips {
            c.reset(blocks);
        }
        self.next_chip = 0;
        self.in_gc = false;
        self.free_pages = self.geom.total_pages();
        self.relocations = 0;
        self.erases = 0;
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }
    fn logical_capacity(&self) -> u64 {
        self.logical
    }
    fn free_pages(&self) -> u64 {
        self.free_pages
    }
    fn relocations(&self) -> u64 {
        self.relocations
    }
    fn erases(&self) -> u64 {
        self.erases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ftl::check_mapping_consistency;

    fn geom(channels: u16, ways: u16) -> Geometry {
        Geometry {
            channels,
            ways,
            blocks_per_chip: 8,
            pages_per_block: 16,
            page_bytes: 2048,
        }
    }

    #[test]
    fn sequential_writes_stripe_across_chips() {
        let g = geom(2, 2);
        let mut f = PageMapFtl::new(g, 64);
        let mut chips = Vec::new();
        for lpn in 0..8 {
            let plan = f.plan_write(lpn);
            assert!(plan.background.is_empty());
            let a = g.page_addr(plan.target_ppn);
            chips.push((a.channel, a.way));
        }
        // 4 chips, round robin, repeated twice.
        assert_eq!(chips[0..4], chips[4..8]);
        let uniq: std::collections::HashSet<_> = chips[0..4].iter().collect();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn translate_follows_latest_write() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 32);
        assert_eq!(f.translate(3), None);
        let p1 = f.plan_write(3).target_ppn;
        assert_eq!(f.translate(3), Some(p1));
        let p2 = f.plan_write(3).target_ppn;
        assert_ne!(p1, p2, "rewrite must go out-of-place");
        assert_eq!(f.translate(3), Some(p2));
    }

    #[test]
    fn gc_reclaims_and_stays_consistent() {
        let g = geom(1, 1); // 8 blocks x 16 pages = 128 physical
        let mut f = PageMapFtl::new(g, 64); // 50% utilization
        let mut total_bg = 0;
        // Write far more than physical capacity to force steady-state GC.
        for round in 0..20 {
            for lpn in 0..64 {
                let plan = f.plan_write(lpn);
                total_bg += plan.background.len();
                assert!(
                    plan.target_ppn < g.total_pages(),
                    "round {round}: ppn in range"
                );
            }
        }
        assert!(f.erases() > 0, "GC must have erased blocks");
        assert!(total_bg > 0);
        let lpns: Vec<u64> = (0..64).collect();
        check_mapping_consistency(&f, &lpns).unwrap();
    }

    #[test]
    fn hot_cold_skew_relocates_cold_data() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        f.tuning.static_wl_threshold = 3;
        // Cold data in lpns 0..32, then hammer lpn 32..40. Greedy GC alone
        // would cycle the hot blocks forever; static WL must eventually
        // relocate the pinned cold blocks.
        for lpn in 0..32 {
            f.plan_write(lpn);
        }
        for _ in 0..80 {
            for lpn in 32..40 {
                f.plan_write(lpn);
            }
        }
        assert!(f.relocations() > 0, "GC must relocate cold valid pages");
        // Cold data still readable.
        for lpn in 0..32 {
            assert!(f.translate(lpn).is_some());
        }
        check_mapping_consistency(&f, &(0..64).collect::<Vec<_>>()).unwrap();
    }

    #[test]
    fn wear_stays_bounded_under_uniform_rewrites() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        for _ in 0..30 {
            for lpn in 0..64 {
                f.plan_write(lpn);
            }
        }
        // Dynamic + static wear leveling keep the spread bounded by the
        // static threshold (+1 transient).
        assert!(
            f.wear_spread() <= f.tuning.static_wl_threshold + 2,
            "spread={}",
            f.wear_spread()
        );
    }

    /// The coordinator-driven wear-leveling entry relocates the coldest
    /// full block, preserves every mapping, and refuses to churn a chip
    /// whose full blocks already sit at max wear.
    #[test]
    fn plan_wear_level_relocates_coldest_block() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        // Disable the FTL-internal static leveler so only the forced entry
        // moves cold data.
        f.tuning.static_wl_threshold = u32::MAX;
        for lpn in 0..32 {
            f.plan_write(lpn); // two cold full blocks
        }
        for _ in 0..40 {
            for lpn in 32..40 {
                f.plan_write(lpn); // hot churn builds a wear spread
            }
        }
        assert!(f.wear_spread() > 0, "hot/cold skew must build a spread");
        let mut out = Vec::new();
        assert!(f.plan_wear_level_into(0, &mut out));
        assert!(
            out.iter()
                .any(|op| matches!(op, FtlOp::EraseBlock { .. })),
            "forced relocation must erase the victim"
        );
        for lpn in 0..32 {
            assert!(f.translate(lpn).is_some(), "lpn {lpn} lost by WL");
        }
        check_mapping_consistency(&f, &(0..64).collect::<Vec<_>>()).unwrap();
        // Out-of-range chip and re-entrant calls are refused.
        let mut out2 = Vec::new();
        assert!(!f.plan_wear_level_into(99, &mut out2));
        assert!(out2.is_empty());
    }

    #[test]
    fn reset_restores_factory_state_and_determinism() {
        let g = geom(2, 2);
        let run = |f: &mut PageMapFtl| -> Vec<u64> {
            (0..48).map(|lpn| f.plan_write(lpn).target_ppn).collect()
        };
        let mut fresh = PageMapFtl::new(g, 64);
        let expect = run(&mut fresh);
        // Dirty a second instance heavily, then reset: identical behaviour.
        let mut reused = PageMapFtl::new(g, 64);
        for round in 0..10 {
            for lpn in 0..64 {
                reused.plan_write((lpn + round) % 64);
            }
        }
        reused.reset();
        assert_eq!(reused.free_pages(), g.total_pages());
        assert_eq!(reused.erases(), 0);
        assert_eq!(reused.translate(0), None);
        assert_eq!(run(&mut reused), expect);
    }

    #[test]
    fn free_pages_accounting() {
        let g = geom(2, 1);
        let mut f = PageMapFtl::new(g, 64);
        let before = f.free_pages();
        f.plan_write(0);
        assert_eq!(f.free_pages(), before - 1);
    }

    /// Valid-page conservation: the allocator's live-page total equals the
    /// number of currently-mapped lpns at every step, through collections.
    #[test]
    fn valid_page_count_tracks_mapped_lpns() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        let mut mapped = std::collections::BTreeSet::new();
        for round in 0..15u64 {
            for lpn in 0..64 {
                f.plan_write((lpn * 7 + round) % 64);
                mapped.insert((lpn * 7 + round) % 64);
                assert_eq!(
                    f.valid_pages_total(),
                    mapped.len() as u64,
                    "conservation broken at round {round} lpn {lpn}"
                );
            }
        }
        assert!(f.erases() > 0, "the loop must have exercised GC");
    }
}
