//! Page-level mapping FTL with striped allocation, greedy GC and
//! wear-aware free-block selection.

use crate::controller::ftl::{Ftl, FtlOp};
use crate::nand::geometry::{Geometry, PageAddr};

const INVALID: u64 = u64::MAX;

/// Per-chip allocation state.
struct ChipAlloc {
    /// Free (erased) blocks, kept unordered; selection scans for min wear.
    free_blocks: Vec<u32>,
    /// Block currently being filled.
    active_block: u32,
    /// Next page within the active block.
    next_page: u32,
    /// FTL-visible erase count per block (wear).
    wear: Vec<u32>,
    /// Valid-page count per block.
    valid: Vec<u32>,
    /// Blocks that are completely written (candidates for GC).
    full_blocks: Vec<u32>,
}

/// Page-mapping FTL.
///
/// Sequential logical pages stripe across channels, then ways (via
/// [`Geometry::page_addr`] on the allocation counter), which is what makes
/// way interleaving and channel striping effective on the paper's
/// sequential traces.
pub struct PageMapFtl {
    geom: Geometry,
    /// lpn -> ppn.
    map: Vec<u64>,
    /// ppn -> lpn (reverse map, for GC).
    rmap: Vec<u64>,
    chips: Vec<ChipAlloc>,
    /// Next chip for striped allocation (round robin).
    next_chip: usize,
    /// GC triggers when a chip's free blocks fall to this threshold. Must
    /// be ≥ 2: one block of headroom for the relocation overflow while a
    /// victim is being reclaimed.
    pub gc_threshold_blocks: u32,
    /// Static wear leveling triggers when a chip's P/E spread exceeds this.
    pub static_wl_threshold: u32,
    /// Re-entrancy guard: relocations allocate pages, which must not
    /// recursively trigger another GC cycle mid-reclaim.
    in_gc: bool,
    free_pages: u64,
    relocations: u64,
    erases: u64,
}

impl PageMapFtl {
    /// `logical_pages` is the exported capacity (must leave spare blocks for
    /// GC; typical over-provisioning is ≥ 2 blocks/chip).
    pub fn new(geom: Geometry, logical_pages: u64) -> PageMapFtl {
        let chips = (0..geom.chips())
            .map(|_| {
                let mut free: Vec<u32> = (0..geom.blocks_per_chip).collect();
                let active = free.remove(0);
                ChipAlloc {
                    free_blocks: free,
                    active_block: active,
                    next_page: 0,
                    wear: vec![0; geom.blocks_per_chip as usize],
                    valid: vec![0; geom.blocks_per_chip as usize],
                    full_blocks: Vec::new(),
                }
            })
            .collect();
        assert!(
            logical_pages <= geom.total_pages(),
            "logical capacity exceeds physical"
        );
        PageMapFtl {
            map: vec![INVALID; logical_pages as usize],
            rmap: vec![INVALID; geom.total_pages() as usize],
            chips,
            next_chip: 0,
            gc_threshold_blocks: 2,
            static_wl_threshold: 8,
            in_gc: false,
            free_pages: geom.total_pages(),
            geom,
            relocations: 0,
            erases: 0,
        }
    }

    fn compose_ppn(&self, chip: usize, block: u32, page: u32) -> u64 {
        let channels = self.geom.channels as u64;
        let ways = self.geom.ways as u64;
        let ch = (chip as u64 % channels) as u16;
        let way = (chip as u64 / channels % ways) as u16;
        self.geom.ppn(PageAddr {
            channel: ch,
            way,
            block,
            page,
        })
    }

    fn decompose(&self, ppn: u64) -> (usize, u32, u32) {
        let a = self.geom.page_addr(ppn);
        let chip = a.way as usize * self.geom.channels as usize + a.channel as usize;
        (chip, a.block, a.page)
    }

    /// Allocate the next physical page on `chip`, rolling the active block
    /// and triggering GC as needed. Appends any GC ops to `out`.
    fn alloc_on_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> u64 {
        // GC first if we're about to run dry (never re-entrantly: the
        // threshold keeps one spare block for in-flight relocations).
        let mut attempts = 0u32;
        while !self.in_gc && self.chips[chip].free_blocks.len() as u32 <= self.gc_threshold_blocks
        {
            // Only reclaim when some victim actually holds garbage —
            // erasing fully-valid blocks just churns (and a fresh
            // sequential fill legitimately has none to give back).
            let c = &self.chips[chip];
            let reclaimable = c
                .full_blocks
                .iter()
                .any(|&b| c.valid[b as usize] < self.geom.pages_per_block);
            if !reclaimable {
                break;
            }
            // Bound the attempts so pathological (~100% utilized)
            // configurations fail loudly instead of live-locking.
            attempts += 1;
            assert!(
                attempts <= self.geom.blocks_per_chip,
                "GC cannot reclaim space: utilization too high for over-provisioning"
            );
            self.in_gc = true;
            self.gc_chip(chip, out);
            self.in_gc = false;
        }
        let c = &mut self.chips[chip];
        let block = c.active_block;
        let page = c.next_page;
        c.next_page += 1;
        if c.next_page == self.geom.pages_per_block {
            // Active block is full; pick the lowest-wear free block next
            // (dynamic wear leveling).
            c.full_blocks.push(block);
            let (idx, _) = c
                .free_blocks
                .iter()
                .enumerate()
                .min_by_key(|(_, &b)| c.wear[b as usize])
                .expect("out of free blocks: over-provisioning exhausted");
            c.active_block = c.free_blocks.swap_remove(idx);
            c.next_page = 0;
        }
        self.free_pages -= 1;
        self.compose_ppn(chip, block, page)
    }

    /// Greedy GC on one chip: victim = full block with fewest valid pages;
    /// relocate its valid pages into freshly allocated ones, then erase.
    fn gc_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        let victim = {
            let c = &self.chips[chip];
            let (idx, _) = c
                .full_blocks
                .iter()
                .enumerate()
                .min_by_key(|(_, &b)| c.valid[b as usize])
                .expect("gc called with no full blocks");
            (idx, c.full_blocks[idx])
        };
        let (vidx, vblock) = victim;
        self.chips[chip].full_blocks.swap_remove(vidx);

        // Relocate valid pages.
        for page in 0..self.geom.pages_per_block {
            let src = self.compose_ppn(chip, vblock, page);
            let lpn = self.rmap[src as usize];
            if lpn != INVALID {
                out.push(FtlOp::ReadPage { ppn: src });
                let dst = self.alloc_on_chip(chip, out);
                out.push(FtlOp::ProgramPage { ppn: dst });
                self.map[lpn as usize] = dst;
                self.rmap[dst as usize] = lpn;
                self.rmap[src as usize] = INVALID;
                let (_, dblock, _) = self.decompose(dst);
                self.chips[chip].valid[dblock as usize] += 1;
                self.chips[chip].valid[vblock as usize] -= 1;
                self.relocations += 1;
            }
        }
        debug_assert_eq!(self.chips[chip].valid[vblock as usize], 0);
        out.push(FtlOp::EraseBlock {
            chip,
            block: vblock,
        });
        self.chips[chip].wear[vblock as usize] += 1;
        self.chips[chip].free_blocks.push(vblock);
        self.free_pages += self.geom.pages_per_block as u64;
        self.erases += 1;
    }

    /// Static wear leveling: if the chip's P/E spread exceeds the
    /// threshold, forcibly relocate the coldest (lowest-wear) full block so
    /// it re-enters the free pool. Keeps cold data from pinning low-wear
    /// blocks forever (§2.2.1: wear leveling "plays a critical role to
    /// maintain the initial performance and capacity of an SSD over time").
    fn maybe_static_wl(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        let c = &self.chips[chip];
        let max = c.wear.iter().copied().max().unwrap_or(0);
        let Some((vidx, &vblock)) = c
            .full_blocks
            .iter()
            .enumerate()
            .min_by_key(|(_, &b)| c.wear[b as usize])
        else {
            return;
        };
        if max - c.wear[vblock as usize] <= self.static_wl_threshold || self.in_gc {
            return;
        }
        self.in_gc = true;
        self.chips[chip].full_blocks.swap_remove(vidx);
        for page in 0..self.geom.pages_per_block {
            let src = self.compose_ppn(chip, vblock, page);
            let lpn = self.rmap[src as usize];
            if lpn != INVALID {
                out.push(FtlOp::ReadPage { ppn: src });
                let dst = self.alloc_on_chip(chip, out);
                out.push(FtlOp::ProgramPage { ppn: dst });
                self.map[lpn as usize] = dst;
                self.rmap[dst as usize] = lpn;
                self.rmap[src as usize] = INVALID;
                let (_, dblock, _) = self.decompose(dst);
                self.chips[chip].valid[dblock as usize] += 1;
                self.chips[chip].valid[vblock as usize] -= 1;
                self.relocations += 1;
            }
        }
        out.push(FtlOp::EraseBlock {
            chip,
            block: vblock,
        });
        self.chips[chip].wear[vblock as usize] += 1;
        self.chips[chip].free_blocks.push(vblock);
        self.free_pages += self.geom.pages_per_block as u64;
        self.erases += 1;
        self.in_gc = false;
    }

    /// Max-min wear spread across all blocks of all chips.
    pub fn wear_spread(&self) -> u32 {
        let all = self.chips.iter().flat_map(|c| c.wear.iter().copied());
        let max = all.clone().max().unwrap_or(0);
        let min = all.min().unwrap_or(0);
        max - min
    }
}

impl Ftl for PageMapFtl {
    fn translate(&self, lpn: u64) -> Option<u64> {
        let p = *self.map.get(lpn as usize)?;
        (p != INVALID).then_some(p)
    }

    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64 {
        assert!((lpn as usize) < self.map.len(), "lpn out of range");
        // Invalidate the old location.
        let old = self.map[lpn as usize];
        if old != INVALID {
            self.rmap[old as usize] = INVALID;
            let (chip, block, _) = self.decompose(old);
            self.chips[chip].valid[block as usize] -= 1;
        }
        // Stripe: round-robin chip selection in geometry order. The static
        // wear-leveling check is O(blocks); amortize it to block-roll
        // boundaries (perf pass, EXPERIMENTS.md §Perf — it was 31% of the
        // write path when run per page).
        let chip = self.next_chip;
        self.next_chip = (self.next_chip + 1) % self.chips.len();
        if self.chips[chip].next_page == 0 {
            self.maybe_static_wl(chip, out);
        }
        let ppn = self.alloc_on_chip(chip, out);
        self.map[lpn as usize] = ppn;
        self.rmap[ppn as usize] = lpn;
        let (c, block, _) = self.decompose(ppn);
        self.chips[c].valid[block as usize] += 1;
        ppn
    }

    fn reset(&mut self) {
        self.map.fill(INVALID);
        self.rmap.fill(INVALID);
        let blocks = self.geom.blocks_per_chip;
        for c in &mut self.chips {
            c.free_blocks.clear();
            c.free_blocks.extend(1..blocks);
            c.active_block = 0;
            c.next_page = 0;
            c.wear.fill(0);
            c.valid.fill(0);
            c.full_blocks.clear();
        }
        self.next_chip = 0;
        self.in_gc = false;
        self.free_pages = self.geom.total_pages();
        self.relocations = 0;
        self.erases = 0;
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }
    fn free_pages(&self) -> u64 {
        self.free_pages
    }
    fn relocations(&self) -> u64 {
        self.relocations
    }
    fn erases(&self) -> u64 {
        self.erases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ftl::check_mapping_consistency;

    fn geom(channels: u16, ways: u16) -> Geometry {
        Geometry {
            channels,
            ways,
            blocks_per_chip: 8,
            pages_per_block: 16,
            page_bytes: 2048,
        }
    }

    #[test]
    fn sequential_writes_stripe_across_chips() {
        let g = geom(2, 2);
        let mut f = PageMapFtl::new(g, 64);
        let mut chips = Vec::new();
        for lpn in 0..8 {
            let plan = f.plan_write(lpn);
            assert!(plan.background.is_empty());
            let a = g.page_addr(plan.target_ppn);
            chips.push((a.channel, a.way));
        }
        // 4 chips, round robin, repeated twice.
        assert_eq!(chips[0..4], chips[4..8]);
        let uniq: std::collections::HashSet<_> = chips[0..4].iter().collect();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn translate_follows_latest_write() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 32);
        assert_eq!(f.translate(3), None);
        let p1 = f.plan_write(3).target_ppn;
        assert_eq!(f.translate(3), Some(p1));
        let p2 = f.plan_write(3).target_ppn;
        assert_ne!(p1, p2, "rewrite must go out-of-place");
        assert_eq!(f.translate(3), Some(p2));
    }

    #[test]
    fn gc_reclaims_and_stays_consistent() {
        let g = geom(1, 1); // 8 blocks x 16 pages = 128 physical
        let mut f = PageMapFtl::new(g, 64); // 50% utilization
        let mut total_bg = 0;
        // Write far more than physical capacity to force steady-state GC.
        for round in 0..20 {
            for lpn in 0..64 {
                let plan = f.plan_write(lpn);
                total_bg += plan.background.len();
                assert!(
                    plan.target_ppn < g.total_pages(),
                    "round {round}: ppn in range"
                );
            }
        }
        assert!(f.erases() > 0, "GC must have erased blocks");
        assert!(total_bg > 0);
        let lpns: Vec<u64> = (0..64).collect();
        check_mapping_consistency(&f, &lpns).unwrap();
    }

    #[test]
    fn hot_cold_skew_relocates_cold_data() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        f.static_wl_threshold = 3;
        // Cold data in lpns 0..32, then hammer lpn 32..40. Greedy GC alone
        // would cycle the hot blocks forever; static WL must eventually
        // relocate the pinned cold blocks.
        for lpn in 0..32 {
            f.plan_write(lpn);
        }
        for _ in 0..80 {
            for lpn in 32..40 {
                f.plan_write(lpn);
            }
        }
        assert!(f.relocations() > 0, "GC must relocate cold valid pages");
        // Cold data still readable.
        for lpn in 0..32 {
            assert!(f.translate(lpn).is_some());
        }
        check_mapping_consistency(&f, &(0..64).collect::<Vec<_>>()).unwrap();
    }

    #[test]
    fn wear_stays_bounded_under_uniform_rewrites() {
        let g = geom(1, 1);
        let mut f = PageMapFtl::new(g, 64);
        for _ in 0..30 {
            for lpn in 0..64 {
                f.plan_write(lpn);
            }
        }
        // Dynamic + static wear leveling keep the spread bounded by the
        // static threshold (+1 transient).
        assert!(
            f.wear_spread() <= f.static_wl_threshold + 2,
            "spread={}",
            f.wear_spread()
        );
    }

    #[test]
    fn reset_restores_factory_state_and_determinism() {
        let g = geom(2, 2);
        let run = |f: &mut PageMapFtl| -> Vec<u64> {
            (0..48).map(|lpn| f.plan_write(lpn).target_ppn).collect()
        };
        let mut fresh = PageMapFtl::new(g, 64);
        let expect = run(&mut fresh);
        // Dirty a second instance heavily, then reset: identical behaviour.
        let mut reused = PageMapFtl::new(g, 64);
        for round in 0..10 {
            for lpn in 0..64 {
                reused.plan_write((lpn + round) % 64);
            }
        }
        reused.reset();
        assert_eq!(reused.free_pages(), g.total_pages());
        assert_eq!(reused.erases(), 0);
        assert_eq!(reused.translate(0), None);
        assert_eq!(run(&mut reused), expect);
    }

    #[test]
    fn free_pages_accounting() {
        let g = geom(2, 1);
        let mut f = PageMapFtl::new(g, 64);
        let before = f.free_pages();
        f.plan_write(0);
        assert_eq!(f.free_pages(), before - 1);
    }
}
