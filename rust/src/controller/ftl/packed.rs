//! Compact lazily-allocated mapping storage.
//!
//! The fully-resident `Vec<u64>` pair the page-map FTL shipped with costs
//! 16 bytes per physical page — a simulated 2-TB drive would need ~10 GB
//! of host RAM before the first event fires. [`PackedLazyArray`] brings
//! that down along two independent axes:
//!
//! * **Packed entries.** The entry width is derived from the value domain
//!   (e.g. 30 bits for a drive with 6×10⁸ physical pages) instead of a
//!   full `u64`, an ~2× saving at realistic geometries.
//! * **Lazy segments.** Storage is split into fixed 2¹⁶-entry segments
//!   allocated on first write; reads of untouched segments return the
//!   invalid sentinel without allocating. Host RAM therefore scales with
//!   the *touched* footprint of the workload, not the drive capacity —
//!   the property the CI memory-footprint lane pins.
//!
//! The externally-visible sentinel is `u64::MAX` ([`INVALID`]), matching
//! the FTL's historical convention; internally it is stored as the
//! all-ones pattern of the packed width, which is why the width is sized
//! so `domain` itself (not just `domain - 1`) fits.

/// External sentinel for "no mapping" (all entries start as this).
pub const INVALID: u64 = u64::MAX;

/// Entries per lazily-allocated segment.
const SEG_ENTRIES: u64 = 1 << 16;

/// A fixed-length array of packed unsigned entries in `0..domain`, all
/// initialized to [`INVALID`], with segment-granular lazy allocation.
#[derive(Debug, Clone)]
pub struct PackedLazyArray {
    len: u64,
    /// Bits per entry; sized so the all-ones sentinel is distinct from
    /// every valid value.
    width: u32,
    /// `width` low bits set (`!0` when `width == 64`).
    mask: u64,
    segments: Vec<Option<Box<[u64]>>>,
}

impl PackedLazyArray {
    /// An array of `len` entries holding values in `0..domain`, all
    /// [`INVALID`].
    pub fn new(len: u64, domain: u64) -> PackedLazyArray {
        // The all-ones pattern is reserved for the sentinel, so the width
        // must fit `domain` itself: values go up to domain-1, sentinel is
        // `mask == domain.next_power_of_two()-ish`.
        let width = (64 - domain.leading_zeros()).max(1);
        let mask = if width == 64 { !0u64 } else { (1u64 << width) - 1 };
        debug_assert!(domain <= mask);
        let segs = len.div_ceil(SEG_ENTRIES) as usize;
        PackedLazyArray {
            len,
            width,
            mask,
            segments: vec![None; segs],
        }
    }

    pub fn len(&self) -> u64 {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Entry `i`, or [`INVALID`] if never set (or set to [`INVALID`]).
    pub fn get(&self, i: u64) -> u64 {
        debug_assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        let seg = match &self.segments[(i / SEG_ENTRIES) as usize] {
            Some(s) => s,
            None => return INVALID,
        };
        let bit = (i % SEG_ENTRIES) * self.width as u64;
        let (w, sh) = ((bit / 64) as usize, (bit % 64) as u32);
        let v = if sh + self.width <= 64 {
            (seg[w] >> sh) & self.mask
        } else {
            ((seg[w] >> sh) | (seg[w + 1] << (64 - sh))) & self.mask
        };
        if v == self.mask {
            INVALID
        } else {
            v
        }
    }

    /// Set entry `i` to `v` (which must be `< domain`) or to [`INVALID`].
    pub fn set(&mut self, i: u64, v: u64) {
        debug_assert!(i < self.len, "index {i} out of bounds (len {})", self.len);
        let v = if v == INVALID {
            self.mask
        } else {
            debug_assert!(v < self.mask, "value {v} does not fit width {}", self.width);
            v
        };
        let words = (SEG_ENTRIES * self.width as u64).div_ceil(64) as usize;
        let seg = self.segments[(i / SEG_ENTRIES) as usize]
            // Fresh segments are all-ones: every entry reads INVALID.
            .get_or_insert_with(|| vec![!0u64; words].into_boxed_slice());
        let bit = (i % SEG_ENTRIES) * self.width as u64;
        let (w, sh) = ((bit / 64) as usize, (bit % 64) as u32);
        seg[w] = (seg[w] & !(self.mask << sh)) | (v << sh);
        if sh + self.width > 64 {
            let spill = sh + self.width - 64;
            let himask = (1u64 << spill) - 1;
            seg[w + 1] = (seg[w + 1] & !himask) | (v >> (64 - sh));
        }
    }

    /// Return every entry to [`INVALID`] and release all segment storage.
    pub fn reset(&mut self) {
        for s in &mut self.segments {
            *s = None;
        }
    }

    /// Bytes of segment storage currently allocated (the lazy footprint;
    /// used by the memory-budget tests).
    pub fn resident_bytes(&self) -> u64 {
        let words = (SEG_ENTRIES * self.width as u64).div_ceil(64);
        self.segments.iter().flatten().count() as u64 * words * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn width_is_derived_from_domain() {
        // domain 8 needs 4 bits (values 0..=7 plus a distinct sentinel).
        assert_eq!(PackedLazyArray::new(10, 8).width, 4);
        assert_eq!(PackedLazyArray::new(10, 7).width, 3);
        assert_eq!(PackedLazyArray::new(10, 1).width, 1);
        assert_eq!(PackedLazyArray::new(10, u64::MAX).width, 64);
        // ~600M physical pages (the 2-TB preset) packs into 30 bits.
        assert_eq!(PackedLazyArray::new(4, 603_979_776).width, 30);
    }

    #[test]
    fn unset_entries_read_invalid_without_allocating() {
        let a = PackedLazyArray::new(1 << 20, 1 << 30);
        assert_eq!(a.get(0), INVALID);
        assert_eq!(a.get((1 << 20) - 1), INVALID);
        assert_eq!(a.resident_bytes(), 0);
    }

    #[test]
    fn set_get_roundtrips_across_word_boundaries() {
        // width 31: entries straddle u64 words at most offsets.
        let domain = (1u64 << 31) - 2;
        let mut a = PackedLazyArray::new(1000, domain);
        for i in 0..1000u64 {
            a.set(i, (i * 2_654_435_761) % domain);
        }
        for i in 0..1000u64 {
            assert_eq!(a.get(i), (i * 2_654_435_761) % domain, "entry {i}");
        }
        // Overwrites stick and INVALID round-trips.
        a.set(500, 42);
        assert_eq!(a.get(500), 42);
        a.set(500, INVALID);
        assert_eq!(a.get(500), INVALID);
        assert_eq!(a.get(499), (499 * 2_654_435_761) % domain);
        assert_eq!(a.get(501), (501 * 2_654_435_761) % domain);
    }

    #[test]
    fn full_width_entries_work() {
        let mut a = PackedLazyArray::new(10, u64::MAX);
        a.set(3, u64::MAX - 1);
        assert_eq!(a.get(3), u64::MAX - 1);
        assert_eq!(a.get(4), INVALID);
    }

    #[test]
    fn only_touched_segments_allocate() {
        let mut a = PackedLazyArray::new(10 * SEG_ENTRIES, 1 << 20);
        a.set(0, 1);
        a.set(9 * SEG_ENTRIES + 5, 2);
        let per_seg = (SEG_ENTRIES * 21).div_ceil(64) * 8;
        assert_eq!(a.resident_bytes(), 2 * per_seg);
        assert_eq!(a.get(0), 1);
        assert_eq!(a.get(9 * SEG_ENTRIES + 5), 2);
        assert_eq!(a.get(5 * SEG_ENTRIES), INVALID);
    }

    #[test]
    fn reset_releases_storage() {
        let mut a = PackedLazyArray::new(100, 1000);
        a.set(7, 99);
        a.reset();
        assert_eq!(a.get(7), INVALID);
        assert_eq!(a.resident_bytes(), 0);
    }
}
