//! Flash translation layer (FTL).
//!
//! §2.2.1/§2.3.2: the FTL maps logical to physical addresses and performs
//! wear leveling and garbage collection. Two mapping schemes are provided:
//!
//! * [`page_map::PageMapFtl`] — page-level mapping with striped allocation
//!   across channels/ways (the scheme that exposes maximal interleaving;
//!   used for the paper's sequential-workload experiments).
//! * [`hybrid::HybridFtl`] — BAST-style hybrid log-block mapping per Kim et
//!   al. \[9\]: data blocks are block-mapped, writes land in a small set of
//!   page-mapped log blocks, merges reclaim them.
//!
//! Both emit *plans* — ordered lists of physical page operations — which the
//! coordinator turns into DES page jobs; the FTL itself is time-free.

pub mod demand;
pub mod hybrid;
pub mod packed;
pub mod page_map;
pub mod steady;
pub mod tiered;

use crate::nand::geometry::Geometry;

/// A physical operation requested by the FTL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FtlOp {
    /// Read physical page `ppn` (GC relocation source or host read).
    ReadPage { ppn: u64 },
    /// Program physical page `ppn` (host write target or GC destination).
    ProgramPage { ppn: u64 },
    /// Erase the block containing physical page `ppn`'s (chip, block).
    EraseBlock { chip: usize, block: u32 },
    /// Tier-migration copy-back read (SLC-tier source page). Same bus/array
    /// cost as [`ReadPage`](FtlOp::ReadPage); the distinct variant lets the
    /// coordinator tag the job `MIG_REQ` so migration traffic is counted
    /// apart from GC (see [`tiered`]).
    MigReadPage { ppn: u64 },
    /// Tier-migration program (MLC-tier destination page).
    MigProgramPage { ppn: u64 },
    /// Demand-paged mapping tier: read the translation page stored at
    /// physical page `ppn` (a map-cache miss fill). Same bus/array cost as
    /// [`ReadPage`](FtlOp::ReadPage); the distinct variant lets the
    /// coordinator tag the job `MAP_REQ` so mapping traffic is counted —
    /// and stall-attributed — apart from host and GC work (see [`demand`]).
    MapReadPage { ppn: u64 },
    /// Demand-paged mapping tier: program back the dirty translation page
    /// stored at physical page `ppn` (a map-cache eviction write-back).
    MapProgramPage { ppn: u64 },
}

/// Outcome of consulting the mapping tier for one host page access
/// ([`Ftl::map_access`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MapAccess {
    /// The FTL keeps its whole table resident — translation is free
    /// (the default for every scheme without a mapping tier).
    Resident,
    /// The covering translation page is cached — translation is free.
    Hit,
    /// The covering translation page is not resident: a fill read (and
    /// possibly a dirty-eviction write-back) was appended to `out`.
    Miss {
        /// Physical page holding the missed translation page; the
        /// coordinator keys deferred host work on it and hands it back
        /// via [`Ftl::map_fill_done`] when the fill read completes.
        map_ppn: u64,
        /// Demand mode: the host op must wait for the fill to complete.
        /// The FMMU variant overlaps translation with array access and
        /// never defers (the miss still costs bus/way contention).
        defer: bool,
    },
}

/// The plan for servicing one logical page write: any GC/merge traffic
/// first, then the host-data program itself.
#[derive(Debug, Clone, Default)]
pub struct WritePlan {
    /// Background ops (GC relocations, merges, erases) in order.
    pub background: Vec<FtlOp>,
    /// The physical page the host data lands in.
    pub target_ppn: u64,
}

/// Common FTL interface used by the coordinator.
pub trait Ftl {
    /// Translate a logical page read; `None` if never written.
    fn translate(&self, lpn: u64) -> Option<u64>;

    /// Allocate (and map) a physical page for writing `lpn`; any
    /// garbage-collection/merge work the allocation forces is appended to
    /// `out` in issue order. Returns the physical page the host data lands
    /// in. This is the hot-path entry: the coordinator passes one pooled
    /// buffer so steady-state dispatch is allocation-free.
    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64;

    /// Allocate (and map) a physical page for writing `lpn`, including any
    /// garbage-collection work the allocation forces. Convenience wrapper
    /// over [`plan_write_into`](Ftl::plan_write_into).
    fn plan_write(&mut self, lpn: u64) -> WritePlan {
        let mut background = Vec::new();
        let target_ppn = self.plan_write_into(lpn, &mut background);
        WritePlan {
            background,
            target_ppn,
        }
    }

    /// Apply steady-state GC/wear-leveling tuning (the `[steady]` TOML
    /// section). The default implementation ignores it — mapping schemes
    /// whose reclamation is demand-driven rather than threshold-driven
    /// (the hybrid log-block FTL) have nothing to tune. Called on
    /// construction and after every [`reset`](Ftl::reset), always: with the
    /// [`steady::GcTuning`] defaults the behaviour is bit-identical to the
    /// pre-steady-state code.
    fn set_gc_tuning(&mut self, tuning: steady::GcTuning) {
        let _ = tuning;
    }

    /// Coordinator-driven wear leveling: relocate the coldest full block
    /// of `chip` so it re-enters the free pool, appending the copy-back
    /// ops to `out`. Called by the coordinator when the chip's *measured*
    /// P/E spread ([`crate::nand::chip::Chip::wear_spread`]) exceeds the
    /// `[steady]` limit — the coordinator decides *when*, the FTL decides
    /// *what*. Returns false when nothing was relocated (no lagging full
    /// block, or the FTL does not support forced relocation).
    fn plan_wear_level_into(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> bool {
        let _ = (chip, out);
        false
    }

    /// Consult the demand-paged mapping tier for a host access to `lpn`
    /// (`write` distinguishes lookups that will dirty the translation
    /// page). On a miss the tier appends its fill/write-back flash ops to
    /// `out`; the coordinator issues them as `MAP_REQ` jobs. The default —
    /// every fully-resident scheme — reports [`MapAccess::Resident`] and
    /// touches nothing.
    fn map_access(&mut self, lpn: u64, write: bool, out: &mut Vec<FtlOp>) -> MapAccess {
        let _ = (lpn, write, out);
        MapAccess::Resident
    }

    /// A [`FtlOp::MapReadPage`] fill issued by
    /// [`map_access`](Ftl::map_access) completed for the translation page
    /// stored at `map_ppn`; the tier marks it resident. Default: nothing
    /// to do.
    fn map_fill_done(&mut self, map_ppn: u64) {
        let _ = map_ppn;
    }

    /// Return to the just-initialized state (empty mapping, all blocks
    /// free, zero counters) without dropping the mapping-table allocations
    /// — used when a sweep worker reuses one simulator across runs.
    fn reset(&mut self);

    /// Geometry this FTL manages.
    fn geometry(&self) -> &Geometry;

    /// Exported logical capacity in pages — the highest lpn this FTL
    /// accepts is `logical_capacity() - 1`. For the page-map FTL this is
    /// the `logical_pages` it was constructed with; the hybrid FTL derives
    /// it from its own log-block reserve. Preconditioning fills exactly
    /// this range.
    fn logical_capacity(&self) -> u64;

    /// Number of free (erased, unallocated) pages remaining.
    fn free_pages(&self) -> u64;

    /// Total background page relocations performed (GC traffic).
    fn relocations(&self) -> u64;

    /// Total block erases issued.
    fn erases(&self) -> u64;
}

/// Invariant checks shared by FTL implementations (used by tests and the
/// property harness).
pub fn check_mapping_consistency<F: Ftl>(ftl: &F, lpns: &[u64]) -> Result<(), String> {
    use std::collections::HashSet;
    let mut seen = HashSet::new();
    for &lpn in lpns {
        if let Some(ppn) = ftl.translate(lpn) {
            if ppn >= ftl.geometry().total_pages() {
                return Err(format!("lpn {lpn} maps to out-of-range ppn {ppn}"));
            }
            if !seen.insert(ppn) {
                return Err(format!("ppn {ppn} mapped by two lpns"));
            }
        }
    }
    Ok(())
}
