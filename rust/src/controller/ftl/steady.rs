//! Steady-state block management: the allocation + garbage-collection
//! layer shared by FTL implementations.
//!
//! Fresh-drive runs (the paper's Tables 3–5) never exercise this code:
//! sequential fills allocate monotonically and produce no garbage. Under
//! *sustained* load — random rewrites over a full drive — every host write
//! invalidates an old page, and reclaiming space costs copy-back traffic
//! (read → program per valid page, then an erase) that competes with host
//! requests on the same channels and ways. This module concentrates the
//! per-chip state and the selection policies that determine how much of
//! that traffic exists:
//!
//! * **Greedy GC victim selection** — the full block with the fewest valid
//!   pages frees the most space per erase (minimizes write amplification
//!   for a given over-provisioning level).
//! * **Wear-aware free-block choice** — the lowest-wear free block becomes
//!   the next active block (dynamic wear leveling).
//! * **Cold-block relocation** — the coldest (lowest-wear) full block can
//!   be forcibly recycled (static wear leveling), either on the FTL's own
//!   threshold or on demand from the coordinator when the *chip's* measured
//!   P/E spread (`crate::nand::chip::Chip::wear_spread`) exceeds the
//!   `[steady]` configuration's limit.
//!
//! The mapping-table side of GC (which lpn lives where) stays in the FTL
//! implementations; this layer is policy + per-chip bookkeeping, so both
//! concerns can evolve independently. Tuning comes from
//! [`GcTuning`], fed by the `[steady]` TOML section
//! (`crate::config::SteadyConfig`). With the defaults the behaviour is
//! bit-identical to the pre-steady-state simulator (golden-tested).

/// Tuning knobs for the steady-state layer. Defaults reproduce the
/// historical constants exactly, so an FTL tuned with `GcTuning::default()`
/// behaves bit-identically to the pre-`[steady]` code.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GcTuning {
    /// GC triggers when a chip's free blocks fall to this threshold. Must
    /// be ≥ 2: one block of headroom for the relocation overflow while a
    /// victim is being reclaimed.
    pub gc_threshold_blocks: u32,
    /// FTL-internal static wear leveling triggers when a chip's P/E spread
    /// exceeds this.
    pub static_wl_threshold: u32,
}

impl Default for GcTuning {
    fn default() -> Self {
        GcTuning {
            gc_threshold_blocks: 2,
            static_wl_threshold: 8,
        }
    }
}

/// Per-chip block-allocation state: the free pool, the block being filled,
/// per-block wear and valid-page counts, and the full-block GC candidate
/// list. One per chip; owned by the FTL.
pub struct ChipAllocator {
    /// Free (erased) blocks, kept unordered; selection scans for min wear.
    pub free_blocks: Vec<u32>,
    /// Block currently being filled.
    pub active_block: u32,
    /// Next page within the active block.
    pub next_page: u32,
    /// FTL-visible erase count per block (wear).
    pub wear: Vec<u32>,
    /// Valid-page count per block.
    pub valid: Vec<u32>,
    /// Blocks that are completely written (candidates for GC).
    pub full_blocks: Vec<u32>,
}

impl ChipAllocator {
    /// Fresh allocator over `blocks` erased blocks; block 0 is active.
    pub fn new(blocks: u32) -> ChipAllocator {
        ChipAllocator {
            free_blocks: (1..blocks).collect(),
            active_block: 0,
            next_page: 0,
            wear: vec![0; blocks as usize],
            valid: vec![0; blocks as usize],
            full_blocks: Vec::new(),
        }
    }

    /// Return to the just-initialized state without dropping allocations
    /// (sweep-worker reuse).
    pub fn reset(&mut self, blocks: u32) {
        self.free_blocks.clear();
        self.free_blocks.extend(1..blocks);
        self.active_block = 0;
        self.next_page = 0;
        self.wear.fill(0);
        self.valid.fill(0);
        self.full_blocks.clear();
    }

    /// Free (erased) block count.
    pub fn free_len(&self) -> u32 {
        self.free_blocks.len() as u32
    }

    /// Does any full block hold at least one invalid page? Erasing
    /// fully-valid blocks just churns, so GC only runs when this is true.
    pub fn reclaimable(&self, pages_per_block: u32) -> bool {
        self.full_blocks
            .iter()
            .any(|&b| self.valid[b as usize] < pages_per_block)
    }

    /// Greedy GC victim: the full block with the fewest valid pages,
    /// removed from the full-block list. `None` when no block is full.
    pub fn take_gc_victim(&mut self) -> Option<u32> {
        let (idx, _) = self
            .full_blocks
            .iter()
            .enumerate()
            .min_by_key(|(_, &b)| self.valid[b as usize])?;
        Some(self.full_blocks.swap_remove(idx))
    }

    /// Wear-leveling victim: the coldest (lowest-wear) full block, removed
    /// from the full-block list — but only if its wear lags the chip
    /// maximum by *more than* `threshold` (0 = any strictly-lagging block).
    /// Keeps cold data from pinning low-wear blocks forever while never
    /// churning a block already at max wear.
    pub fn take_wl_victim(&mut self, threshold: u32) -> Option<u32> {
        let max = self.wear.iter().copied().max().unwrap_or(0);
        let (idx, &vblock) = self
            .full_blocks
            .iter()
            .enumerate()
            .min_by_key(|(_, &b)| self.wear[b as usize])?;
        if max - self.wear[vblock as usize] <= threshold {
            return None;
        }
        self.full_blocks.swap_remove(idx);
        Some(vblock)
    }

    /// Allocate the next `(block, page)` slot, rolling the active block
    /// onto the lowest-wear free block when it fills (dynamic wear
    /// leveling). The caller is responsible for triggering GC *before*
    /// allocating (see the FTL implementations); running completely dry
    /// means over-provisioning was exhausted and panics.
    pub fn alloc_page(&mut self, pages_per_block: u32) -> (u32, u32) {
        let block = self.active_block;
        let page = self.next_page;
        self.next_page += 1;
        if self.next_page == pages_per_block {
            self.full_blocks.push(block);
            let (idx, _) = self
                .free_blocks
                .iter()
                .enumerate()
                .min_by_key(|(_, &b)| self.wear[b as usize])
                .expect("out of free blocks: over-provisioning exhausted");
            self.active_block = self.free_blocks.swap_remove(idx);
            self.next_page = 0;
        }
        (block, page)
    }

    /// Record a completed erase: the block's wear ticks and it returns to
    /// the free pool.
    ///
    /// (FTL-visible wear only; the *measured* spread the `[steady]`
    /// wear-leveling hook consumes comes from the chip model,
    /// `crate::nand::chip::Chip::wear_spread`.)
    pub fn note_erased(&mut self, block: u32) {
        self.wear[block as usize] += 1;
        self.free_blocks.push(block);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_allocator_state() {
        let a = ChipAllocator::new(8);
        assert_eq!(a.active_block, 0);
        assert_eq!(a.free_len(), 7);
        assert!(!a.reclaimable(16));
        assert!(a.wear.iter().all(|&w| w == 0));
    }

    #[test]
    fn alloc_rolls_to_lowest_wear_free_block() {
        let mut a = ChipAllocator::new(4);
        a.wear[1] = 5;
        a.wear[2] = 1;
        a.wear[3] = 3;
        // Fill block 0 (2 pages/block): the roll must pick block 2.
        assert_eq!(a.alloc_page(2), (0, 0));
        assert_eq!(a.alloc_page(2), (0, 1));
        assert_eq!(a.active_block, 2);
        assert_eq!(a.full_blocks, vec![0]);
        assert_eq!(a.free_len(), 2);
    }

    #[test]
    fn greedy_victim_has_fewest_valid_pages() {
        let mut a = ChipAllocator::new(4);
        a.full_blocks = vec![1, 2, 3];
        a.valid[1] = 9;
        a.valid[2] = 3;
        a.valid[3] = 7;
        assert_eq!(a.take_gc_victim(), Some(2));
        assert_eq!(a.full_blocks.len(), 2);
        // No full blocks left -> no victim.
        a.full_blocks.clear();
        assert_eq!(a.take_gc_victim(), None);
    }

    #[test]
    fn reclaimable_requires_garbage() {
        let mut a = ChipAllocator::new(4);
        a.full_blocks = vec![1];
        a.valid[1] = 16;
        assert!(!a.reclaimable(16), "fully-valid block is not reclaimable");
        a.valid[1] = 15;
        assert!(a.reclaimable(16));
    }

    #[test]
    fn wl_victim_respects_threshold_and_skips_max_wear() {
        let mut a = ChipAllocator::new(4);
        a.full_blocks = vec![1, 2];
        a.wear[0] = 10; // chip max
        a.wear[1] = 2;
        a.wear[2] = 9;
        assert_eq!(a.take_wl_victim(8), None, "spread 8 not exceeded");
        assert_eq!(a.take_wl_victim(7), Some(1));
        // Remaining full block lags max by 1: only threshold 0 takes it.
        assert_eq!(a.take_wl_victim(1), None);
        assert_eq!(a.take_wl_victim(0), Some(2));
        // Everything at max wear: even threshold 0 refuses (no churn).
        a.full_blocks = vec![3];
        a.wear[3] = 10;
        assert_eq!(a.take_wl_victim(0), None);
    }

    #[test]
    fn erase_ticks_wear_and_frees() {
        let mut a = ChipAllocator::new(4);
        let before = a.free_len();
        a.note_erased(3);
        assert_eq!(a.wear[3], 1);
        assert_eq!(a.free_len(), before + 1);
    }

    #[test]
    fn reset_restores_factory_state() {
        let mut a = ChipAllocator::new(4);
        a.alloc_page(2);
        a.alloc_page(2);
        a.note_erased(0);
        a.reset(4);
        assert_eq!(a.active_block, 0);
        assert_eq!(a.next_page, 0);
        assert_eq!(a.free_len(), 3);
        assert_eq!(a.wear, vec![0; 4]);
        assert!(a.full_blocks.is_empty());
    }

    #[test]
    fn default_tuning_matches_historical_constants() {
        let t = GcTuning::default();
        assert_eq!(t.gc_threshold_blocks, 2);
        assert_eq!(t.static_wl_threshold, 8);
    }
}
