//! Tiered SLC/MLC flash translation layer.
//!
//! The combined-flash architecture of multi-tiered SSD proposals
//! (Batni & Safaei, "A New Multi-Tiered Solid State Disk Using SLC/MLC
//! Combined Flash Memory"): chips `[0, slc_chips)` form an **SLC
//! write-buffer tier** — the base geometry driven with SLC-mode array
//! latencies — and the remaining chips form the **MLC capacity tier**.
//!
//! * **Host writes** always land in the SLC tier, striped round-robin
//!   across its chips, so the host sees SLC program latency.
//! * **Migration** is the SLC tier's primary reclamation path: when an SLC
//!   chip's free blocks fall to `migrate_free_blocks`, its oldest full
//!   block (fill-order FIFO ≈ coldest data) is copied page-by-page into
//!   the MLC tier ([`FtlOp::MigReadPage`]/[`FtlOp::MigProgramPage`], which
//!   the coordinator tags `MIG_REQ`) and erased. Like GC, migration is
//!   planned inline on the write path, so its copy-back jobs queue ahead
//!   of the host program and contend for the same channels and ways.
//! * **GC** runs per chip within each tier (greedy min-valid victims via
//!   [`ChipAllocator`]), reclaiming rewritten pages without crossing
//!   tiers; migration and GC therefore interact in one simulation when
//!   the `[steady]` regime is enabled on top.
//! * **Wear leveling** (FTL-internal static and the coordinator-driven
//!   hook) relocates within a chip, exactly as in
//!   [`super::page_map::PageMapFtl`].
//!
//! Reads are served from wherever the page lives — recently written data
//! from the SLC tier at SLC read latency, migrated cold data from MLC.
//! The mapping tables span both tiers, so [`Ftl::translate`] and the
//! shared consistency checks are tier-agnostic.
//!
//! Cross-chip migration has no data-dependency tracking in the DES: the
//! MLC program of a migrated page may be scheduled while its SLC read is
//! still queued on the source way. This slightly flatters migration
//! latency and is an accepted behavioral-model simplification (internal
//! jobs never complete host requests).

use crate::controller::ftl::steady::{ChipAllocator, GcTuning};
use crate::controller::ftl::{Ftl, FtlOp};
use crate::nand::geometry::{Geometry, PageAddr};

const INVALID: u64 = u64::MAX;

/// Tiered SLC/MLC FTL (see the module docs).
pub struct TieredFtl {
    geom: Geometry,
    /// lpn -> ppn.
    map: Vec<u64>,
    /// ppn -> lpn (reverse map, for GC and migration).
    rmap: Vec<u64>,
    chips: Vec<ChipAllocator>,
    /// Chips `[0, slc_chips)` are the SLC tier; the rest are MLC.
    slc_chips: usize,
    /// Next SLC chip for striped host-write allocation.
    next_slc: usize,
    /// Next MLC chip for striped migration destinations.
    next_mlc: usize,
    /// Migration triggers when an SLC chip's free blocks fall to this.
    migrate_free_blocks: u32,
    /// Running valid-page total per chip (mirrors the sum of each
    /// allocator's `valid[]`), so the migration headroom check on the
    /// host-write hot path is O(mlc_chips) instead of a full per-block
    /// scan of every MLC chip.
    chip_valid: Vec<u64>,
    /// GC/wear-leveling thresholds (the `[steady]` TOML section).
    pub tuning: GcTuning,
    /// Re-entrancy guard shared with the GC path: relocations allocate
    /// pages, which must not recursively trigger another reclaim.
    in_gc: bool,
    free_pages: u64,
    relocations: u64,
    erases: u64,
    migrated_pages: u64,
}

impl TieredFtl {
    /// `logical_pages` is the exported capacity; `slc_chips` in
    /// `[1, chips]` partitions the array (chips == slc_chips means every
    /// chip is SLC-mode and migration is off).
    pub fn new(
        geom: Geometry,
        logical_pages: u64,
        slc_chips: usize,
        migrate_free_blocks: u32,
    ) -> TieredFtl {
        let chips: Vec<ChipAllocator> = (0..geom.chips())
            .map(|_| ChipAllocator::new(geom.blocks_per_chip))
            .collect();
        assert!(
            (1..=chips.len()).contains(&slc_chips),
            "slc_chips {slc_chips} out of [1, {}]",
            chips.len()
        );
        assert!(
            logical_pages <= geom.total_pages(),
            "logical capacity exceeds physical"
        );
        let chip_valid = vec![0; chips.len()];
        TieredFtl {
            map: vec![INVALID; logical_pages as usize],
            rmap: vec![INVALID; geom.total_pages() as usize],
            chips,
            slc_chips,
            next_slc: 0,
            next_mlc: 0,
            migrate_free_blocks,
            chip_valid,
            tuning: GcTuning::default(),
            in_gc: false,
            free_pages: geom.total_pages(),
            geom,
            relocations: 0,
            erases: 0,
            migrated_pages: 0,
        }
    }

    fn compose_ppn(&self, chip: usize, block: u32, page: u32) -> u64 {
        let (channel, way) = self.geom.chip_addr(chip);
        self.geom.ppn(PageAddr {
            channel,
            way,
            block,
            page,
        })
    }

    fn decompose(&self, ppn: u64) -> (usize, u32, u32) {
        let a = self.geom.page_addr(ppn);
        (self.geom.chip_of(a.channel, a.way), a.block, a.page)
    }

    /// Is `chip` in the SLC tier?
    pub fn is_slc_chip(&self, chip: usize) -> bool {
        chip < self.slc_chips
    }

    /// Pages SLC→MLC migration has moved so far.
    pub fn migrated_pages(&self) -> u64 {
        self.migrated_pages
    }

    /// Allocate the next physical page on `chip`, rolling the active block
    /// and triggering within-chip GC as needed (identical policy to the
    /// page-map FTL). Appends any GC ops to `out`.
    fn alloc_on_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> u64 {
        let mut attempts = 0u32;
        while !self.in_gc
            && self.chips[chip].free_len() <= self.tuning.gc_threshold_blocks
            && self.chips[chip].reclaimable(self.geom.pages_per_block)
        {
            attempts += 1;
            assert!(
                attempts <= self.geom.blocks_per_chip,
                "GC cannot reclaim space: utilization too high for over-provisioning"
            );
            self.in_gc = true;
            self.gc_chip(chip, out);
            self.in_gc = false;
        }
        let (block, page) = self.chips[chip].alloc_page(self.geom.pages_per_block);
        self.free_pages -= 1;
        self.compose_ppn(chip, block, page)
    }

    /// Greedy within-chip GC: victim = full block with fewest valid pages.
    fn gc_chip(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        let vblock = self.chips[chip]
            .take_gc_victim()
            .expect("gc called with no full blocks");
        self.relocate_within(chip, vblock, out);
    }

    /// Copy-back loop shared by GC and wear leveling: relocate every valid
    /// page of `vblock` into freshly allocated pages *of the same chip*,
    /// then erase it. The caller has already removed `vblock` from the
    /// full-block list.
    fn relocate_within(&mut self, chip: usize, vblock: u32, out: &mut Vec<FtlOp>) {
        for page in 0..self.geom.pages_per_block {
            let src = self.compose_ppn(chip, vblock, page);
            let lpn = self.rmap[src as usize];
            if lpn != INVALID {
                out.push(FtlOp::ReadPage { ppn: src });
                let dst = self.alloc_on_chip(chip, out);
                out.push(FtlOp::ProgramPage { ppn: dst });
                self.remap(lpn, src, dst, chip, vblock);
                self.relocations += 1;
            }
        }
        self.finish_erase(chip, vblock, out);
    }

    /// Move `lpn` from `src` (in `vblock` of `src_chip`) to `dst`,
    /// updating both maps and both valid counters.
    fn remap(&mut self, lpn: u64, src: u64, dst: u64, src_chip: usize, vblock: u32) {
        self.map[lpn as usize] = dst;
        self.rmap[dst as usize] = lpn;
        self.rmap[src as usize] = INVALID;
        let (dchip, dblock, _) = self.decompose(dst);
        self.chips[dchip].valid[dblock as usize] += 1;
        self.chips[src_chip].valid[vblock as usize] -= 1;
        self.chip_valid[dchip] += 1;
        self.chip_valid[src_chip] -= 1;
    }

    /// Emit the erase of a fully-drained victim block and return it to the
    /// free pool.
    fn finish_erase(&mut self, chip: usize, vblock: u32, out: &mut Vec<FtlOp>) {
        debug_assert_eq!(self.chips[chip].valid[vblock as usize], 0);
        out.push(FtlOp::EraseBlock {
            chip,
            block: vblock,
        });
        self.chips[chip].note_erased(vblock);
        self.free_pages += self.geom.pages_per_block as u64;
        self.erases += 1;
    }

    /// Migration pump for one SLC chip: while its free pool sits at or
    /// below the migration threshold and the MLC tier has headroom, move
    /// its oldest full block to MLC. Each iteration frees exactly one
    /// block, so the loop terminates.
    fn maybe_migrate(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        if self.in_gc || self.slc_chips == self.chips.len() {
            return;
        }
        while self.chips[chip].free_len() <= self.migrate_free_blocks
            && !self.chips[chip].full_blocks.is_empty()
            && self.mlc_headroom_ok()
        {
            // Oldest full block in fill order ≈ coldest data (the order is
            // perturbed by GC's swap_remove but stays deterministic).
            let vblock = self.chips[chip].full_blocks.remove(0);
            self.migrate_block(chip, vblock, out);
        }
    }

    /// Every MLC chip must keep its GC floor plus one block of slack free
    /// or reclaimable before we pour another block into the tier —
    /// otherwise a crammed destination chip would exhaust its
    /// over-provisioning mid-copy. O(mlc_chips) via the running per-chip
    /// valid totals: this sits in `maybe_migrate`'s loop condition on the
    /// host-write hot path.
    fn mlc_headroom_ok(&self) -> bool {
        let ppb = self.geom.pages_per_block as u64;
        let per_chip = self.geom.blocks_per_chip as u64 * ppb;
        let reserve = (self.tuning.gc_threshold_blocks as u64 + 2) * ppb;
        self.chip_valid[self.slc_chips..]
            .iter()
            .all(|&valid| per_chip - valid >= reserve)
    }

    /// Copy every valid page of SLC block `vblock` into the MLC tier
    /// (striped round-robin), then erase it. Destination allocations may
    /// trigger MLC-tier GC inline; those ops are plain (GC-tagged)
    /// read/program/erase, while the migration copies themselves are the
    /// `Mig*` variants.
    fn migrate_block(&mut self, chip: usize, vblock: u32, out: &mut Vec<FtlOp>) {
        debug_assert!(chip < self.slc_chips);
        for page in 0..self.geom.pages_per_block {
            let src = self.compose_ppn(chip, vblock, page);
            let lpn = self.rmap[src as usize];
            if lpn != INVALID {
                out.push(FtlOp::MigReadPage { ppn: src });
                let mlc_count = self.chips.len() - self.slc_chips;
                let dst_chip = self.slc_chips + self.next_mlc;
                self.next_mlc = (self.next_mlc + 1) % mlc_count;
                let dst = self.alloc_on_chip(dst_chip, out);
                out.push(FtlOp::MigProgramPage { ppn: dst });
                self.remap(lpn, src, dst, chip, vblock);
                self.migrated_pages += 1;
            }
        }
        self.finish_erase(chip, vblock, out);
    }

    /// FTL-internal static wear leveling, within one chip (same policy as
    /// the page-map FTL).
    fn maybe_static_wl(&mut self, chip: usize, out: &mut Vec<FtlOp>) {
        if self.in_gc {
            return;
        }
        let Some(vblock) = self.chips[chip].take_wl_victim(self.tuning.static_wl_threshold)
        else {
            return;
        };
        self.in_gc = true;
        self.relocate_within(chip, vblock, out);
        self.in_gc = false;
    }

    /// Max-min wear spread across all blocks of all chips.
    pub fn wear_spread(&self) -> u32 {
        let all = self.chips.iter().flat_map(|c| c.wear.iter().copied());
        let max = all.clone().max().unwrap_or(0);
        let min = all.min().unwrap_or(0);
        max - min
    }

    /// Total valid (live) pages across all chips (GC/migration
    /// conservation invariant; used by the property tests).
    pub fn valid_pages_total(&self) -> u64 {
        self.chips
            .iter()
            .map(|c| c.valid.iter().map(|&v| v as u64).sum::<u64>())
            .sum()
    }

    /// Valid pages currently resident in the SLC tier.
    pub fn slc_valid_pages(&self) -> u64 {
        self.chips[..self.slc_chips]
            .iter()
            .map(|c| c.valid.iter().map(|&v| v as u64).sum::<u64>())
            .sum()
    }

    /// Smallest per-chip free-block count across the whole array.
    pub fn min_free_blocks(&self) -> u32 {
        self.chips.iter().map(|c| c.free_len()).min().unwrap_or(0)
    }
}

impl Ftl for TieredFtl {
    fn translate(&self, lpn: u64) -> Option<u64> {
        let p = *self.map.get(lpn as usize)?;
        (p != INVALID).then_some(p)
    }

    fn plan_write_into(&mut self, lpn: u64, out: &mut Vec<FtlOp>) -> u64 {
        assert!((lpn as usize) < self.map.len(), "lpn out of range");
        // Invalidate the old location (either tier).
        let old = self.map[lpn as usize];
        if old != INVALID {
            self.rmap[old as usize] = INVALID;
            let (chip, block, _) = self.decompose(old);
            self.chips[chip].valid[block as usize] -= 1;
            self.chip_valid[chip] -= 1;
        }
        // Host writes stripe across the SLC tier only.
        let chip = self.next_slc;
        self.next_slc = (self.next_slc + 1) % self.slc_chips;
        if self.chips[chip].next_page == 0 {
            self.maybe_static_wl(chip, out);
        }
        // Migration first (frees whole cold blocks), then within-chip GC
        // inside the allocation as a fallback for rewritten pages.
        self.maybe_migrate(chip, out);
        let ppn = self.alloc_on_chip(chip, out);
        self.map[lpn as usize] = ppn;
        self.rmap[ppn as usize] = lpn;
        let (c, block, _) = self.decompose(ppn);
        self.chips[c].valid[block as usize] += 1;
        self.chip_valid[c] += 1;
        ppn
    }

    fn set_gc_tuning(&mut self, tuning: GcTuning) {
        self.tuning = tuning;
    }

    fn plan_wear_level_into(&mut self, chip: usize, out: &mut Vec<FtlOp>) -> bool {
        if self.in_gc || chip >= self.chips.len() {
            return false;
        }
        let Some(vblock) = self.chips[chip].take_wl_victim(0) else {
            return false;
        };
        self.in_gc = true;
        self.relocate_within(chip, vblock, out);
        self.in_gc = false;
        true
    }

    fn reset(&mut self) {
        self.map.fill(INVALID);
        self.rmap.fill(INVALID);
        let blocks = self.geom.blocks_per_chip;
        for c in &mut self.chips {
            c.reset(blocks);
        }
        self.next_slc = 0;
        self.next_mlc = 0;
        self.chip_valid.fill(0);
        self.in_gc = false;
        self.free_pages = self.geom.total_pages();
        self.relocations = 0;
        self.erases = 0;
        self.migrated_pages = 0;
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }
    fn logical_capacity(&self) -> u64 {
        self.map.len() as u64
    }
    fn free_pages(&self) -> u64 {
        self.free_pages
    }
    fn relocations(&self) -> u64 {
        self.relocations
    }
    fn erases(&self) -> u64 {
        self.erases
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ftl::check_mapping_consistency;

    fn geom(channels: u16, ways: u16) -> Geometry {
        Geometry {
            channels,
            ways,
            blocks_per_chip: 8,
            pages_per_block: 16,
            page_bytes: 4096,
        }
    }

    /// 4 chips, 1 SLC: host writes only ever land on chip 0.
    #[test]
    fn host_writes_stay_in_slc_tier() {
        let g = geom(2, 2);
        let mut f = TieredFtl::new(g, 64, 1, 4);
        for lpn in 0..16 {
            let plan = f.plan_write(lpn);
            let (chip, _, _) = f.decompose(plan.target_ppn);
            assert_eq!(chip, 0, "lpn {lpn} must land on the SLC chip");
        }
        assert_eq!(f.slc_valid_pages(), 16);
        check_mapping_consistency(&f, &(0..64).collect::<Vec<_>>()).unwrap();
    }

    /// Two SLC chips stripe host writes round robin.
    #[test]
    fn slc_tier_stripes_round_robin() {
        let g = geom(2, 2);
        let mut f = TieredFtl::new(g, 64, 2, 4);
        let chips: Vec<usize> = (0..8)
            .map(|lpn| {
                let p = f.plan_write(lpn).target_ppn;
                f.decompose(p).0
            })
            .collect();
        assert_eq!(chips, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    /// Filling past the SLC tier's capacity forces migration: Mig ops
    /// appear, cold data ends up on MLC chips, and every lpn stays
    /// readable.
    #[test]
    fn overflow_migrates_cold_blocks_to_mlc() {
        let g = geom(1, 2); // 2 chips x 8 blocks x 16 pages = 256 phys
        let mut f = TieredFtl::new(g, 160, 1, 4); // SLC chip: 128 pages
        let mut mig_reads = 0;
        let mut mig_progs = 0;
        for lpn in 0..160 {
            let plan = f.plan_write(lpn);
            for op in &plan.background {
                match op {
                    FtlOp::MigReadPage { .. } => mig_reads += 1,
                    FtlOp::MigProgramPage { .. } => mig_progs += 1,
                    _ => {}
                }
            }
        }
        assert!(f.migrated_pages() > 0, "the fill must overflow the SLC tier");
        assert_eq!(mig_reads, mig_progs);
        assert_eq!(mig_progs as u64, f.migrated_pages());
        // Migrated pages live on the MLC chip now.
        let on_mlc = (0..160u64)
            .filter(|&lpn| {
                let ppn = f.translate(lpn).expect("every lpn written");
                !f.is_slc_chip(f.decompose(ppn).0)
            })
            .count();
        assert_eq!(on_mlc as u64, f.migrated_pages());
        assert!(f.slc_valid_pages() < 160);
        check_mapping_consistency(&f, &(0..160).collect::<Vec<_>>()).unwrap();
    }

    /// Sustained rewrites over a tier-overflowing volume keep every
    /// invariant: conservation of valid pages, the free-block floor, and
    /// mapping consistency — with GC and migration interleaved.
    #[test]
    fn rewrites_keep_invariants_under_gc_plus_migration() {
        let g = geom(1, 2);
        let mut f = TieredFtl::new(g, 160, 1, 4);
        let mut mapped = std::collections::BTreeSet::new();
        for round in 0..8u64 {
            for i in 0..160u64 {
                let lpn = (i * 7 + round) % 160;
                f.plan_write(lpn);
                mapped.insert(lpn);
                assert_eq!(f.valid_pages_total(), mapped.len() as u64);
            }
        }
        assert!(f.erases() > 0, "the loop must exercise reclamation");
        assert!(f.migrated_pages() > 0);
        assert!(f.min_free_blocks() >= 1, "no chip may run dry");
        // The running per-chip totals (the O(1)-per-update headroom
        // counters) stay in lockstep with the allocators' ground truth.
        for (chip, alloc) in f.chips.iter().enumerate() {
            let truth: u64 = alloc.valid.iter().map(|&v| v as u64).sum();
            assert_eq!(f.chip_valid[chip], truth, "chip {chip} total drifted");
        }
        check_mapping_consistency(&f, &(0..160).collect::<Vec<_>>()).unwrap();
    }

    /// With every chip in the SLC tier migration is off and the FTL
    /// degenerates to striped within-chip GC.
    #[test]
    fn all_slc_partition_never_migrates() {
        let g = geom(1, 2);
        let mut f = TieredFtl::new(g, 160, 2, 4);
        for round in 0..5u64 {
            for lpn in 0..160 {
                f.plan_write((lpn + round) % 160);
            }
        }
        assert_eq!(f.migrated_pages(), 0);
        assert!(f.erases() > 0, "GC must still reclaim rewrites");
        check_mapping_consistency(&f, &(0..160).collect::<Vec<_>>()).unwrap();
    }

    /// Reset restores factory state and determinism (sweep-worker reuse).
    #[test]
    fn reset_restores_factory_state_and_determinism() {
        let g = geom(1, 2);
        let run = |f: &mut TieredFtl| -> Vec<u64> {
            (0..150).map(|lpn| f.plan_write(lpn).target_ppn).collect()
        };
        let mut fresh = TieredFtl::new(g, 160, 1, 4);
        let expect = run(&mut fresh);
        let mut reused = TieredFtl::new(g, 160, 1, 4);
        for round in 0..6 {
            for lpn in 0..160 {
                reused.plan_write((lpn + round) % 160);
            }
        }
        reused.reset();
        assert_eq!(reused.free_pages(), g.total_pages());
        assert_eq!(reused.migrated_pages(), 0);
        assert_eq!(reused.erases(), 0);
        assert_eq!(reused.translate(0), None);
        assert_eq!(run(&mut reused), expect);
    }

    /// The coordinator wear-leveling entry relocates within the chip and
    /// preserves mappings, for chips of either tier.
    #[test]
    fn plan_wear_level_stays_within_chip() {
        let g = geom(1, 2);
        let mut f = TieredFtl::new(g, 160, 1, 4);
        f.tuning.static_wl_threshold = u32::MAX;
        for round in 0..6u64 {
            for lpn in 0..160 {
                f.plan_write((lpn + round) % 160);
            }
        }
        let mut out = Vec::new();
        if f.plan_wear_level_into(0, &mut out) {
            assert!(out
                .iter()
                .any(|op| matches!(op, FtlOp::EraseBlock { chip: 0, .. })));
            assert!(!out
                .iter()
                .any(|op| matches!(op, FtlOp::MigReadPage { .. })));
        }
        check_mapping_consistency(&f, &(0..160).collect::<Vec<_>>()).unwrap();
        assert!(!f.plan_wear_level_into(99, &mut Vec::new()));
    }
}
