//! Error-correction code (ECC) block latency model.
//!
//! Each channel has a dedicated ECC block (§2.2.1: "each channel requires a
//! NAND interface block and an error correction code (ECC) block"). We model
//! a BCH engine that processes data in 512-byte sectors; its per-sector
//! latency is pipelined with, but accounted on, the channel's page path —
//! this is the fixed per-page overhead `F` in DESIGN.md's calibration
//! (4 µs for a 4-sector SLC page, 8 µs for an 8-sector MLC page).

use crate::nand::datasheet::CellType;
use crate::util::time::Ps;

/// BCH ECC engine model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EccModel {
    /// Codeword (sector) size in bytes.
    pub sector_bytes: u32,
    /// Correction capability in bits per sector (t of BCH(t)); affects
    /// latency linearly in this model.
    pub t_bits: u32,
    /// Engine latency per sector at t_bits = 4 (SLC-grade). Calibration
    /// constant (DESIGN.md §Calibration anchors).
    pub base_sector_latency: Ps,
}

impl Default for EccModel {
    fn default() -> Self {
        EccModel {
            sector_bytes: 512,
            t_bits: 4,
            base_sector_latency: Ps::ns(875),
        }
    }
}

impl EccModel {
    /// ECC at a given correction strength; latency scales with t beyond
    /// the t=4 base.
    pub fn for_t(t_bits: u32) -> EccModel {
        EccModel {
            t_bits,
            ..EccModel::default()
        }
    }

    /// The strength the controller provisions per cell type: BCH(t=4) for
    /// SLC, BCH(t=6) for MLC — the paper notes ECC is "essential for data
    /// reliability, especially when the MLC flash is used" (§2.2.1).
    pub fn for_cell(cell: CellType) -> EccModel {
        match cell {
            CellType::Slc => EccModel::for_t(4),
            CellType::Mlc => EccModel::for_t(6),
        }
    }

    /// Sectors in a page of `page_bytes` main data.
    pub fn sectors(&self, page_bytes: u32) -> u32 {
        page_bytes.div_ceil(self.sector_bytes)
    }

    /// Per-sector processing latency (scales with correction strength
    /// beyond the base t=4).
    pub fn sector_latency(&self) -> Ps {
        // BCH decode latency grows ~linearly in t; normalize to t=4.
        Ps((self.base_sector_latency.as_ps() as f64 * (self.t_bits as f64 / 4.0).max(1.0)) as i64)
    }

    /// Total engine occupancy to encode or decode one page.
    pub fn page_latency(&self, page_bytes: u32) -> Ps {
        self.sector_latency().times(self.sectors(page_bytes) as u64)
    }
}

/// Count-style telemetry for the per-channel ECC engine.
///
/// The latency model above is stateless by design — it prices a page and
/// forgets it. The bottleneck observer ([`crate::observe`]) and the
/// planned reliability pack both want cumulative engine telemetry
/// (pages through the decoder, sectors processed, total occupancy), so
/// the counters live here next to the pricing they mirror. `Default` is
/// all-zero and recording is integer-only, so a channel that never
/// records pays nothing.
///
/// ```
/// use ddrnand::controller::ecc::{EccCounters, EccModel};
///
/// let e = EccModel::default();
/// let mut c = EccCounters::default();
/// c.record_decode(&e, 2048);
/// c.record_encode(&e, 2048);
/// assert_eq!(c.pages_decoded, 1);
/// assert_eq!(c.pages_encoded, 1);
/// assert_eq!(c.sectors_processed, 8);
/// assert_eq!(c.busy_ps, 2 * e.page_latency(2048).as_ps() as u64);
/// ```
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EccCounters {
    /// Pages through the decode path (reads).
    pub pages_decoded: u64,
    /// Pages through the encode path (programs).
    pub pages_encoded: u64,
    /// 512-byte sectors processed across both paths.
    pub sectors_processed: u64,
    /// Cumulative engine occupancy in picoseconds (the busy-time figure
    /// an observer merges into its per-resource accounting).
    pub busy_ps: u64,
}

impl EccCounters {
    /// Record one page decode priced by `model`.
    pub fn record_decode(&mut self, model: &EccModel, page_bytes: u32) {
        self.pages_decoded += 1;
        self.sectors_processed += model.sectors(page_bytes) as u64;
        self.busy_ps += model.page_latency(page_bytes).as_ps() as u64;
    }

    /// Record one page encode priced by `model`.
    pub fn record_encode(&mut self, model: &EccModel, page_bytes: u32) {
        self.pages_encoded += 1;
        self.sectors_processed += model.sectors(page_bytes) as u64;
        self.busy_ps += model.page_latency(page_bytes).as_ps() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_page_is_3500ns() {
        let e = EccModel::for_cell(CellType::Slc);
        assert_eq!(e.sectors(2048), 4);
        assert_eq!(e.page_latency(2048), Ps::ns(3500));
    }

    #[test]
    fn mlc_page_is_10500ns() {
        // t=6 -> 1312.5 ns/sector x 8 sectors.
        let e = EccModel::for_cell(CellType::Mlc);
        assert_eq!(e.sectors(4096), 8);
        assert_eq!(e.page_latency(4096), Ps::ns(10_500));
    }

    #[test]
    fn partial_sector_rounds_up() {
        let e = EccModel::default();
        assert_eq!(e.sectors(513), 2);
        assert_eq!(e.sectors(512), 1);
    }

    #[test]
    fn stronger_code_costs_more() {
        let weak = EccModel::for_t(4);
        let strong = EccModel::for_t(8);
        assert_eq!(strong.sector_latency(), weak.sector_latency() * 2);
    }

    #[test]
    fn counters_accumulate_against_the_pricing_model() {
        let e = EccModel::for_cell(CellType::Mlc);
        let mut c = EccCounters::default();
        assert_eq!(c, EccCounters::default(), "all-zero default");
        c.record_decode(&e, 4096);
        c.record_decode(&e, 4096);
        c.record_encode(&e, 4096);
        assert_eq!(c.pages_decoded, 2);
        assert_eq!(c.pages_encoded, 1);
        assert_eq!(c.sectors_processed, 3 * 8);
        assert_eq!(c.busy_ps, 3 * e.page_latency(4096).as_ps() as u64);
    }
}
