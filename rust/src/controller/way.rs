//! Per-way state: the job queue and phase machine for one NAND chip behind
//! a shared channel bus.
//!
//! Way interleaving (§2.2.1) = the channel scheduler multiplexing the bus
//! across these way queues in round-robin order, so that one way's t_R /
//! t_PROG busy time is hidden behind other ways' bus phases.

use crate::host::trace::{CLASS_BACKGROUND, NUM_CLASSES};
use crate::nand::chip::Chip;
use crate::util::time::Ps;
use std::collections::VecDeque;

/// What a page job does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageJobKind {
    Read,
    Program,
    Erase,
}

/// Phase of a page job's lifecycle on (bus, chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Waiting for its first bus phase (cmd for reads/erases, cmd+data for
    /// programs).
    Queued,
    /// Array operation in flight (t_R / t_PROG / t_BERS).
    ArrayBusy,
    /// Read only: array fetch done, waiting for the data-out bus phase.
    AwaitXferOut,
    /// Program/erase only: array op done, waiting for the status poll.
    AwaitStatus,
    Done,
}

/// One page-granular operation bound for a specific chip.
#[derive(Debug, Clone, Copy)]
pub struct PageJob {
    /// Host request this job belongs to. Values at the top of the range
    /// mark internal traffic (see `coordinator::ssd`: `INTERNAL_REQ` cache
    /// flushes, `WL_REQ` wear leveling, `GC_REQ` GC copy-back, `MIG_REQ`
    /// tier migration).
    pub req: u64,
    /// Originating host stream (`u16::MAX` for internal traffic) — the
    /// tenant this job's latency is attributed to.
    pub stream: u16,
    /// Priority class consumed by the way schedulers
    /// ([`crate::controller::sched`]): host classes 0..=2, with internal
    /// GC/WL/migration traffic always at the explicit lowest class
    /// ([`crate::host::trace::CLASS_BACKGROUND`]) instead of relying on
    /// implicit queue ordering.
    pub class: u8,
    pub kind: PageJobKind,
    pub block: u32,
    pub page: u32,
    /// Main-data bytes (page size; spare is added by the bus model).
    pub bytes: u32,
    pub phase: JobPhase,
}

/// A way: one chip + its pending job queue + the in-flight job.
pub struct WayState {
    pub chip: Chip,
    /// The pending jobs. Mutate through [`push`](Self::push) /
    /// [`take_job`](Self::take_job) so the per-class counts below stay in
    /// sync — the QoS schedulers treat them as authoritative.
    pub queue: VecDeque<PageJob>,
    /// Queued jobs per priority class (scheduler fast path: skip ways
    /// without a candidate class in O(1)).
    class_counts: [u32; NUM_CLASSES],
    /// Queued read jobs (scheduler fast path for read preemption).
    queued_reads: u32,
    /// Job currently owning the chip (ArrayBusy/AwaitXferOut/AwaitStatus).
    pub inflight: Option<PageJob>,
    /// Completion time of the in-flight array op, if any.
    pub array_done_at: Ps,
}

impl WayState {
    pub fn new(chip: Chip) -> WayState {
        WayState {
            chip,
            queue: VecDeque::new(),
            class_counts: [0; NUM_CLASSES],
            queued_reads: 0,
            inflight: None,
            array_done_at: Ps::ZERO,
        }
    }

    /// Enqueue a job (FIFO per way). An out-of-range priority class is
    /// clamped to background here, at the boundary, so the class counts,
    /// the stored job and the schedulers' exact-match lookups can never
    /// disagree (mirrors `WeightedQos::new`'s zero-weight clamp).
    pub fn push(&mut self, mut job: PageJob) {
        job.class = job.class.min(CLASS_BACKGROUND);
        self.class_counts[job.class as usize] += 1;
        if job.kind == PageJobKind::Read {
            self.queued_reads += 1;
        }
        self.queue.push_back(job);
    }

    /// Remove and return the queued job at `idx` (the grant-consumption
    /// path; keeps the class/read counts in sync with the queue).
    pub fn take_job(&mut self, idx: usize) -> Option<PageJob> {
        let job = self.queue.remove(idx)?;
        self.class_counts[job.class as usize] -= 1;
        if job.kind == PageJobKind::Read {
            self.queued_reads -= 1;
        }
        Some(job)
    }

    /// Queued jobs of a priority class.
    pub fn queued_of_class(&self, class: u8) -> u32 {
        self.class_counts[(class as usize).min(NUM_CLASSES - 1)]
    }

    /// Queued read jobs.
    pub fn queued_reads(&self) -> u32 {
        self.queued_reads
    }

    /// The reorder window: queued background jobs (GC / wear-leveling /
    /// migration / cache-flush copy-back) are **plan-order barriers** —
    /// an FTL write plan queues its copy-back and erase ops ahead of the
    /// host program on the same way, and that relative order is load-
    /// bearing (the erase must not run after a host program into the
    /// reclaimed block; the request's GC-stall attribution depends on it).
    /// Scheduling policies may therefore pull a job forward only from the
    /// queue prefix strictly before the first background job; the first
    /// background job itself is dispatchable (it is, by FIFO, the next of
    /// its class). Returns that prefix length (= queue length when no
    /// background job is queued, computed in O(1) from the class counts).
    pub fn reorder_window(&self) -> usize {
        if self.class_counts[CLASS_BACKGROUND as usize] == 0 {
            self.queue.len()
        } else {
            self.queue
                .iter()
                .position(|j| j.class >= CLASS_BACKGROUND)
                .unwrap_or(self.queue.len())
        }
    }

    /// Drop all queued/in-flight work and reset the chip, keeping the
    /// queue's allocation (sweep-worker reuse; steady-state dispatch then
    /// re-fills the same storage allocation-free).
    pub fn reset(&mut self, timing: crate::nand::datasheet::NandTiming) {
        self.queue.clear();
        self.class_counts = [0; NUM_CLASSES];
        self.queued_reads = 0;
        self.inflight = None;
        self.array_done_at = Ps::ZERO;
        self.chip.reset(timing);
    }

    /// True if this way could use the bus right now: either a queued job
    /// waiting to start, or an in-flight job whose array phase completed
    /// and now needs a bus phase (data-out or status).
    pub fn wants_bus(&self, now: Ps) -> bool {
        self.bus_class(now).is_some()
    }

    /// Scheduling class of this way's pending bus work, if any. Lower is
    /// higher priority (see [`crate::controller::channel`]):
    /// 0 = status poll (frees the way, ~0.1 µs), 1 = command dispatch
    /// (starts an array op → creates parallelism), 2 = data-out (drains the
    /// page register). Issuing short phases that unlock parallelism before
    /// long data bursts is what lets way interleaving hide t_R.
    pub fn bus_class(&self, now: Ps) -> Option<u8> {
        if let Some(j) = &self.inflight {
            if now < self.array_done_at {
                return None;
            }
            match j.phase {
                JobPhase::AwaitStatus => Some(0),
                JobPhase::AwaitXferOut => Some(2),
                _ => None,
            }
        } else if !self.queue.is_empty() {
            Some(1)
        } else {
            None
        }
    }

    /// The queue depth including the in-flight job.
    pub fn backlog(&self) -> usize {
        self.queue.len() + usize::from(self.inflight.is_some())
    }

    pub fn is_idle(&self) -> bool {
        self.inflight.is_none() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nand::datasheet::NandTiming;

    fn way() -> WayState {
        WayState::new(Chip::new(NandTiming::slc(), 8))
    }

    fn job(kind: PageJobKind) -> PageJob {
        PageJob {
            req: 0,
            stream: 0,
            class: 1,
            kind,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    #[test]
    fn fresh_way_is_idle() {
        let w = way();
        assert!(w.is_idle());
        assert!(!w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 0);
    }

    #[test]
    fn queued_job_wants_bus() {
        let mut w = way();
        w.push(job(PageJobKind::Read));
        assert!(w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 1);
    }

    #[test]
    fn inflight_array_busy_does_not_want_bus() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::ArrayBusy;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(10)));
    }

    #[test]
    fn awaiting_xfer_wants_bus_after_array_done() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::AwaitXferOut;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(20)));
        assert!(w.wants_bus(Ps::us(25)));
    }
}
