//! Per-way state: the job queue and phase machine for one NAND chip behind
//! a shared channel bus.
//!
//! Way interleaving (§2.2.1) = the channel scheduler multiplexing the bus
//! across these way queues in round-robin order, so that one way's t_R /
//! t_PROG busy time is hidden behind other ways' bus phases.

use crate::host::trace::{CLASS_BACKGROUND, NUM_CLASSES};
use crate::nand::chip::Chip;
use crate::util::time::Ps;

/// What a page job does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageJobKind {
    Read,
    Program,
    Erase,
}

/// Phase of a page job's lifecycle on (bus, chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Waiting for its first bus phase (cmd for reads/erases, cmd+data for
    /// programs).
    Queued,
    /// Array operation in flight (t_R / t_PROG / t_BERS).
    ArrayBusy,
    /// Read only: array fetch done, waiting for the data-out bus phase.
    AwaitXferOut,
    /// Program/erase only: array op done, waiting for the status poll.
    AwaitStatus,
    Done,
}

/// One page-granular operation bound for a specific chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PageJob {
    /// Host request this job belongs to. Values at the top of the range
    /// mark internal traffic (see `coordinator::ssd`: `INTERNAL_REQ` cache
    /// flushes, `WL_REQ` wear leveling, `GC_REQ` GC copy-back, `MIG_REQ`
    /// tier migration).
    pub req: u64,
    /// Originating host stream (`u16::MAX` for internal traffic) — the
    /// tenant this job's latency is attributed to.
    pub stream: u16,
    /// Priority class consumed by the way schedulers
    /// ([`crate::controller::sched`]): host classes 0..=2, with internal
    /// GC/WL/migration traffic always at the explicit lowest class
    /// ([`crate::host::trace::CLASS_BACKGROUND`]) instead of relying on
    /// implicit queue ordering.
    pub class: u8,
    pub kind: PageJobKind,
    pub block: u32,
    pub page: u32,
    /// Main-data bytes (page size; spare is added by the bus model).
    pub bytes: u32,
    pub phase: JobPhase,
}

/// Structure-of-arrays job queue: every [`PageJob`] field lives in its own
/// parallel lane, indexed from a logical head cursor.
///
/// The schedulers' hot scans (first read in the reorder window, first job
/// of a class) filter on a single one-byte lane — one cache line now holds
/// 64 class tags where the array-of-structs layout held one and a half
/// 40-byte jobs — and the common FIFO pop (grant at index 0) is a cursor
/// bump instead of a shift. The lanes are an arena: `clear` keeps their
/// allocations, so sweep-worker reuse refills the same storage
/// allocation-free, and the consumed prefix compacts once it passes the
/// live tail so storage stays bounded by the queue's high-water mark.
#[derive(Debug, Default)]
pub struct JobQueue {
    req: Vec<u64>,
    stream: Vec<u16>,
    class: Vec<u8>,
    kind: Vec<PageJobKind>,
    block: Vec<u32>,
    page: Vec<u32>,
    bytes: Vec<u32>,
    phase: Vec<JobPhase>,
    /// Consumed entries at the front of every lane.
    head: usize,
}

/// Compact once the dead prefix exceeds this many entries *and* the live
/// tail (amortized O(1) per pop, bounded memory).
const COMPACT_THRESHOLD: usize = 64;

impl JobQueue {
    pub fn len(&self) -> usize {
        self.req.len() - self.head
    }

    pub fn is_empty(&self) -> bool {
        self.head == self.req.len()
    }

    /// Assemble the job at logical index `i` from the lanes.
    pub fn get(&self, i: usize) -> PageJob {
        let i = self.head + i;
        PageJob {
            req: self.req[i],
            stream: self.stream[i],
            class: self.class[i],
            kind: self.kind[i],
            block: self.block[i],
            page: self.page[i],
            bytes: self.bytes[i],
            phase: self.phase[i],
        }
    }

    /// Index (within the first `limit` entries) of the first job of
    /// `class`. Touches only the class lane.
    pub fn first_of_class_in(&self, class: u8, limit: usize) -> Option<usize> {
        let n = limit.min(self.len());
        self.class[self.head..self.head + n]
            .iter()
            .position(|&c| c == class)
    }

    /// Index (within the first `limit` entries) of the first read job.
    /// Touches only the kind lane.
    pub fn first_read_in(&self, limit: usize) -> Option<usize> {
        let n = limit.min(self.len());
        self.kind[self.head..self.head + n]
            .iter()
            .position(|&k| k == PageJobKind::Read)
    }

    /// Index of the first background-class job — the plan-order barrier
    /// ([`WayState::reorder_window`]).
    fn first_background(&self) -> Option<usize> {
        self.class[self.head..]
            .iter()
            .position(|&c| c >= CLASS_BACKGROUND)
    }

    fn push_back(&mut self, job: PageJob) {
        self.req.push(job.req);
        self.stream.push(job.stream);
        self.class.push(job.class);
        self.kind.push(job.kind);
        self.block.push(job.block);
        self.page.push(job.page);
        self.bytes.push(job.bytes);
        self.phase.push(job.phase);
    }

    /// Remove and return the job at logical index `idx` (`VecDeque::remove`
    /// semantics). Index 0 — the overwhelmingly common FIFO grant — is a
    /// cursor bump; mid-queue removal shifts the lane tails.
    fn remove(&mut self, idx: usize) -> Option<PageJob> {
        if idx >= self.len() {
            return None;
        }
        let job = self.get(idx);
        if idx == 0 {
            self.head += 1;
            if self.head == self.req.len() {
                self.clear();
            } else if self.head >= COMPACT_THRESHOLD && self.head >= self.len() {
                self.compact();
            }
        } else {
            let i = self.head + idx;
            self.req.remove(i);
            self.stream.remove(i);
            self.class.remove(i);
            self.kind.remove(i);
            self.block.remove(i);
            self.page.remove(i);
            self.bytes.remove(i);
            self.phase.remove(i);
        }
        Some(job)
    }

    /// Drop the consumed prefix, keeping lane allocations.
    fn compact(&mut self) {
        self.req.drain(..self.head);
        self.stream.drain(..self.head);
        self.class.drain(..self.head);
        self.kind.drain(..self.head);
        self.block.drain(..self.head);
        self.page.drain(..self.head);
        self.bytes.drain(..self.head);
        self.phase.drain(..self.head);
        self.head = 0;
    }

    /// Empty the queue, keeping lane allocations (arena reuse).
    fn clear(&mut self) {
        self.req.clear();
        self.stream.clear();
        self.class.clear();
        self.kind.clear();
        self.block.clear();
        self.page.clear();
        self.bytes.clear();
        self.phase.clear();
        self.head = 0;
    }
}

/// A way: one chip + its pending job queue + the in-flight job.
pub struct WayState {
    pub chip: Chip,
    /// The pending jobs. Mutate through [`push`](Self::push) /
    /// [`take_job`](Self::take_job) so the per-class counts below stay in
    /// sync — the QoS schedulers treat them as authoritative.
    queue: JobQueue,
    /// Queued jobs per priority class (scheduler fast path: skip ways
    /// without a candidate class in O(1)).
    class_counts: [u32; NUM_CLASSES],
    /// Queued read jobs (scheduler fast path for read preemption).
    queued_reads: u32,
    /// Job currently owning the chip (ArrayBusy/AwaitXferOut/AwaitStatus).
    pub inflight: Option<PageJob>,
    /// Completion time of the in-flight array op, if any.
    pub array_done_at: Ps,
}

impl WayState {
    pub fn new(chip: Chip) -> WayState {
        WayState {
            chip,
            queue: JobQueue::default(),
            class_counts: [0; NUM_CLASSES],
            queued_reads: 0,
            inflight: None,
            array_done_at: Ps::ZERO,
        }
    }

    /// Enqueue a job (FIFO per way). An out-of-range priority class is
    /// clamped to background here, at the boundary, so the class counts,
    /// the stored job and the schedulers' exact-match lookups can never
    /// disagree (mirrors `WeightedQos::new`'s zero-weight clamp).
    pub fn push(&mut self, mut job: PageJob) {
        job.class = job.class.min(CLASS_BACKGROUND);
        self.class_counts[job.class as usize] += 1;
        if job.kind == PageJobKind::Read {
            self.queued_reads += 1;
        }
        self.queue.push_back(job);
    }

    /// Remove and return the queued job at `idx` (the grant-consumption
    /// path; keeps the class/read counts in sync with the queue).
    pub fn take_job(&mut self, idx: usize) -> Option<PageJob> {
        let job = self.queue.remove(idx)?;
        self.class_counts[job.class as usize] -= 1;
        if job.kind == PageJobKind::Read {
            self.queued_reads -= 1;
        }
        Some(job)
    }

    /// Queued-job count (excluding the in-flight job).
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// The queued job at logical index `i` (assembled from the SoA lanes).
    pub fn job_at(&self, i: usize) -> PageJob {
        self.queue.get(i)
    }

    /// Index of the first queued job of `class` within the first `limit`
    /// entries (single-lane scan; see [`JobQueue::first_of_class_in`]).
    pub fn first_of_class_in(&self, class: u8, limit: usize) -> Option<usize> {
        self.queue.first_of_class_in(class, limit)
    }

    /// Index of the first queued read within the first `limit` entries.
    pub fn first_read_in(&self, limit: usize) -> Option<usize> {
        self.queue.first_read_in(limit)
    }

    /// Queued jobs of a priority class.
    pub fn queued_of_class(&self, class: u8) -> u32 {
        self.class_counts[(class as usize).min(NUM_CLASSES - 1)]
    }

    /// Queued read jobs.
    pub fn queued_reads(&self) -> u32 {
        self.queued_reads
    }

    /// The reorder window: queued background jobs (GC / wear-leveling /
    /// migration / cache-flush copy-back) are **plan-order barriers** —
    /// an FTL write plan queues its copy-back and erase ops ahead of the
    /// host program on the same way, and that relative order is load-
    /// bearing (the erase must not run after a host program into the
    /// reclaimed block; the request's GC-stall attribution depends on it).
    /// Scheduling policies may therefore pull a job forward only from the
    /// queue prefix strictly before the first background job; the first
    /// background job itself is dispatchable (it is, by FIFO, the next of
    /// its class). Returns that prefix length (= queue length when no
    /// background job is queued, computed in O(1) from the class counts).
    pub fn reorder_window(&self) -> usize {
        if self.class_counts[CLASS_BACKGROUND as usize] == 0 {
            self.queue.len()
        } else {
            self.queue.first_background().unwrap_or(self.queue.len())
        }
    }

    /// Drop all queued/in-flight work and reset the chip, keeping the
    /// queue's allocation (sweep-worker reuse; steady-state dispatch then
    /// re-fills the same storage allocation-free).
    pub fn reset(&mut self, timing: crate::nand::datasheet::NandTiming) {
        self.queue.clear();
        self.class_counts = [0; NUM_CLASSES];
        self.queued_reads = 0;
        self.inflight = None;
        self.array_done_at = Ps::ZERO;
        self.chip.reset(timing);
    }

    /// True if this way could use the bus right now: either a queued job
    /// waiting to start, or an in-flight job whose array phase completed
    /// and now needs a bus phase (data-out or status).
    pub fn wants_bus(&self, now: Ps) -> bool {
        self.bus_class(now).is_some()
    }

    /// Scheduling class of this way's pending bus work, if any. Lower is
    /// higher priority (see [`crate::controller::channel`]):
    /// 0 = status poll (frees the way, ~0.1 µs), 1 = command dispatch
    /// (starts an array op → creates parallelism), 2 = data-out (drains the
    /// page register). Issuing short phases that unlock parallelism before
    /// long data bursts is what lets way interleaving hide t_R.
    pub fn bus_class(&self, now: Ps) -> Option<u8> {
        if let Some(j) = &self.inflight {
            if now < self.array_done_at {
                return None;
            }
            match j.phase {
                JobPhase::AwaitStatus => Some(0),
                JobPhase::AwaitXferOut => Some(2),
                _ => None,
            }
        } else if !self.queue.is_empty() {
            Some(1)
        } else {
            None
        }
    }

    /// Is the NAND array itself working at `now` (t_R / t_PROG / t_BERS in
    /// flight)? Distinct from [`wants_bus`](Self::wants_bus): an array-busy
    /// way is *productive*, not waiting. Caveat for observers: during a
    /// command transfer the in-flight job is already `ArrayBusy` but
    /// `array_done_at` still holds the *previous* job's completion (always
    /// `<= now`, so this returns false) — classify bus ownership *before*
    /// consulting this, and the transfer interval lands on the bus owner.
    pub fn array_busy(&self, now: Ps) -> bool {
        matches!(&self.inflight, Some(j) if j.phase == JobPhase::ArrayBusy)
            && now < self.array_done_at
    }

    /// The queue depth including the in-flight job.
    pub fn backlog(&self) -> usize {
        self.queue.len() + usize::from(self.inflight.is_some())
    }

    pub fn is_idle(&self) -> bool {
        self.inflight.is_none() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nand::datasheet::NandTiming;
    use crate::util::prng::Prng;
    use std::collections::VecDeque;

    fn way() -> WayState {
        WayState::new(Chip::new(NandTiming::slc(), 8))
    }

    fn job(kind: PageJobKind) -> PageJob {
        PageJob {
            req: 0,
            stream: 0,
            class: 1,
            kind,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    #[test]
    fn fresh_way_is_idle() {
        let w = way();
        assert!(w.is_idle());
        assert!(!w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 0);
    }

    #[test]
    fn queued_job_wants_bus() {
        let mut w = way();
        w.push(job(PageJobKind::Read));
        assert!(w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 1);
    }

    #[test]
    fn inflight_array_busy_does_not_want_bus() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::ArrayBusy;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(10)));
    }

    #[test]
    fn awaiting_xfer_wants_bus_after_array_done() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::AwaitXferOut;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(20)));
        assert!(w.wants_bus(Ps::us(25)));
    }

    /// Full logical-view equivalence between the SoA lanes and a
    /// `VecDeque<PageJob>` reference: elements, and every scan helper
    /// against its naive whole-struct scan.
    fn assert_queue_equiv(q: &JobQueue, r: &VecDeque<PageJob>) {
        assert_eq!(q.len(), r.len());
        assert_eq!(q.is_empty(), r.is_empty());
        for i in 0..r.len() {
            assert_eq!(q.get(i), r[i], "element {i} diverged (head={})", q.head);
        }
        for limit in [0, 1, r.len() / 2, r.len(), r.len() + 3] {
            let n = limit.min(r.len());
            assert_eq!(
                q.first_read_in(limit),
                r.iter().take(n).position(|j| j.kind == PageJobKind::Read)
            );
            for class in 0..NUM_CLASSES as u8 {
                assert_eq!(
                    q.first_of_class_in(class, limit),
                    r.iter().take(n).position(|j| j.class == class)
                );
            }
        }
        assert_eq!(
            q.first_background(),
            r.iter().position(|j| j.class >= CLASS_BACKGROUND)
        );
    }

    /// The SoA lanes behave exactly like the `VecDeque<PageJob>` they
    /// replaced: randomized push/remove sequences (heavy on the index-0
    /// fast path, like real grants) stay element-identical, and the scan
    /// helpers agree with naive whole-struct scans.
    #[test]
    fn soa_queue_matches_vecdeque_reference() {
        let mut rng = Prng::new(0x50A5_0A50);
        for _case in 0..crate::proptest::effective_cases(50) {
            let mut q = JobQueue::default();
            let mut r: VecDeque<PageJob> = VecDeque::new();
            for step in 0..400u64 {
                let op = rng.next_bounded(10);
                if op < 6 || r.is_empty() {
                    let j = PageJob {
                        req: step,
                        stream: rng.next_bounded(4) as u16,
                        class: rng.next_bounded(5) as u8, // incl. out-of-range
                        kind: match rng.next_bounded(3) {
                            0 => PageJobKind::Read,
                            1 => PageJobKind::Program,
                            _ => PageJobKind::Erase,
                        },
                        block: step as u32,
                        page: (step * 7) as u32,
                        bytes: 2048,
                        phase: JobPhase::Queued,
                    };
                    q.push_back(j);
                    r.push_back(j);
                } else {
                    // Mostly FIFO pops, occasionally mid-queue removal.
                    let idx = if rng.next_bounded(4) == 0 {
                        rng.next_bounded(r.len() as u64 + 1) as usize
                    } else {
                        0
                    };
                    assert_eq!(q.remove(idx), r.remove(idx), "step {step} idx {idx}");
                }
                assert_queue_equiv(&q, &r);
            }
        }

        // Deferred-compaction regime, deterministically: march the consumed
        // prefix past COMPACT_THRESHOLD while a *longer* live tail defers
        // the compaction, so every translated-index path (get, scans,
        // further pops) runs with a large standing cursor.
        let mk = |step: u64| PageJob {
            req: step,
            stream: (step % 3) as u16,
            class: (step % 5) as u8,
            kind: match step % 3 {
                0 => PageJobKind::Read,
                1 => PageJobKind::Program,
                _ => PageJobKind::Erase,
            },
            block: step as u32,
            page: (step * 7) as u32,
            bytes: 2048,
            phase: JobPhase::Queued,
        };
        let mut q = JobQueue::default();
        let mut r: VecDeque<PageJob> = VecDeque::new();
        for step in 0..3 * COMPACT_THRESHOLD as u64 {
            q.push_back(mk(step));
            r.push_back(mk(step));
        }
        while q.head <= COMPACT_THRESHOLD {
            assert_eq!(q.remove(0), r.pop_front());
            assert_queue_equiv(&q, &r);
        }
        assert!(
            q.len() > q.head,
            "scenario bug: live tail must outlast the dead prefix here"
        );
        assert_eq!(
            q.req.len(),
            3 * COMPACT_THRESHOLD,
            "compaction must be deferred while the live tail exceeds the prefix"
        );
        // Interleave pushes and scans mid-stream: appends land beyond the
        // cursor and must not disturb the standing dead prefix.
        for step in 0..8u64 {
            q.push_back(mk(1000 + step));
            r.push_back(mk(1000 + step));
            assert_eq!(q.remove(0), r.pop_front());
            assert_queue_equiv(&q, &r);
        }
        // Drain until the live tail dips below the dead prefix: that pop
        // compacts, wrapping the cursor back to 0 without changing the
        // logical view.
        while q.head != 0 {
            assert_eq!(q.remove(0), r.pop_front());
            assert_queue_equiv(&q, &r);
        }
        assert!(
            !r.is_empty(),
            "compaction should fire with a live tail, not via the empty-reset path"
        );
        assert_eq!(
            q.req.len(),
            r.len(),
            "post-compaction lanes should hold exactly the live tail"
        );
        assert_queue_equiv(&q, &r);
        // And the queue keeps working after the wraparound.
        q.push_back(mk(2000));
        r.push_back(mk(2000));
        assert_queue_equiv(&q, &r);
        while let Some(want) = r.pop_front() {
            assert_eq!(q.remove(0), Some(want));
        }
        assert!(q.is_empty());
    }

    /// The dead prefix left by FIFO pops compacts away: storage stays
    /// bounded by the high-water mark, not the total jobs ever queued.
    #[test]
    fn soa_queue_compacts_consumed_prefix() {
        let mut q = JobQueue::default();
        for round in 0..100 {
            for _ in 0..8 {
                q.push_back(job(PageJobKind::Program));
            }
            for _ in 0..8 {
                assert!(q.remove(0).is_some());
            }
            assert!(q.is_empty(), "round {round}");
            assert!(
                q.req.len() <= 2 * COMPACT_THRESHOLD + 16,
                "lane storage grew unbounded: {}",
                q.req.len()
            );
        }
        // Interleaved churn with a persistent backlog also stays bounded.
        for _ in 0..16 {
            q.push_back(job(PageJobKind::Read));
        }
        for _ in 0..1000 {
            q.push_back(job(PageJobKind::Program));
            assert!(q.remove(0).is_some());
        }
        assert_eq!(q.len(), 16);
        assert!(q.req.len() <= 2 * COMPACT_THRESHOLD + 32);
    }

    /// Class clamping still happens at the push boundary (counts, stored
    /// job and scan lanes agree).
    #[test]
    fn out_of_range_class_clamped_at_push() {
        let mut w = way();
        let mut j = job(PageJobKind::Program);
        j.class = 17;
        w.push(j);
        assert_eq!(w.queued_of_class(CLASS_BACKGROUND), 1);
        assert_eq!(w.job_at(0).class, CLASS_BACKGROUND);
        assert_eq!(w.first_of_class_in(CLASS_BACKGROUND, 1), Some(0));
        assert_eq!(w.reorder_window(), 0);
        let taken = w.take_job(0).unwrap();
        assert_eq!(taken.class, CLASS_BACKGROUND);
        assert_eq!(w.queued_of_class(CLASS_BACKGROUND), 0);
    }
}
