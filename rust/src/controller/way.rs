//! Per-way state: the job queue and phase machine for one NAND chip behind
//! a shared channel bus.
//!
//! Way interleaving (§2.2.1) = the channel scheduler multiplexing the bus
//! across these way queues in round-robin order, so that one way's t_R /
//! t_PROG busy time is hidden behind other ways' bus phases.

use crate::nand::chip::Chip;
use crate::util::time::Ps;
use std::collections::VecDeque;

/// What a page job does.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageJobKind {
    Read,
    Program,
    Erase,
}

/// Phase of a page job's lifecycle on (bus, chip).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobPhase {
    /// Waiting for its first bus phase (cmd for reads/erases, cmd+data for
    /// programs).
    Queued,
    /// Array operation in flight (t_R / t_PROG / t_BERS).
    ArrayBusy,
    /// Read only: array fetch done, waiting for the data-out bus phase.
    AwaitXferOut,
    /// Program/erase only: array op done, waiting for the status poll.
    AwaitStatus,
    Done,
}

/// One page-granular operation bound for a specific chip.
#[derive(Debug, Clone, Copy)]
pub struct PageJob {
    /// Host request this job belongs to. Values at the top of the range
    /// mark internal traffic (see `coordinator::ssd`: `INTERNAL_REQ` cache
    /// flushes, `WL_REQ` wear leveling, `GC_REQ` GC copy-back, `MIG_REQ`
    /// tier migration).
    pub req: u64,
    pub kind: PageJobKind,
    pub block: u32,
    pub page: u32,
    /// Main-data bytes (page size; spare is added by the bus model).
    pub bytes: u32,
    pub phase: JobPhase,
}

/// A way: one chip + its pending job queue + the in-flight job.
pub struct WayState {
    pub chip: Chip,
    pub queue: VecDeque<PageJob>,
    /// Job currently owning the chip (ArrayBusy/AwaitXferOut/AwaitStatus).
    pub inflight: Option<PageJob>,
    /// Completion time of the in-flight array op, if any.
    pub array_done_at: Ps,
}

impl WayState {
    pub fn new(chip: Chip) -> WayState {
        WayState {
            chip,
            queue: VecDeque::new(),
            inflight: None,
            array_done_at: Ps::ZERO,
        }
    }

    /// Enqueue a job (FIFO per way).
    pub fn push(&mut self, job: PageJob) {
        self.queue.push_back(job);
    }

    /// Drop all queued/in-flight work and reset the chip, keeping the
    /// queue's allocation (sweep-worker reuse; steady-state dispatch then
    /// re-fills the same storage allocation-free).
    pub fn reset(&mut self, timing: crate::nand::datasheet::NandTiming) {
        self.queue.clear();
        self.inflight = None;
        self.array_done_at = Ps::ZERO;
        self.chip.reset(timing);
    }

    /// True if this way could use the bus right now: either a queued job
    /// waiting to start, or an in-flight job whose array phase completed
    /// and now needs a bus phase (data-out or status).
    pub fn wants_bus(&self, now: Ps) -> bool {
        self.bus_class(now).is_some()
    }

    /// Scheduling class of this way's pending bus work, if any. Lower is
    /// higher priority (see [`crate::controller::channel`]):
    /// 0 = status poll (frees the way, ~0.1 µs), 1 = command dispatch
    /// (starts an array op → creates parallelism), 2 = data-out (drains the
    /// page register). Issuing short phases that unlock parallelism before
    /// long data bursts is what lets way interleaving hide t_R.
    pub fn bus_class(&self, now: Ps) -> Option<u8> {
        if let Some(j) = &self.inflight {
            if now < self.array_done_at {
                return None;
            }
            match j.phase {
                JobPhase::AwaitStatus => Some(0),
                JobPhase::AwaitXferOut => Some(2),
                _ => None,
            }
        } else if !self.queue.is_empty() {
            Some(1)
        } else {
            None
        }
    }

    /// The queue depth including the in-flight job.
    pub fn backlog(&self) -> usize {
        self.queue.len() + usize::from(self.inflight.is_some())
    }

    pub fn is_idle(&self) -> bool {
        self.inflight.is_none() && self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nand::datasheet::NandTiming;

    fn way() -> WayState {
        WayState::new(Chip::new(NandTiming::slc(), 8))
    }

    fn job(kind: PageJobKind) -> PageJob {
        PageJob {
            req: 0,
            kind,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    #[test]
    fn fresh_way_is_idle() {
        let w = way();
        assert!(w.is_idle());
        assert!(!w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 0);
    }

    #[test]
    fn queued_job_wants_bus() {
        let mut w = way();
        w.push(job(PageJobKind::Read));
        assert!(w.wants_bus(Ps::ZERO));
        assert_eq!(w.backlog(), 1);
    }

    #[test]
    fn inflight_array_busy_does_not_want_bus() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::ArrayBusy;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(10)));
    }

    #[test]
    fn awaiting_xfer_wants_bus_after_array_done() {
        let mut w = way();
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::AwaitXferOut;
        w.inflight = Some(j);
        w.array_done_at = Ps::us(25);
        assert!(!w.wants_bus(Ps::us(20)));
        assert!(w.wants_bus(Ps::us(25)));
    }
}
