//! Per-channel state: the shared bus (NAND_IF + ECC) and the pluggable
//! way scheduler implementing way interleaving.

use crate::controller::ecc::EccModel;
use crate::controller::nand_if::NandIf;
use crate::controller::sched::{Grant, WayScheduler};
use crate::controller::way::WayState;
use crate::util::time::Ps;

/// One channel: a NAND_IF/ECC pair, its ways (Fig. 2 row) and the
/// scheduling policy that multiplexes the bus across them
/// ([`crate::controller::sched`]; round robin is the bit-identical
/// default).
pub struct ChannelState {
    pub bus: NandIf,
    pub ecc: EccModel,
    pub ways: Vec<WayState>,
    /// The way-scheduling (QoS) policy.
    sched: Box<dyn WayScheduler>,
    /// Set when a bus-free event is already scheduled (avoid duplicates).
    pub kick_scheduled: bool,
}

impl ChannelState {
    pub fn new(
        bus: NandIf,
        ecc: EccModel,
        ways: Vec<WayState>,
        sched: Box<dyn WayScheduler>,
    ) -> ChannelState {
        ChannelState {
            bus,
            ecc,
            ways,
            sched,
            kick_scheduled: false,
        }
    }

    /// Reset the channel for a new run without dropping way/queue storage
    /// (sweep-worker reuse). Bus timing, ECC grade and NAND timing may all
    /// change between sweep points; the way *count* and the scheduler
    /// policy may not (both are part of [`crate::coordinator::ssd::SsdSim::
    /// reuse_key`]); the scheduler's arbitration state is rewound.
    pub fn reset(
        &mut self,
        params: &crate::iface::timing::IfaceParams,
        kind: crate::iface::timing::InterfaceKind,
        ecc: EccModel,
        timing: crate::nand::datasheet::NandTiming,
    ) {
        self.bus.reset(params, kind);
        self.ecc = ecc;
        for w in &mut self.ways {
            w.reset(timing);
        }
        self.sched.reset();
        self.kick_scheduled = false;
    }

    /// Replace the way scheduler (testing hook: the scheduler-equivalence
    /// oracle in `rust/tests/qos.rs` injects the pre-refactor arbiter).
    pub fn set_scheduler(&mut self, sched: Box<dyn WayScheduler>) {
        self.sched = sched;
    }

    /// Ask the policy for the next bus grant: which way, and — when that
    /// way has no in-flight job — which queued job to dispatch.
    pub fn next_grant(&mut self, now: Ps) -> Option<Grant> {
        self.sched.pick(&self.ways, now)
    }

    /// Earliest future time any way will want the bus (array completions),
    /// used to schedule wake-ups when the bus idles.
    pub fn next_wakeup(&self, now: Ps) -> Option<Ps> {
        self.ways
            .iter()
            .filter(|w| w.inflight.is_some() && w.array_done_at > now)
            .map(|w| w.array_done_at)
            .min()
    }

    /// Does any way have bus work pending at `now`? Read-only probe for
    /// the observer layer ([`crate::observe`]): a free bus with a waiting
    /// way is an *idle-with-work-queued* interval (a transient between a
    /// release and the re-kick, or a scheduler hold), distinct from true
    /// idleness.
    pub fn any_wants_bus(&self, now: Ps) -> bool {
        self.ways.iter().any(|w| w.wants_bus(now))
    }

    /// All ways idle and queues empty?
    pub fn is_drained(&self) -> bool {
        self.ways.iter().all(|w| w.is_idle())
    }

    /// Total queued + in-flight jobs.
    pub fn backlog(&self) -> usize {
        self.ways.iter().map(|w| w.backlog()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::sched::{self, SchedKind};
    use crate::controller::way::{JobPhase, PageJob, PageJobKind};
    use crate::iface::timing::{IfaceParams, InterfaceKind};
    use crate::nand::chip::Chip;
    use crate::nand::datasheet::NandTiming;

    fn chan(nways: usize) -> ChannelState {
        let ways = (0..nways)
            .map(|_| WayState::new(Chip::new(NandTiming::slc(), 8)))
            .collect();
        ChannelState::new(
            NandIf::new(&IfaceParams::default(), InterfaceKind::Proposed),
            EccModel::default(),
            ways,
            sched::build(SchedKind::RoundRobin, [8, 4, 2, 1]),
        )
    }

    fn job() -> PageJob {
        PageJob {
            req: 0,
            stream: 0,
            class: 1,
            kind: PageJobKind::Read,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut c = chan(4);
        for w in 0..4 {
            c.ways[w].push(job());
        }
        // Consume the granted job each time, as the coordinator does.
        let order: Vec<usize> = (0..4)
            .map(|_| {
                let g = c.next_grant(Ps::ZERO).unwrap();
                c.ways[g.way].take_job(g.job);
                g.way
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        // Pointer wraps.
        c.ways[1].push(job());
        assert_eq!(c.next_grant(Ps::ZERO).map(|g| g.way), Some(1));
    }

    #[test]
    fn skips_ways_not_wanting() {
        let mut c = chan(4);
        c.ways[2].push(job());
        assert_eq!(c.next_grant(Ps::ZERO).map(|g| g.way), Some(2));
        c.ways[2].take_job(0);
        assert!(c.next_grant(Ps::ZERO).is_none());
    }

    #[test]
    fn wakeup_is_earliest_array_completion() {
        let mut c = chan(2);
        let mut j = job();
        j.phase = JobPhase::ArrayBusy;
        c.ways[0].inflight = Some(j);
        c.ways[0].array_done_at = Ps::us(30);
        c.ways[1].inflight = Some(j);
        c.ways[1].array_done_at = Ps::us(10);
        assert_eq!(c.next_wakeup(Ps::ZERO), Some(Ps::us(10)));
        assert_eq!(c.next_wakeup(Ps::us(20)), Some(Ps::us(30)));
    }

    #[test]
    fn drained_accounting() {
        let mut c = chan(2);
        assert!(c.is_drained());
        c.ways[0].push(job());
        assert!(!c.is_drained());
        assert_eq!(c.backlog(), 1);
    }

    /// Swapping the policy changes which queued job a grant names.
    #[test]
    fn scheduler_is_pluggable() {
        let mut c = chan(1);
        let mut w = job();
        w.kind = PageJobKind::Program;
        c.ways[0].push(w);
        c.ways[0].push(job()); // a read behind the program
        assert_eq!(c.next_grant(Ps::ZERO).map(|g| g.job), Some(0), "FIFO");
        c.set_scheduler(sched::build(SchedKind::ReadPriority, [8, 4, 2, 1]));
        assert_eq!(
            c.next_grant(Ps::ZERO).map(|g| g.job),
            Some(1),
            "the read preempts the queued program"
        );
    }
}
