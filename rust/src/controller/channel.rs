//! Per-channel state: the shared bus (NAND_IF + ECC) and the round-robin
//! way pointer implementing way interleaving.

use crate::controller::ecc::EccModel;
use crate::controller::nand_if::NandIf;
use crate::controller::way::WayState;
use crate::util::time::Ps;

/// One channel: a NAND_IF/ECC pair and its ways (Fig. 2 row).
pub struct ChannelState {
    pub bus: NandIf,
    pub ecc: EccModel,
    pub ways: Vec<WayState>,
    /// Round-robin pointer: next way to consider for the bus.
    rr_next: usize,
    /// Set when a bus-free event is already scheduled (avoid duplicates).
    pub kick_scheduled: bool,
}

impl ChannelState {
    pub fn new(bus: NandIf, ecc: EccModel, ways: Vec<WayState>) -> ChannelState {
        ChannelState {
            bus,
            ecc,
            ways,
            rr_next: 0,
            kick_scheduled: false,
        }
    }

    /// Reset the channel for a new run without dropping way/queue storage
    /// (sweep-worker reuse). Bus timing, ECC grade and NAND timing may all
    /// change between sweep points; the way *count* may not.
    pub fn reset(
        &mut self,
        params: &crate::iface::timing::IfaceParams,
        kind: crate::iface::timing::InterfaceKind,
        ecc: EccModel,
        timing: crate::nand::datasheet::NandTiming,
    ) {
        self.bus.reset(params, kind);
        self.ecc = ecc;
        for w in &mut self.ways {
            w.reset(timing);
        }
        self.rr_next = 0;
        self.kick_scheduled = false;
    }

    /// Pick the next way to grant the bus: highest scheduling class first
    /// (status > command dispatch > data-out; see
    /// [`crate::controller::way::WayState::bus_class`]), round-robin within
    /// a class. Advances the pointer past the chosen way.
    pub fn next_way_wanting_bus(&mut self, now: Ps) -> Option<usize> {
        let n = self.ways.len();
        let mut best: Option<(u8, usize, usize)> = None; // (class, rr-dist, idx)
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            if let Some(class) = self.ways[i].bus_class(now) {
                if class == 0 {
                    self.rr_next = (i + 1) % n;
                    return Some(i);
                }
                match best {
                    Some((c, _, _)) if c <= class => {}
                    _ => best = Some((class, off, i)),
                }
            }
        }
        best.map(|(_, _, i)| {
            self.rr_next = (i + 1) % n;
            i
        })
    }

    /// Earliest future time any way will want the bus (array completions),
    /// used to schedule wake-ups when the bus idles.
    pub fn next_wakeup(&self, now: Ps) -> Option<Ps> {
        self.ways
            .iter()
            .filter(|w| w.inflight.is_some() && w.array_done_at > now)
            .map(|w| w.array_done_at)
            .min()
    }

    /// All ways idle and queues empty?
    pub fn is_drained(&self) -> bool {
        self.ways.iter().all(|w| w.is_idle())
    }

    /// Total queued + in-flight jobs.
    pub fn backlog(&self) -> usize {
        self.ways.iter().map(|w| w.backlog()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::way::{JobPhase, PageJob, PageJobKind};
    use crate::iface::timing::{IfaceParams, InterfaceKind};
    use crate::nand::chip::Chip;
    use crate::nand::datasheet::NandTiming;

    fn chan(nways: usize) -> ChannelState {
        let ways = (0..nways)
            .map(|_| WayState::new(Chip::new(NandTiming::slc(), 8)))
            .collect();
        ChannelState::new(
            NandIf::new(&IfaceParams::default(), InterfaceKind::Proposed),
            EccModel::default(),
            ways,
        )
    }

    fn job() -> PageJob {
        PageJob {
            req: 0,
            kind: PageJobKind::Read,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    #[test]
    fn round_robin_rotates() {
        let mut c = chan(4);
        for w in 0..4 {
            c.ways[w].push(job());
        }
        // Consume the granted way's job each time, as the scheduler does.
        let order: Vec<usize> = (0..4)
            .map(|_| {
                let w = c.next_way_wanting_bus(Ps::ZERO).unwrap();
                c.ways[w].queue.pop_front();
                w
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        // Pointer wraps.
        c.ways[1].push(job());
        assert_eq!(c.next_way_wanting_bus(Ps::ZERO), Some(1));
    }

    #[test]
    fn skips_ways_not_wanting() {
        let mut c = chan(4);
        c.ways[2].push(job());
        assert_eq!(c.next_way_wanting_bus(Ps::ZERO), Some(2));
        c.ways[2].queue.pop_front();
        assert_eq!(c.next_way_wanting_bus(Ps::ZERO), None);
    }

    #[test]
    fn wakeup_is_earliest_array_completion() {
        let mut c = chan(2);
        let mut j = job();
        j.phase = JobPhase::ArrayBusy;
        c.ways[0].inflight = Some(j);
        c.ways[0].array_done_at = Ps::us(30);
        c.ways[1].inflight = Some(j);
        c.ways[1].array_done_at = Ps::us(10);
        assert_eq!(c.next_wakeup(Ps::ZERO), Some(Ps::us(10)));
        assert_eq!(c.next_wakeup(Ps::us(20)), Some(Ps::us(30)));
    }

    #[test]
    fn drained_accounting() {
        let mut c = chan(2);
        assert!(c.is_drained());
        c.ways[0].push(job());
        assert!(!c.is_drained());
        assert_eq!(c.backlog(), 1);
    }
}
