//! NAND_IF — the per-channel interface block of Fig. 3/Fig. 5.
//!
//! Wraps the bus timing of the selected interface and tracks bus occupancy
//! as a DES resource. The Gen_W/Gen_R/D_CON/FIFO structure of the figures
//! collapses, at behavioral level, into the phase durations of
//! [`crate::iface::bus::BusTiming`] plus the occupancy bookkeeping here.

use crate::iface::bus::BusTiming;
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::util::time::Ps;

/// One channel's NAND interface: bus timing + busy tracking + traffic stats.
#[derive(Debug, Clone)]
pub struct NandIf {
    pub timing: BusTiming,
    busy_until: Ps,
    /// Total time the bus spent occupied (for utilization metrics).
    pub busy_time: Ps,
    /// Total data bytes moved across this channel.
    pub data_bytes: u64,
    /// Total command/status cycles issued.
    pub cmd_ops: u64,
}

impl NandIf {
    pub fn new(params: &IfaceParams, kind: InterfaceKind) -> NandIf {
        NandIf {
            timing: BusTiming::from_params(params, kind),
            busy_until: Ps::ZERO,
            busy_time: Ps::ZERO,
            data_bytes: 0,
            cmd_ops: 0,
        }
    }

    /// Free the bus and zero its statistics; `timing` may change when a
    /// sweep worker is retargeted at a different interface.
    pub fn reset(&mut self, params: &IfaceParams, kind: InterfaceKind) {
        self.timing = BusTiming::from_params(params, kind);
        self.busy_until = Ps::ZERO;
        self.busy_time = Ps::ZERO;
        self.data_bytes = 0;
        self.cmd_ops = 0;
    }

    /// Is the bus free at `now`?
    pub fn is_free(&self, now: Ps) -> bool {
        now >= self.busy_until
    }

    /// Time the bus becomes free.
    pub fn free_at(&self, now: Ps) -> Ps {
        self.busy_until.max(now)
    }

    /// Occupy the bus for `dur` starting at `now`. Returns the completion
    /// time. Panics if the bus is already occupied (the channel scheduler
    /// must serialize).
    pub fn occupy(&mut self, now: Ps, dur: Ps) -> Ps {
        assert!(self.is_free(now), "bus occupied until {:?} at {now:?}", self.busy_until);
        self.busy_until = now + dur;
        self.busy_time += dur;
        self.busy_until
    }

    /// Occupy for a data burst, accounting the bytes.
    pub fn occupy_data(&mut self, now: Ps, bytes: u32) -> Ps {
        self.data_bytes += bytes as u64;
        let dur = self.timing.data_transfer(bytes);
        self.occupy(now, dur)
    }

    /// Occupy for a command phase.
    pub fn occupy_cmd(&mut self, now: Ps, dur: Ps) -> Ps {
        self.cmd_ops += 1;
        self.occupy(now, dur)
    }

    /// Bus utilization over an elapsed window.
    pub fn utilization(&self, elapsed: Ps) -> f64 {
        if elapsed.as_ps() <= 0 {
            0.0
        } else {
            self.busy_time.as_ps() as f64 / elapsed.as_ps() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nif() -> NandIf {
        NandIf::new(&IfaceParams::default(), InterfaceKind::Proposed)
    }

    #[test]
    fn occupancy_serializes() {
        let mut n = nif();
        assert!(n.is_free(Ps::ZERO));
        let done = n.occupy(Ps::ZERO, Ps::us(10));
        assert_eq!(done, Ps::us(10));
        assert!(!n.is_free(Ps::us(9)));
        assert!(n.is_free(Ps::us(10)));
        assert_eq!(n.free_at(Ps::us(3)), Ps::us(10));
    }

    #[test]
    #[should_panic(expected = "bus occupied")]
    fn double_occupy_panics() {
        let mut n = nif();
        n.occupy(Ps::ZERO, Ps::us(10));
        n.occupy(Ps::us(5), Ps::us(1));
    }

    #[test]
    fn data_accounting() {
        let mut n = nif();
        n.occupy_data(Ps::ZERO, 2112);
        assert_eq!(n.data_bytes, 2112);
        // DDR at 83 MHz: 2112 bytes x 6.024 ns
        assert_eq!(n.busy_time, Ps::ps(2112 * 6_024));
    }

    #[test]
    fn utilization() {
        let mut n = nif();
        n.occupy(Ps::ZERO, Ps::us(25));
        let u = n.utilization(Ps::us(100));
        assert!((u - 0.25).abs() < 1e-12);
    }
}
