//! DRAM cache buffer (§2.2.1: "in most commercially available SSDs, DRAM is
//! used as a cache buffer to hide the long access latency of NAND").
//!
//! A page-granular write-back LRU cache. On a hit, the NAND path is skipped
//! entirely (the paper's point); evictions of dirty pages generate flush
//! writes. Disabled (capacity 0) for the paper's Table 3–5 runs, which
//! measure the raw NAND path; exercised by its own tests and ablations.

use std::collections::BTreeMap;

/// Cache configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Capacity in pages (0 disables the cache).
    pub capacity_pages: u32,
    /// If true, writes are absorbed and flushed on eviction (write-back);
    /// otherwise writes always go to NAND (write-through).
    pub write_back: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_pages: 0,
            write_back: true,
        }
    }
}

/// Result of a cache access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Serviced from DRAM; no NAND access needed.
    Hit,
    /// Must access NAND; carries an optional dirty eviction to flush first.
    Miss { evict_flush: Option<u64> },
    /// Cache disabled.
    Bypass,
}

/// Page-granular LRU cache with dirty tracking.
///
/// Recency is a monotone tick; every entry holds its tick and the
/// `by_tick` index mirrors `entries` keyed by it. Ticks are unique (one
/// per access), so the index's smallest key *is* the LRU entry and
/// eviction is O(log n) instead of the full-map scan it replaced —
/// bit-identical eviction order, since the old scan minimized the same
/// unique tick (regression-tested against the scan oracle below).
pub struct DramCache {
    cfg: CacheConfig,
    /// lpn -> (lru tick, dirty). A `BTreeMap` (not `HashMap`) so every
    /// traversal is in deterministic lpn order — simlint rule R1 forbids
    /// hash-order iteration anywhere in the simulator (the pre-PR 9
    /// `dirty_pages` relied on a post-hoc sort to mask it).
    entries: BTreeMap<u64, (u64, bool)>,
    /// lru tick -> lpn (recency index; exactly one entry per cached lpn).
    by_tick: BTreeMap<u64, u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub flushes: u64,
}

impl DramCache {
    pub fn new(cfg: CacheConfig) -> DramCache {
        DramCache {
            cfg,
            entries: BTreeMap::new(),
            by_tick: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            flushes: 0,
        }
    }

    /// Drop all entries and statistics, keeping the map's allocation; the
    /// configuration may change when a sweep worker is retargeted.
    pub fn reset(&mut self, cfg: CacheConfig) {
        self.cfg = cfg;
        self.entries.clear();
        self.by_tick.clear();
        self.tick = 0;
        self.hits = 0;
        self.misses = 0;
        self.flushes = 0;
    }

    fn touch(&mut self, lpn: u64, dirty: bool) {
        self.tick += 1;
        let e = self.entries.entry(lpn).or_insert((0, false));
        if e.0 != 0 {
            self.by_tick.remove(&e.0);
        }
        e.0 = self.tick;
        e.1 |= dirty;
        self.by_tick.insert(self.tick, lpn);
    }

    /// Evict the LRU entry; returns `Some(lpn)` if it was dirty (needs
    /// flushing to NAND).
    fn evict_lru(&mut self) -> Option<u64> {
        let (_, lpn) = self.by_tick.pop_first()?;
        let (_, dirty) = self.entries.remove(&lpn).expect("index entry without map entry");
        if dirty {
            self.flushes += 1;
            Some(lpn)
        } else {
            None
        }
    }

    fn insert(&mut self, lpn: u64, dirty: bool) -> Option<u64> {
        let mut flush = None;
        if self.entries.len() as u32 >= self.cfg.capacity_pages && !self.entries.contains_key(&lpn)
        {
            flush = self.evict_lru();
        }
        self.touch(lpn, dirty);
        flush
    }

    /// Access for read.
    pub fn read(&mut self, lpn: u64) -> CacheOutcome {
        if self.cfg.capacity_pages == 0 {
            return CacheOutcome::Bypass;
        }
        if self.entries.contains_key(&lpn) {
            self.hits += 1;
            self.touch(lpn, false);
            CacheOutcome::Hit
        } else {
            self.misses += 1;
            let evict_flush = self.insert(lpn, false);
            CacheOutcome::Miss { evict_flush }
        }
    }

    /// Access for write.
    pub fn write(&mut self, lpn: u64) -> CacheOutcome {
        if self.cfg.capacity_pages == 0 || !self.cfg.write_back {
            return CacheOutcome::Bypass;
        }
        if self.entries.contains_key(&lpn) {
            self.hits += 1;
            self.touch(lpn, true);
            CacheOutcome::Hit
        } else {
            self.misses += 1;
            let evict_flush = self.insert(lpn, true);
            CacheOutcome::Miss { evict_flush }
        }
    }

    /// Dirty pages remaining (to flush at shutdown), in ascending lpn
    /// order (`entries` is a `BTreeMap`, so no sort is needed).
    pub fn dirty_pages(&self) -> Vec<u64> {
        self.entries
            .iter()
            .filter(|(_, (_, d))| *d)
            .map(|(&l, _)| l)
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn cache(cap: u32) -> DramCache {
        DramCache::new(CacheConfig {
            capacity_pages: cap,
            write_back: true,
        })
    }

    #[test]
    fn disabled_cache_bypasses() {
        let mut c = cache(0);
        assert_eq!(c.read(1), CacheOutcome::Bypass);
        assert_eq!(c.write(1), CacheOutcome::Bypass);
    }

    #[test]
    fn read_after_write_hits() {
        let mut c = cache(4);
        assert!(matches!(c.write(7), CacheOutcome::Miss { .. }));
        assert_eq!(c.read(7), CacheOutcome::Hit);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = cache(2);
        c.write(1);
        c.write(2);
        c.read(1); // 2 becomes LRU
        match c.write(3) {
            CacheOutcome::Miss { evict_flush } => assert_eq!(evict_flush, Some(2)),
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn clean_eviction_needs_no_flush() {
        let mut c = cache(1);
        c.read(1); // clean
        match c.read(2) {
            CacheOutcome::Miss { evict_flush } => assert_eq!(evict_flush, None),
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn dirty_pages_listed() {
        let mut c = cache(4);
        c.write(3);
        c.write(1);
        c.read(2);
        assert_eq!(c.dirty_pages(), vec![1, 3]);
    }

    #[test]
    fn hit_rate() {
        let mut c = cache(8);
        c.write(1);
        c.read(1);
        c.read(1);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    /// The pre-rewrite cache, verbatim: full-map `min_by_key` scan per
    /// eviction. Kept as the oracle the indexed implementation must match
    /// access-for-access.
    struct ScanOracle {
        cfg: CacheConfig,
        entries: HashMap<u64, (u64, bool)>,
        tick: u64,
        hits: u64,
        misses: u64,
        flushes: u64,
    }

    impl ScanOracle {
        fn new(cfg: CacheConfig) -> ScanOracle {
            ScanOracle {
                cfg,
                entries: HashMap::new(),
                tick: 0,
                hits: 0,
                misses: 0,
                flushes: 0,
            }
        }

        fn touch(&mut self, lpn: u64, dirty: bool) {
            self.tick += 1;
            let e = self.entries.entry(lpn).or_insert((0, false));
            e.0 = self.tick;
            e.1 |= dirty;
        }

        fn evict_lru(&mut self) -> Option<u64> {
            let (&lpn, &(_, dirty)) = self.entries.iter().min_by_key(|(_, (t, _))| *t)?;
            self.entries.remove(&lpn);
            if dirty {
                self.flushes += 1;
                Some(lpn)
            } else {
                None
            }
        }

        fn access(&mut self, lpn: u64, write: bool) -> CacheOutcome {
            if self.cfg.capacity_pages == 0 || (write && !self.cfg.write_back) {
                return CacheOutcome::Bypass;
            }
            if self.entries.contains_key(&lpn) {
                self.hits += 1;
                self.touch(lpn, write);
                CacheOutcome::Hit
            } else {
                self.misses += 1;
                let mut evict_flush = None;
                if self.entries.len() as u32 >= self.cfg.capacity_pages {
                    evict_flush = self.evict_lru();
                }
                self.touch(lpn, write);
                CacheOutcome::Miss { evict_flush }
            }
        }

        fn dirty_pages(&self) -> Vec<u64> {
            let mut v: Vec<u64> = self
                .entries
                .iter()
                .filter(|(_, (_, d))| *d)
                .map(|(&l, _)| l)
                .collect();
            v.sort();
            v
        }
    }

    /// Randomized oracle check: a long random mix of reads and writes over
    /// a footprint several times the capacity must produce *identical*
    /// outcomes — every hit/miss, every eviction victim, every flush — on
    /// the O(log n) index and the old O(n) scan.
    #[test]
    fn indexed_lru_matches_scan_oracle() {
        use crate::util::prng::Prng;
        for (seed, cap) in [(1u64, 1u32), (2, 7), (3, 32), (4, 128)] {
            let cfg = CacheConfig {
                capacity_pages: cap,
                write_back: true,
            };
            let mut fast = DramCache::new(cfg);
            let mut oracle = ScanOracle::new(cfg);
            let mut rng = Prng::new(0xCAC4E + seed);
            for step in 0..4000u32 {
                let lpn = rng.next_bounded(cap as u64 * 4);
                let write = rng.next_bounded(2) == 0;
                let got = if write { fast.write(lpn) } else { fast.read(lpn) };
                let want = oracle.access(lpn, write);
                assert_eq!(got, want, "seed {seed} cap {cap} step {step} lpn {lpn}");
            }
            assert_eq!(fast.hits, oracle.hits);
            assert_eq!(fast.misses, oracle.misses);
            assert_eq!(fast.flushes, oracle.flushes);
            assert_eq!(fast.dirty_pages(), oracle.dirty_pages());
            assert_eq!(fast.len(), oracle.entries.len());
        }
    }

    /// The recency index never leaks: one index entry per cached lpn,
    /// through heavy churn and reset.
    #[test]
    fn index_stays_in_lockstep_with_entries() {
        let mut c = cache(4);
        for lpn in 0..64 {
            c.write(lpn % 9);
            c.read(lpn % 5);
            assert_eq!(c.by_tick.len(), c.entries.len());
        }
        c.reset(CacheConfig {
            capacity_pages: 2,
            write_back: true,
        });
        assert!(c.by_tick.is_empty() && c.entries.is_empty());
        c.write(1);
        assert_eq!(c.by_tick.len(), 1);
    }
}
