//! DRAM cache buffer (§2.2.1: "in most commercially available SSDs, DRAM is
//! used as a cache buffer to hide the long access latency of NAND").
//!
//! A page-granular write-back LRU cache. On a hit, the NAND path is skipped
//! entirely (the paper's point); evictions of dirty pages generate flush
//! writes. Disabled (capacity 0) for the paper's Table 3–5 runs, which
//! measure the raw NAND path; exercised by its own tests and ablations.

use std::collections::HashMap;

/// Cache configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Capacity in pages (0 disables the cache).
    pub capacity_pages: u32,
    /// If true, writes are absorbed and flushed on eviction (write-back);
    /// otherwise writes always go to NAND (write-through).
    pub write_back: bool,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            capacity_pages: 0,
            write_back: true,
        }
    }
}

/// Result of a cache access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Serviced from DRAM; no NAND access needed.
    Hit,
    /// Must access NAND; carries an optional dirty eviction to flush first.
    Miss { evict_flush: Option<u64> },
    /// Cache disabled.
    Bypass,
}

/// Page-granular LRU cache with dirty tracking.
pub struct DramCache {
    cfg: CacheConfig,
    /// lpn -> (lru tick, dirty)
    entries: HashMap<u64, (u64, bool)>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub flushes: u64,
}

impl DramCache {
    pub fn new(cfg: CacheConfig) -> DramCache {
        DramCache {
            cfg,
            entries: HashMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            flushes: 0,
        }
    }

    /// Drop all entries and statistics, keeping the map's allocation; the
    /// configuration may change when a sweep worker is retargeted.
    pub fn reset(&mut self, cfg: CacheConfig) {
        self.cfg = cfg;
        self.entries.clear();
        self.tick = 0;
        self.hits = 0;
        self.misses = 0;
        self.flushes = 0;
    }

    fn touch(&mut self, lpn: u64, dirty: bool) {
        self.tick += 1;
        let e = self.entries.entry(lpn).or_insert((0, false));
        e.0 = self.tick;
        e.1 |= dirty;
    }

    /// Evict the LRU entry; returns `Some(lpn)` if it was dirty (needs
    /// flushing to NAND).
    fn evict_lru(&mut self) -> Option<u64> {
        let (&lpn, &(_, dirty)) = self.entries.iter().min_by_key(|(_, (t, _))| *t)?;
        self.entries.remove(&lpn);
        if dirty {
            self.flushes += 1;
            Some(lpn)
        } else {
            None
        }
    }

    fn insert(&mut self, lpn: u64, dirty: bool) -> Option<u64> {
        let mut flush = None;
        if self.entries.len() as u32 >= self.cfg.capacity_pages && !self.entries.contains_key(&lpn)
        {
            flush = self.evict_lru();
        }
        self.touch(lpn, dirty);
        flush
    }

    /// Access for read.
    pub fn read(&mut self, lpn: u64) -> CacheOutcome {
        if self.cfg.capacity_pages == 0 {
            return CacheOutcome::Bypass;
        }
        if self.entries.contains_key(&lpn) {
            self.hits += 1;
            self.touch(lpn, false);
            CacheOutcome::Hit
        } else {
            self.misses += 1;
            let evict_flush = self.insert(lpn, false);
            CacheOutcome::Miss { evict_flush }
        }
    }

    /// Access for write.
    pub fn write(&mut self, lpn: u64) -> CacheOutcome {
        if self.cfg.capacity_pages == 0 || !self.cfg.write_back {
            return CacheOutcome::Bypass;
        }
        if self.entries.contains_key(&lpn) {
            self.hits += 1;
            self.touch(lpn, true);
            CacheOutcome::Hit
        } else {
            self.misses += 1;
            let evict_flush = self.insert(lpn, true);
            CacheOutcome::Miss { evict_flush }
        }
    }

    /// Dirty pages remaining (to flush at shutdown).
    pub fn dirty_pages(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self
            .entries
            .iter()
            .filter(|(_, (_, d))| *d)
            .map(|(&l, _)| l)
            .collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache(cap: u32) -> DramCache {
        DramCache::new(CacheConfig {
            capacity_pages: cap,
            write_back: true,
        })
    }

    #[test]
    fn disabled_cache_bypasses() {
        let mut c = cache(0);
        assert_eq!(c.read(1), CacheOutcome::Bypass);
        assert_eq!(c.write(1), CacheOutcome::Bypass);
    }

    #[test]
    fn read_after_write_hits() {
        let mut c = cache(4);
        assert!(matches!(c.write(7), CacheOutcome::Miss { .. }));
        assert_eq!(c.read(7), CacheOutcome::Hit);
        assert_eq!(c.hits, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = cache(2);
        c.write(1);
        c.write(2);
        c.read(1); // 2 becomes LRU
        match c.write(3) {
            CacheOutcome::Miss { evict_flush } => assert_eq!(evict_flush, Some(2)),
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn clean_eviction_needs_no_flush() {
        let mut c = cache(1);
        c.read(1); // clean
        match c.read(2) {
            CacheOutcome::Miss { evict_flush } => assert_eq!(evict_flush, None),
            o => panic!("unexpected {o:?}"),
        }
    }

    #[test]
    fn dirty_pages_listed() {
        let mut c = cache(4);
        c.write(3);
        c.write(1);
        c.read(2);
        assert_eq!(c.dirty_pages(), vec![1, 3]);
    }

    #[test]
    fn hit_rate() {
        let mut c = cache(8);
        c.write(1);
        c.read(1);
        c.read(1);
        assert!((c.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
