//! The SSD controller (Fig. 1): NAND interface blocks, ECC, FTL, DRAM
//! cache, and the way/channel scheduling policies that implement
//! way interleaving and channel striping (Fig. 2).
//!
//! These are *policy and state* types; the event-driven composition lives
//! in [`crate::coordinator`], which owns the DES model.

pub mod cache;
pub mod channel;
pub mod ecc;
pub mod ftl;
pub mod nand_if;
pub mod sched;
pub mod way;

pub use cache::{CacheConfig, DramCache};
pub use channel::ChannelState;
pub use ecc::EccModel;
pub use nand_if::NandIf;
pub use sched::{Grant, SchedKind, WayScheduler};
pub use way::{PageJob, PageJobKind, WayState};
