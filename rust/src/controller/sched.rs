//! Pluggable way-scheduling (QoS) policies.
//!
//! The channel scheduler multiplexes one shared bus across the channel's
//! way queues. PR 5 extracted the decision into the [`WayScheduler`]
//! trait so QoS policies plug in per config (`qos.way_scheduler` in TOML):
//!
//! * [`RoundRobin`] — the paper's arbiter, bit-identical to the historical
//!   hard-coded implementation (oracle-tested in `rust/tests/qos.rs`).
//! * [`ReadPriority`] — reads preempt *queued* writes at arbitration: a
//!   way whose queue holds a read outranks ways that would dispatch a
//!   program/erase, and the read is pulled past queued writes within its
//!   way. In-flight array operations are never preempted.
//! * [`WeightedQos`] — weighted round robin across the four priority
//!   classes ([`crate::host::trace::CLASS_URGENT`]..=background), with
//!   credit refill when every pending class is spent — so any class with
//!   a positive weight is starvation-free (property-tested in
//!   `rust/tests/ftl_properties.rs`).
//!
//! All policies share the phase hierarchy the paper's interleaving relies
//! on: status polls first (they free a way in ~0.1 µs), then command
//! dispatch (starts an array op → creates parallelism), then data-out.
//! Policies only reorder *within* the dispatch tier, where the queueing
//! actually happens — and never across a queued background job
//! ([`WayState::reorder_window`]): an FTL write plan's copy-back and
//! erase ops keep their order relative to the host jobs queued around
//! them, so QoS cannot program a block before its reclaim erase runs.
//!
//! Cost note: the per-way class/read counts make "does this way have a
//! candidate?" O(1), and with no background jobs queued (the fresh-drive
//! E9 regime) the reorder window is the whole queue at O(1) too. When
//! background jobs *are* queued (steady/tiered + QoS), locating the
//! barrier and the in-way candidate is a prefix scan per grant — fine at
//! GC-throttled depths; an incrementally-maintained first-background
//! index is the upgrade path if a sweep ever couples deep overload
//! backlogs with background traffic.

use crate::controller::way::WayState;
use crate::host::trace::NUM_CLASSES;
use crate::util::time::Ps;

/// Which way-scheduling policy a config selects (`qos.way_scheduler`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedKind {
    RoundRobin,
    ReadPriority,
    WeightedQos,
}

impl SchedKind {
    pub const ALL: [SchedKind; 3] = [
        SchedKind::RoundRobin,
        SchedKind::ReadPriority,
        SchedKind::WeightedQos,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SchedKind::RoundRobin => "round_robin",
            SchedKind::ReadPriority => "read_priority",
            SchedKind::WeightedQos => "weighted_qos",
        }
    }

    pub fn parse(s: &str) -> Option<SchedKind> {
        match s {
            "round_robin" => Some(SchedKind::RoundRobin),
            "read_priority" => Some(SchedKind::ReadPriority),
            "weighted_qos" => Some(SchedKind::WeightedQos),
            _ => None,
        }
    }
}

/// A bus-grant decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grant {
    /// Way to grant the bus to.
    pub way: usize,
    /// For a dispatch grant (the way has no in-flight job): index into the
    /// way's queue of the job to dispatch. 0 — and unused — for in-flight
    /// phase grants (status poll / data-out).
    pub job: usize,
}

impl Grant {
    fn phase(way: usize) -> Grant {
        Grant { way, job: 0 }
    }
}

/// A way-scheduling policy: given the channel's ways at time `now`, decide
/// which way (and, for dispatches, which queued job) gets the bus next.
///
/// `Send` because channel state (including its boxed policy) migrates into
/// per-channel shard workers under `[engine] threads > 1`
/// ([`crate::coordinator::shard`]).
pub trait WayScheduler: Send {
    fn pick(&mut self, ways: &[WayState], now: Ps) -> Option<Grant>;

    /// Forget all arbitration state (sweep-worker reuse).
    fn reset(&mut self);
}

/// Build the policy a config names. `weights` is only consulted by
/// [`WeightedQos`].
pub fn build(kind: SchedKind, weights: [u32; NUM_CLASSES]) -> Box<dyn WayScheduler> {
    match kind {
        SchedKind::RoundRobin => Box::new(RoundRobin::default()),
        SchedKind::ReadPriority => Box::new(ReadPriority::default()),
        SchedKind::WeightedQos => Box::new(WeightedQos::new(weights)),
    }
}

/// The paper's arbiter: highest scheduling class first (status > command
/// dispatch > data-out, [`WayState::bus_class`]), round robin within a
/// class, FIFO within a way. Bit-identical to the pre-trait hard-coded
/// implementation.
#[derive(Debug, Clone, Default)]
pub struct RoundRobin {
    rr_next: usize,
}

impl WayScheduler for RoundRobin {
    fn pick(&mut self, ways: &[WayState], now: Ps) -> Option<Grant> {
        let n = ways.len();
        let mut best: Option<(u8, usize)> = None; // (class, idx)
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            if let Some(class) = ways[i].bus_class(now) {
                if class == 0 {
                    self.rr_next = (i + 1) % n;
                    return Some(Grant::phase(i));
                }
                match best {
                    Some((c, _)) if c <= class => {}
                    _ => best = Some((class, i)),
                }
            }
        }
        best.map(|(_, i)| {
            self.rr_next = (i + 1) % n;
            Grant::phase(i)
        })
    }

    fn reset(&mut self) {
        self.rr_next = 0;
    }
}

/// Reads preempt queued writes: at the dispatch tier, a way holding a
/// queued read outranks ways that would dispatch a program/erase, and the
/// first queued read is pulled past earlier queued writes on its way —
/// but never past a queued background job ([`WayState::reorder_window`]:
/// GC/WL/migration copy-back and erases keep their plan order relative to
/// the host jobs queued around them). Phase hierarchy and round robin
/// within a rank are unchanged.
#[derive(Debug, Clone, Default)]
pub struct ReadPriority {
    rr_next: usize,
}

impl WayScheduler for ReadPriority {
    fn pick(&mut self, ways: &[WayState], now: Ps) -> Option<Grant> {
        let n = ways.len();
        // Rank: 0 status, 1 read dispatch, 2 write/erase dispatch,
        // 3 data-out.
        let mut best: Option<(u8, usize, usize)> = None; // (rank, way, job)
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            let Some(class) = ways[i].bus_class(now) else {
                continue;
            };
            let (rank, job) = match class {
                0 => {
                    self.rr_next = (i + 1) % n;
                    return Some(Grant::phase(i));
                }
                1 => {
                    let window = ways[i].reorder_window();
                    let read = if ways[i].queued_reads() == 0 {
                        None
                    } else {
                        // Single-lane SoA scan over the kind column.
                        ways[i].first_read_in(window)
                    };
                    match read {
                        Some(j) => (1, j),
                        None => (2, 0),
                    }
                }
                _ => (3, 0),
            };
            match best {
                Some((r, _, _)) if r <= rank => {}
                _ => best = Some((rank, i, job)),
            }
        }
        best.map(|(_, i, job)| {
            self.rr_next = (i + 1) % n;
            Grant { way: i, job }
        })
    }

    fn reset(&mut self) {
        self.rr_next = 0;
    }
}

/// Weighted round robin across priority classes at the dispatch tier.
/// Each class's credit refills to its weight once every class with pending
/// work is spent, so a class with weight *w* receives *w* of every
/// Σweights dispatch grants while contended — and at least one, which
/// makes the policy starvation-free for any all-positive weight vector
/// (validated at config load).
#[derive(Debug, Clone)]
pub struct WeightedQos {
    weights: [u32; NUM_CLASSES],
    credits: [u32; NUM_CLASSES],
    rr_next: usize,
}

impl WeightedQos {
    pub fn new(weights: [u32; NUM_CLASSES]) -> WeightedQos {
        // Config validation rejects zero weights (they would starve a
        // class); clamping keeps a hand-built scheduler starvation-free
        // too, which the dispatch tier's refill logic relies on.
        let weights = weights.map(|w| w.max(1));
        WeightedQos {
            weights,
            credits: weights,
            rr_next: 0,
        }
    }

    /// First way (round robin from `rr_next`) with a dispatchable job of
    /// `class`, with that job's index. Host-class candidates must sit
    /// before the way's first queued background job
    /// ([`WayState::reorder_window`]); the first background job itself is
    /// the (only) background candidate of its way.
    fn dispatch_of(&self, ways: &[WayState], now: Ps, class: u8) -> Option<(usize, usize)> {
        let n = ways.len();
        let background = class >= crate::host::trace::CLASS_BACKGROUND;
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            if ways[i].queued_of_class(class) == 0 || ways[i].bus_class(now) != Some(1) {
                continue;
            }
            let window = ways[i].reorder_window();
            let limit = if background {
                // The barrier job is the first of its class and eligible.
                (window + 1).min(ways[i].queue_len())
            } else {
                window
            };
            // Single-lane SoA scan over the class column.
            if let Some(j) = ways[i].first_of_class_in(class, limit) {
                return Some((i, j));
            }
        }
        None
    }
}

impl WayScheduler for WeightedQos {
    fn pick(&mut self, ways: &[WayState], now: Ps) -> Option<Grant> {
        let n = ways.len();
        // Status polls first (free the way), round robin.
        let mut dataout: Option<usize> = None;
        let mut any_dispatch = false;
        for off in 0..n {
            let i = (self.rr_next + off) % n;
            match ways[i].bus_class(now) {
                Some(0) => {
                    self.rr_next = (i + 1) % n;
                    return Some(Grant::phase(i));
                }
                Some(1) => any_dispatch = true,
                Some(_) if dataout.is_none() => dataout = Some(i),
                _ => {}
            }
        }
        // Dispatch tier: WRR over classes, spending credit first and
        // refilling once every pending class is spent.
        if any_dispatch {
            for refill in [false, true] {
                if refill {
                    self.credits = self.weights;
                }
                for class in 0..NUM_CLASSES as u8 {
                    if self.credits[class as usize] == 0 {
                        continue;
                    }
                    if let Some((way, job)) = self.dispatch_of(ways, now, class) {
                        self.credits[class as usize] -= 1;
                        self.rr_next = (way + 1) % n;
                        return Some(Grant { way, job });
                    }
                }
            }
            unreachable!("a dispatch candidate exists after refill");
        }
        // Data-out last, round robin.
        dataout.map(|i| {
            self.rr_next = (i + 1) % n;
            Grant::phase(i)
        })
    }

    fn reset(&mut self) {
        self.credits = self.weights;
        self.rr_next = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::way::{JobPhase, PageJob, PageJobKind};
    use crate::host::trace::{CLASS_BACKGROUND, CLASS_BULK, CLASS_NORMAL, CLASS_URGENT};
    use crate::nand::chip::Chip;
    use crate::nand::datasheet::NandTiming;

    fn way() -> WayState {
        WayState::new(Chip::new(NandTiming::slc(), 8))
    }

    fn job(kind: PageJobKind, class: u8) -> PageJob {
        PageJob {
            req: 0,
            stream: 0,
            class,
            kind,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    /// Drain the scheduler against always-dispatchable ways, returning the
    /// granted job classes in order.
    fn drain(sched: &mut dyn WayScheduler, ways: &mut [WayState]) -> Vec<u8> {
        let mut order = Vec::new();
        while let Some(g) = sched.pick(ways, Ps::ZERO) {
            let j = ways[g.way].take_job(g.job).expect("granted job");
            order.push(j.class);
        }
        order
    }

    #[test]
    fn read_priority_pulls_read_past_queued_writes() {
        let mut ways = vec![way(), way()];
        ways[0].push(job(PageJobKind::Program, CLASS_BULK));
        ways[0].push(job(PageJobKind::Program, CLASS_BULK));
        ways[0].push(job(PageJobKind::Read, CLASS_URGENT));
        ways[1].push(job(PageJobKind::Program, CLASS_BULK));
        let mut s = ReadPriority::default();
        let g = s.pick(&ways, Ps::ZERO).unwrap();
        assert_eq!((g.way, g.job), (0, 2), "the queued read jumps the line");
        // Round robin drains the writes once no read is pending.
        ways[0].take_job(2);
        let g = s.pick(&ways, Ps::ZERO).unwrap();
        assert_eq!(g.job, 0);
    }

    #[test]
    fn read_priority_equals_round_robin_without_reads() {
        let mk = |n: usize| {
            let mut ways: Vec<WayState> = (0..n).map(|_| way()).collect();
            for (i, w) in ways.iter_mut().enumerate() {
                for _ in 0..=i {
                    w.push(job(PageJobKind::Program, CLASS_NORMAL));
                }
            }
            ways
        };
        let grants = |sched: &mut dyn WayScheduler| {
            let mut ways = mk(3);
            let mut order = Vec::new();
            while let Some(g) = sched.pick(&ways, Ps::ZERO) {
                ways[g.way].take_job(g.job);
                order.push(g.way);
            }
            order
        };
        assert_eq!(
            grants(&mut RoundRobin::default()),
            grants(&mut ReadPriority::default())
        );
    }

    /// Background jobs are plan-order barriers: no policy pulls a host
    /// job past a queued background (GC/WL/migration) job, preserving the
    /// copy-back → erase → host-program order an FTL write plan relies
    /// on. Background jobs themselves stay FIFO.
    #[test]
    fn policies_never_reorder_across_background_barrier() {
        // Plan shape on one way: [GC read (bg), GC program (bg),
        // erase (bg), host program (bulk)], then a host read arrives.
        let build = || {
            let mut w = way();
            w.push(job(PageJobKind::Read, CLASS_BACKGROUND));
            w.push(job(PageJobKind::Program, CLASS_BACKGROUND));
            w.push(job(PageJobKind::Erase, CLASS_BACKGROUND));
            w.push(job(PageJobKind::Program, CLASS_BULK));
            w.push(job(PageJobKind::Read, CLASS_URGENT));
            vec![w]
        };
        assert_eq!(build()[0].reorder_window(), 0, "barrier at the head");
        for kind in SchedKind::ALL {
            let mut ways = build();
            let mut s = build_sched(kind);
            let order: Vec<PageJobKind> = std::iter::from_fn(|| {
                s.pick(&ways, Ps::ZERO)
                    .map(|g| ways[g.way].take_job(g.job).expect("granted job").kind)
            })
            .collect();
            // The three background ops dispatch first, in plan order.
            assert_eq!(
                &order[..3],
                &[PageJobKind::Read, PageJobKind::Program, PageJobKind::Erase],
                "{kind:?} must not break plan order"
            );
            assert_eq!(order.len(), 5, "{kind:?} drains everything");
        }
        // Once the barrier clears, the host read may jump the host write.
        let mut ways = build();
        for _ in 0..3 {
            ways[0].take_job(0);
        }
        let mut s = ReadPriority::default();
        let g = s.pick(&ways, Ps::ZERO).unwrap();
        assert_eq!(g.job, 1, "host read preempts the host write");
    }

    fn build_sched(kind: SchedKind) -> Box<dyn WayScheduler> {
        build(kind, [8, 4, 2, 1])
    }

    #[test]
    fn weighted_qos_shares_follow_weights() {
        // Classes on separate ways, so the plan-order barrier (which
        // would interleave them FIFO on one way) does not apply.
        let mut ways = vec![way(), way()];
        for _ in 0..12 {
            ways[0].push(job(PageJobKind::Program, CLASS_URGENT));
            ways[1].push(job(PageJobKind::Program, CLASS_BACKGROUND));
        }
        let mut s = WeightedQos::new([3, 1, 1, 1]);
        let order = drain(&mut s, &mut ways);
        assert_eq!(order.len(), 24);
        // First credit cycle: 3 urgent, then background gets its grant.
        assert_eq!(&order[..4], &[0, 0, 0, 3]);
        // Background is never starved: within any 4-grant window it
        // appears at least once while it has work pending.
        for w in order[..20].windows(4) {
            assert!(w.contains(&3), "window {w:?} starves background");
        }
    }

    #[test]
    fn weighted_qos_falls_back_across_classes() {
        // Only bulk jobs pending: the urgent credit cannot block them.
        let mut ways = vec![way()];
        ways[0].push(job(PageJobKind::Program, CLASS_BULK));
        ways[0].push(job(PageJobKind::Program, CLASS_BULK));
        let mut s = WeightedQos::new([8, 4, 2, 1]);
        let order = drain(&mut s, &mut ways);
        assert_eq!(order, vec![CLASS_BULK, CLASS_BULK]);
    }

    #[test]
    fn status_precedes_dispatch_for_all_policies() {
        for kind in SchedKind::ALL {
            let mut ways = vec![way(), way()];
            ways[0].push(job(PageJobKind::Read, CLASS_URGENT));
            let mut j = job(PageJobKind::Program, CLASS_BULK);
            j.phase = JobPhase::AwaitStatus;
            ways[1].inflight = Some(j);
            ways[1].array_done_at = Ps::ZERO;
            let mut s = build(kind, [8, 4, 2, 1]);
            let g = s.pick(&ways, Ps::ZERO).unwrap();
            assert_eq!(g.way, 1, "{kind:?}: status poll must come first");
        }
    }

    #[test]
    fn kind_parse_roundtrip() {
        for k in SchedKind::ALL {
            assert_eq!(SchedKind::parse(k.name()), Some(k));
        }
        assert_eq!(SchedKind::parse("fifo"), None);
    }
}
