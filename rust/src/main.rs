//! `ddrnand` — leader binary. See `ddrnand --help` / `cli::usage()`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", ddrnand::cli::usage());
        std::process::exit(0);
    }
    std::process::exit(ddrnand::cli::run(&argv));
}
