//! Deterministic pseudo-random number generation.
//!
//! `rand` is unavailable offline; we implement SplitMix64 (seeding/stream
//! splitting) and xoshiro256** (bulk generation) — both public-domain
//! algorithms with excellent statistical quality, more than sufficient for
//! workload generation and PVT jitter sampling.

/// SplitMix64: tiny, fast seeder; also a fine generator on its own.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main generator used throughout the simulator.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via SplitMix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Prng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) via Lemire's multiply-shift rejection.
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [lo, hi] inclusive.
    pub fn next_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_bounded(hi - lo + 1)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard exponential (mean 1) via inverse transform — the
    /// inter-arrival law of a Poisson process. Scale by the desired mean
    /// to get arbitrary-rate gaps (see `host::trace::TraceGen`).
    pub fn next_exponential(&mut self) -> f64 {
        // next_f64 is in [0, 1), so 1 - u is in (0, 1] and ln() is finite.
        -(1.0 - self.next_f64()).ln()
    }

    /// Bernoulli with probability `p`.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_bounded(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let x = p.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bounded_is_bounded_and_covers() {
        let mut p = Prng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = p.next_bounded(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut p = Prng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_moments_and_support() {
        let mut p = Prng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| p.next_exponential()).collect();
        assert!(xs.iter().all(|&x| x >= 0.0 && x.is_finite()));
        let mean = xs.iter().sum::<f64>() / n as f64;
        // Exponential(1): mean 1, variance 1.
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut p = Prng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        p.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
