//! Small self-contained utilities shared across the simulator.
//!
//! The offline build environment ships only the `xla` crate closure, so the
//! usual ecosystem crates (rand, statrs, humansize, ...) are replaced by the
//! minimal implementations in this module. Everything here is deterministic
//! and dependency-free.

pub mod fmt;
pub mod prng;
pub mod stats;
pub mod time;

pub use fmt::{fmt_bytes, fmt_mbps, fmt_si};
pub use prng::{Prng, SplitMix64};
pub use stats::Summary;
pub use time::Ps;
