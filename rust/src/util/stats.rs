//! Descriptive statistics for metrics and the bench harness.

/// Summary statistics over a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns `None` on an empty sample.
    ///
    /// NaN policy: NaN samples are ordered by IEEE `total_cmp` (positive
    /// NaN sorts above +∞, negative NaN below −∞) instead of panicking,
    /// so they surface in the extrema / tail percentiles and poison the
    /// mean — visible in the output rather than a crash mid-sweep.
    /// Callers who need NaN-free statistics filter their samples first.
    pub fn from_samples(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        Some(Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        })
    }
}

/// Linear-interpolated percentile of a **sorted** sample, `p` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean, as used for the "Ratio" columns of the paper's tables
/// (Tables 3–5 use the geometric mean for ratio columns).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Arithmetic mean, as used for the "Performance" columns of Tables 3–5.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Jain's fairness index over per-tenant allocations (throughputs):
/// `(Σx)² / (n·Σx²)`, in (0, 1] — 1 when every tenant gets an equal
/// share, → 1/n when one tenant takes everything. NaN for fewer than two
/// tenants (fairness of one stream is meaningless) or an all-zero vector.
pub fn jain_fairness(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return f64::NAN;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Online (Welford) accumulator for streaming metrics.
#[derive(Debug, Clone)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Default for Welford {
    /// Same as [`Welford::new`] — a derived zeroed `min`/`max` would
    /// corrupt the extrema of any positive sample stream.
    fn default() -> Self {
        Welford::new()
    }
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    /// Smallest pushed value; NaN on an empty accumulator (consistent with
    /// [`mean`](Self::mean) — the old ±∞ sentinels leaked straight into
    /// BENCH JSON, which has no representation for them).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }
    /// Largest pushed value; NaN on an empty accumulator (see [`min`](Self::min)).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from_samples(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_none() {
        assert!(Summary::from_samples(&[]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile_sorted(&v, 0.0), 10.0);
        assert_eq!(percentile_sorted(&v, 100.0), 40.0);
        assert!((percentile_sorted(&v, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_paper_usage() {
        // Table 3, SLC write P/C column: geomean of the 5 ratios = 1.42
        let ratios = [8.50 / 7.77, 17.52 / 15.22, 34.30 / 28.94, 63.00 / 39.78, 97.35 / 39.76];
        let g = geomean(&ratios);
        assert!((g - 1.42).abs() < 0.02, "geomean={g}");
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let s = Summary::from_samples(&xs).unwrap();
        assert!((w.mean() - s.mean).abs() < 1e-9);
        assert!((w.stddev() - s.stddev).abs() < 1e-9);
        assert_eq!(w.min(), s.min);
        assert_eq!(w.max(), s.max);
    }

    /// Regression: a NaN-bearing sample set must not panic (the old
    /// `partial_cmp().unwrap()` comparator did); NaNs sort to the top end
    /// and surface in max while the clean low quantiles stay exact.
    #[test]
    fn summary_tolerates_nan_samples() {
        let s = Summary::from_samples(&[2.0, f64::NAN, 1.0]).unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan(), "NaN surfaces in the max");
        assert_eq!(s.median, 2.0);
        assert!(s.mean.is_nan(), "NaN poisons the mean visibly");
        // All-NaN input still summarizes without panicking.
        let s = Summary::from_samples(&[f64::NAN, f64::NAN]).unwrap();
        assert!(s.min.is_nan() && s.max.is_nan());
    }

    #[test]
    fn jain_fairness_bounds() {
        assert!((jain_fairness(&[10.0, 10.0]) - 1.0).abs() < 1e-12);
        // One tenant takes everything: 1/n.
        assert!((jain_fairness(&[30.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        // 2:1 split: 9/10.
        assert!((jain_fairness(&[2.0, 1.0]) - 0.9).abs() < 1e-12);
        assert!(jain_fairness(&[5.0]).is_nan());
        assert!(jain_fairness(&[0.0, 0.0]).is_nan());
    }

    /// Regression: an empty accumulator must report NaN across the board,
    /// never the ±∞ seed sentinels (which are unrepresentable in JSON and
    /// used to reach `bench.rs` emission verbatim).
    #[test]
    fn welford_empty_is_nan_not_infinite() {
        let w = Welford::new();
        assert_eq!(w.count(), 0);
        assert!(w.mean().is_nan());
        assert!(w.min().is_nan(), "empty min leaked {}", w.min());
        assert!(w.max().is_nan(), "empty max leaked {}", w.max());
        assert!(!w.min().is_infinite() && !w.max().is_infinite());
        // One sample restores exact reporting.
        let mut w = Welford::new();
        w.push(3.5);
        assert_eq!(w.min(), 3.5);
        assert_eq!(w.max(), 3.5);
    }
}
