//! Human-friendly number formatting for reports and the CLI.

/// Format a byte count with binary units (KiB/MiB/GiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

/// Format a bandwidth in MB/s with two decimals (the paper's table format).
pub fn fmt_mbps(mbps: f64) -> String {
    format!("{mbps:.2}")
}

/// Format with an SI prefix (k/M/G), e.g. event rates.
pub fn fmt_si(v: f64) -> String {
    let a = v.abs();
    if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(65536), "64.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }

    #[test]
    fn mbps() {
        assert_eq!(fmt_mbps(97.351), "97.35");
    }

    #[test]
    fn si() {
        assert_eq!(fmt_si(20_000_000.0), "20.00M");
        assert_eq!(fmt_si(1_500.0), "1.50k");
        assert_eq!(fmt_si(12.3), "12.30");
    }
}
