//! Simulation time base.
//!
//! All simulator time is integer **picoseconds** (`Ps`). The paper's timing
//! parameters span 0.02 ns (t_H) to 832 µs (MLC t_PROG); picoseconds keep
//! every quantity exact (Table 2 is specified to 10 ps resolution) while an
//! `i64` still covers ±106 days of simulated time — ample for any campaign.

/// A point in (or duration of) simulated time, in integer picoseconds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Ps(pub i64);

impl Ps {
    pub const ZERO: Ps = Ps(0);
    pub const MAX: Ps = Ps(i64::MAX);

    /// Construct from picoseconds.
    pub const fn ps(v: i64) -> Ps {
        Ps(v)
    }
    /// Construct from nanoseconds.
    pub const fn ns(v: i64) -> Ps {
        Ps(v * 1_000)
    }
    /// Construct from microseconds.
    pub const fn us(v: i64) -> Ps {
        Ps(v * 1_000_000)
    }
    /// Construct from milliseconds.
    pub const fn ms(v: i64) -> Ps {
        Ps(v * 1_000_000_000)
    }
    /// Construct from (possibly fractional) nanoseconds, rounding to ps.
    pub fn from_ns_f64(v: f64) -> Ps {
        Ps((v * 1_000.0).round() as i64)
    }
    /// Construct from (possibly fractional) microseconds, rounding to ps.
    pub fn from_us_f64(v: f64) -> Ps {
        Ps((v * 1_000_000.0).round() as i64)
    }

    /// Value in picoseconds.
    pub const fn as_ps(self) -> i64 {
        self.0
    }
    /// Value in fractional nanoseconds.
    pub fn as_ns_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }
    /// Value in fractional microseconds.
    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }
    /// Value in fractional seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e12
    }

    /// Saturating addition.
    pub fn saturating_add(self, rhs: Ps) -> Ps {
        Ps(self.0.saturating_add(rhs.0))
    }

    /// Multiply a per-unit duration by a count (e.g. bytes × t_cycle).
    pub fn times(self, n: u64) -> Ps {
        Ps(self.0 * n as i64)
    }

    /// max(self, other)
    pub fn max(self, other: Ps) -> Ps {
        if self >= other {
            self
        } else {
            other
        }
    }
    /// min(self, other)
    pub fn min(self, other: Ps) -> Ps {
        if self <= other {
            self
        } else {
            other
        }
    }
}

impl std::ops::Add for Ps {
    type Output = Ps;
    fn add(self, rhs: Ps) -> Ps {
        Ps(self.0 + rhs.0)
    }
}
impl std::ops::AddAssign for Ps {
    fn add_assign(&mut self, rhs: Ps) {
        self.0 += rhs.0;
    }
}
impl std::ops::Sub for Ps {
    type Output = Ps;
    fn sub(self, rhs: Ps) -> Ps {
        Ps(self.0 - rhs.0)
    }
}
impl std::ops::SubAssign for Ps {
    fn sub_assign(&mut self, rhs: Ps) {
        self.0 -= rhs.0;
    }
}
impl std::ops::Mul<i64> for Ps {
    type Output = Ps;
    fn mul(self, rhs: i64) -> Ps {
        Ps(self.0 * rhs)
    }
}
impl std::ops::Div<i64> for Ps {
    type Output = Ps;
    fn div(self, rhs: i64) -> Ps {
        Ps(self.0 / rhs)
    }
}

impl std::fmt::Display for Ps {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let v = self.0;
        if v.abs() >= 1_000_000_000_000 {
            write!(f, "{:.3}s", v as f64 / 1e12)
        } else if v.abs() >= 1_000_000_000 {
            write!(f, "{:.3}ms", v as f64 / 1e9)
        } else if v.abs() >= 1_000_000 {
            write!(f, "{:.3}us", v as f64 / 1e6)
        } else if v.abs() >= 1_000 {
            write!(f, "{:.3}ns", v as f64 / 1e3)
        } else {
            write!(f, "{v}ps")
        }
    }
}

/// Bandwidth helper: bytes moved over a duration, in MB/s (decimal MB, as
/// used by the paper's tables).
pub fn mbps(bytes: u64, elapsed: Ps) -> f64 {
    if elapsed.0 <= 0 {
        return 0.0;
    }
    bytes as f64 / elapsed.as_secs_f64() / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_roundtrip() {
        assert_eq!(Ps::ns(20).as_ps(), 20_000);
        assert_eq!(Ps::us(25).as_ps(), 25_000_000);
        assert_eq!(Ps::ms(1).as_ps(), 1_000_000_000);
        assert_eq!(Ps::from_ns_f64(19.81).as_ps(), 19_810);
        assert_eq!(Ps::from_ns_f64(0.02).as_ps(), 20);
    }

    #[test]
    fn arithmetic() {
        let a = Ps::ns(12);
        let b = Ps::ns(8);
        assert_eq!(a + b, Ps::ns(20));
        assert_eq!(a - b, Ps::ns(4));
        assert_eq!(a * 2, Ps::ns(24));
        assert_eq!(a / 2, Ps::ns(6));
        assert_eq!(a.times(2048), Ps::ns(24576));
        assert_eq!(a.max(b), a);
        assert_eq!(a.min(b), b);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", Ps::ps(500)), "500ps");
        assert_eq!(format!("{}", Ps::ns(12)), "12.000ns");
        assert_eq!(format!("{}", Ps::us(25)), "25.000us");
    }

    #[test]
    fn bandwidth() {
        // 2048 bytes in 73.72us -> 27.78 MB/s (paper Table 3, SLC read 1-way CONV)
        let bw = mbps(2048, Ps::from_us_f64(73.72));
        assert!((bw - 27.78).abs() < 0.01, "bw={bw}");
    }

    #[test]
    fn ordering_and_saturating() {
        assert!(Ps::ns(1) < Ps::ns(2));
        assert_eq!(Ps::MAX.saturating_add(Ps::ns(1)), Ps::MAX);
    }
}
