//! # ddrnand
//!
//! Reproduction of *"A High-Performance Solid-State Disk with
//! Double-Data-Rate NAND Flash Memory"* (Chung, Son, Bang, Kim, Shin, Yoon —
//! 2015): a discrete-event SSD simulator comparing the conventional
//! asynchronous NAND interface (CONV), the synchronous SDR interface of
//! Son et al. \[23\] (SYNC_ONLY) and the paper's proposed synchronous DDR
//! interface (PROPOSED), across way-interleaving degrees, channel
//! configurations, SLC/MLC devices, bandwidth and energy — plus an
//! AOT-compiled JAX/Pallas analytic model executed from Rust via PJRT for
//! fast design-space exploration.
//!
//! See `DESIGN.md` for the architecture and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analytic;
pub mod bench;
pub mod cli;
// Clippy wall aligned with simlint rule R3 (see `xtask` and DESIGN.md §14):
// config-load paths must return errors, never panic. Test code is exempt
// via clippy.toml (`allow-unwrap-in-tests` / `allow-expect-in-tests`).
#[warn(clippy::unwrap_used, clippy::expect_used)]
pub mod config;
pub mod controller;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod host;
pub mod iface;
pub mod nand;
pub mod observe;
pub mod proptest;
pub mod report;
pub mod runtime;
// Clippy wall aligned with simlint rule R2: simulation time is exact
// integer picoseconds, so the DES core must not do float arithmetic
// (randomized test generators opt out locally with an `#[allow]`).
#[warn(clippy::float_arithmetic)]
pub mod sim;
pub mod util;
