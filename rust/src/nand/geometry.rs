//! Physical geometry and addressing of the flash array.

/// Physical page address within one SSD: (channel, way, block, page).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageAddr {
    pub channel: u16,
    pub way: u16,
    pub block: u32,
    pub page: u32,
}

/// Array geometry of the whole SSD.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    pub channels: u16,
    pub ways: u16,
    pub blocks_per_chip: u32,
    pub pages_per_block: u32,
    pub page_bytes: u32,
}

impl Geometry {
    pub fn chips(&self) -> u32 {
        self.channels as u32 * self.ways as u32
    }

    pub fn pages_per_chip(&self) -> u64 {
        self.blocks_per_chip as u64 * self.pages_per_block as u64
    }

    pub fn total_pages(&self) -> u64 {
        self.pages_per_chip() * self.chips() as u64
    }

    pub fn capacity_bytes(&self) -> u64 {
        self.total_pages() * self.page_bytes as u64
    }

    /// Map a linear physical page number to a `PageAddr`.
    ///
    /// Layout stripes consecutive pages **across channels first, then ways**
    /// (channel striping then way interleaving, matching Fig. 2: sequential
    /// data fans out over all buses before re-using one).
    pub fn page_addr(&self, ppn: u64) -> PageAddr {
        debug_assert!(ppn < self.total_pages());
        let ch = (ppn % self.channels as u64) as u16;
        let rest = ppn / self.channels as u64;
        let way = (rest % self.ways as u64) as u16;
        let rest = rest / self.ways as u64;
        let page = (rest % self.pages_per_block as u64) as u32;
        let block = (rest / self.pages_per_block as u64) as u32;
        PageAddr {
            channel: ch,
            way,
            block,
            page,
        }
    }

    /// Inverse of [`Geometry::page_addr`].
    pub fn ppn(&self, a: PageAddr) -> u64 {
        let within_chip = a.block as u64 * self.pages_per_block as u64 + a.page as u64;
        (within_chip * self.ways as u64 + a.way as u64) * self.channels as u64 + a.channel as u64
    }

    /// Linear chip index of `(channel, way)` in FTL order. Sequential
    /// ppns stripe across channels first (see [`Geometry::page_addr`]),
    /// so chip `k` sits at channel `k % channels`, way `k / channels` —
    /// the single definition every layer (FTL allocators, the
    /// coordinator's tier/wear-leveling lookups) must share.
    pub fn chip_of(&self, channel: u16, way: u16) -> usize {
        way as usize * self.channels as usize + channel as usize
    }

    /// Inverse of [`Geometry::chip_of`]: the `(channel, way)` of a linear
    /// chip index.
    pub fn chip_addr(&self, chip: usize) -> (u16, u16) {
        debug_assert!(chip < self.chips() as usize);
        (
            (chip % self.channels as usize) as u16,
            (chip / self.channels as usize) as u16,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g() -> Geometry {
        Geometry {
            channels: 4,
            ways: 4,
            blocks_per_chip: 128,
            pages_per_block: 64,
            page_bytes: 2048,
        }
    }

    #[test]
    fn totals() {
        let g = g();
        assert_eq!(g.chips(), 16);
        assert_eq!(g.pages_per_chip(), 8192);
        assert_eq!(g.total_pages(), 131072);
        assert_eq!(g.capacity_bytes(), 131072 * 2048);
    }

    #[test]
    fn addr_roundtrip() {
        let g = g();
        for ppn in [0u64, 1, 4, 16, 17, 1000, 131071] {
            assert_eq!(g.ppn(g.page_addr(ppn)), ppn, "ppn={ppn}");
        }
    }

    #[test]
    fn sequential_pages_stripe_channels_first() {
        let g = g();
        // ppn 0..4 should land on channels 0..3 (striping before interleaving)
        for ppn in 0..4u64 {
            assert_eq!(g.page_addr(ppn).channel, ppn as u16);
            assert_eq!(g.page_addr(ppn).way, 0);
        }
        // next four move to way 1
        for ppn in 4..8u64 {
            assert_eq!(g.page_addr(ppn).channel, (ppn % 4) as u16);
            assert_eq!(g.page_addr(ppn).way, 1);
        }
    }

    /// chip_of/chip_addr round-trip and agree with page_addr's layout:
    /// every page of a chip decomposes to that chip's (channel, way).
    #[test]
    fn chip_linearization_roundtrip_and_layout() {
        let g = g();
        for chip in 0..g.chips() as usize {
            let (ch, way) = g.chip_addr(chip);
            assert_eq!(g.chip_of(ch, way), chip);
        }
        for ppn in [0u64, 1, 5, 63, 1000, 131071] {
            let a = g.page_addr(ppn);
            let chip = g.chip_of(a.channel, a.way);
            assert_eq!(g.chip_addr(chip), (a.channel, a.way), "ppn={ppn}");
        }
    }

    #[test]
    fn exhaustive_roundtrip_small() {
        let g = Geometry {
            channels: 2,
            ways: 3,
            blocks_per_chip: 4,
            pages_per_block: 8,
            page_bytes: 2048,
        };
        for ppn in 0..g.total_pages() {
            assert_eq!(g.ppn(g.page_addr(ppn)), ppn);
        }
    }
}
