//! Behavioral model of a single NAND flash chip.
//!
//! A chip is a state machine over {Ready, Busy}: array operations (read
//! fetch t_R, program t_PROG, erase t_BERS) make the chip busy; IO-latch
//! transfers are modelled by the bus (see [`crate::iface`]) and do not busy
//! the array. This matches §2.1/§3: during t_PROG the chip "enters the busy
//! state and cannot be interrupted".
//!
//! The chip also tracks per-block wear (program/erase cycles) so the FTL's
//! wear-leveling has real state to act on.

use crate::nand::datasheet::NandTiming;
use crate::util::time::Ps;

/// Array operations that busy the chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipOp {
    /// Fetch one page from the cell array into the page register (t_R).
    ReadFetch { block: u32, page: u32 },
    /// Program the page register into the cell array (t_PROG).
    Program { block: u32, page: u32 },
    /// Erase a whole block (t_BERS).
    Erase { block: u32 },
}

/// Chip readiness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChipState {
    Ready,
    /// Busy until the embedded completion time.
    Busy(Ps),
}

/// One NAND die with its timing, busy state and wear counters.
#[derive(Debug, Clone)]
pub struct Chip {
    pub timing: NandTiming,
    state: ChipState,
    /// Program/erase cycle count per block (wear).
    pe_cycles: Vec<u32>,
    /// Per-block count of programmed pages (for write-order invariants).
    programmed_pages: Vec<u32>,
    /// Statistics.
    pub reads: u64,
    pub programs: u64,
    pub erases: u64,
}

impl Chip {
    pub fn new(timing: NandTiming, blocks: u32) -> Chip {
        Chip {
            timing,
            state: ChipState::Ready,
            pe_cycles: vec![0; blocks as usize],
            programmed_pages: vec![0; blocks as usize],
            reads: 0,
            programs: 0,
            erases: 0,
        }
    }

    pub fn state(&self) -> ChipState {
        self.state
    }

    /// Return the chip to factory state (ready, zero wear, zero counters)
    /// without reallocating the per-block tables; `timing` may change when
    /// a sweep worker is retargeted at a different cell type.
    pub fn reset(&mut self, timing: NandTiming) {
        self.timing = timing;
        self.state = ChipState::Ready;
        self.pe_cycles.fill(0);
        self.programmed_pages.fill(0);
        self.reads = 0;
        self.programs = 0;
        self.erases = 0;
    }

    /// True if the array is ready at time `now` (lazily clears Busy).
    pub fn is_ready(&mut self, now: Ps) -> bool {
        if let ChipState::Busy(until) = self.state {
            if now >= until {
                self.state = ChipState::Ready;
            }
        }
        self.state == ChipState::Ready
    }

    /// Time at which the chip becomes ready (now if already ready).
    pub fn ready_at(&self, now: Ps) -> Ps {
        match self.state {
            ChipState::Ready => now,
            ChipState::Busy(until) => until.max(now),
        }
    }

    /// Start an array operation at `now`; returns its duration.
    ///
    /// Panics if the chip is busy — the controller must check readiness
    /// first (the paper's controller polls the status register).
    pub fn start(&mut self, now: Ps, op: ChipOp) -> Ps {
        assert!(
            self.is_ready(now),
            "chip busy at {now:?}; controller must serialize array ops"
        );
        let dur = match op {
            ChipOp::ReadFetch { block, .. } => {
                assert!((block as usize) < self.pe_cycles.len(), "block out of range");
                self.reads += 1;
                self.timing.t_r
            }
            ChipOp::Program { block, page } => {
                let b = block as usize;
                assert!(b < self.pe_cycles.len(), "block out of range");
                assert!(
                    page < self.timing.pages_per_block,
                    "page out of range within block"
                );
                self.programs += 1;
                self.programmed_pages[b] += 1;
                self.timing.t_prog
            }
            ChipOp::Erase { block } => {
                let b = block as usize;
                assert!(b < self.pe_cycles.len(), "block out of range");
                self.erases += 1;
                self.pe_cycles[b] += 1;
                self.programmed_pages[b] = 0;
                self.timing.t_bers
            }
        };
        self.state = ChipState::Busy(now + dur);
        dur
    }

    /// Program/erase cycles of a block (wear).
    pub fn wear(&self, block: u32) -> u32 {
        self.pe_cycles[block as usize]
    }

    /// Pages currently programmed in a block.
    pub fn programmed(&self, block: u32) -> u32 {
        self.programmed_pages[block as usize]
    }

    pub fn blocks(&self) -> u32 {
        self.pe_cycles.len() as u32
    }

    /// Maximum wear across all blocks.
    pub fn max_wear(&self) -> u32 {
        self.pe_cycles.iter().copied().max().unwrap_or(0)
    }

    /// Wear spread: max - min P/E cycles (wear leveling aims to keep small).
    /// Single pass: the steady-state coordinator consults this after every
    /// completed erase, so it sits on the sustained-write hot path.
    pub fn wear_spread(&self) -> u32 {
        let mut min = u32::MAX;
        let mut max = 0u32;
        for &w in &self.pe_cycles {
            min = min.min(w);
            max = max.max(w);
        }
        if min == u32::MAX {
            0
        } else {
            max - min
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nand::datasheet::NandTiming;

    fn chip() -> Chip {
        Chip::new(NandTiming::slc(), 16)
    }

    #[test]
    fn read_busies_for_t_r() {
        let mut c = chip();
        let d = c.start(Ps::ZERO, ChipOp::ReadFetch { block: 0, page: 0 });
        assert_eq!(d, Ps::us(25));
        assert!(!c.is_ready(Ps::us(24)));
        assert!(c.is_ready(Ps::us(25)));
        assert_eq!(c.reads, 1);
    }

    #[test]
    fn program_busies_for_t_prog() {
        let mut c = chip();
        let d = c.start(Ps::ZERO, ChipOp::Program { block: 1, page: 0 });
        assert_eq!(d, Ps::us(215));
        assert_eq!(c.ready_at(Ps::ZERO), Ps::us(215));
        assert_eq!(c.programmed(1), 1);
    }

    #[test]
    fn erase_resets_block_and_increments_wear() {
        let mut c = chip();
        c.start(Ps::ZERO, ChipOp::Program { block: 2, page: 0 });
        let t = c.ready_at(Ps::ZERO);
        c.start(t, ChipOp::Erase { block: 2 });
        assert_eq!(c.wear(2), 1);
        assert_eq!(c.programmed(2), 0);
        assert_eq!(c.erases, 1);
    }

    #[test]
    #[should_panic(expected = "chip busy")]
    fn cannot_start_while_busy() {
        let mut c = chip();
        c.start(Ps::ZERO, ChipOp::ReadFetch { block: 0, page: 0 });
        c.start(Ps::us(1), ChipOp::ReadFetch { block: 0, page: 1 });
    }

    #[test]
    fn back_to_back_after_ready() {
        let mut c = chip();
        c.start(Ps::ZERO, ChipOp::ReadFetch { block: 0, page: 0 });
        let t = c.ready_at(Ps::ZERO);
        c.start(t, ChipOp::ReadFetch { block: 0, page: 1 });
        assert_eq!(c.reads, 2);
    }

    #[test]
    fn wear_spread_tracks() {
        let mut c = chip();
        let mut t = Ps::ZERO;
        for _ in 0..5 {
            c.start(t, ChipOp::Erase { block: 0 });
            t = c.ready_at(t);
        }
        assert_eq!(c.wear_spread(), 5);
        assert_eq!(c.max_wear(), 5);
    }
}
