//! Datasheet timing and geometry constants for the simulated NAND devices.

use crate::util::time::Ps;

/// NAND flash cell type (bits per cell).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellType {
    /// Single-level cell: 1 bit/cell; fast program, small pages.
    Slc,
    /// Multi-level cell: 2 bits/cell; ~3–4× slower program (§1 of the paper).
    Mlc,
}

impl CellType {
    pub fn name(self) -> &'static str {
        match self {
            CellType::Slc => "SLC",
            CellType::Mlc => "MLC",
        }
    }
}

impl std::fmt::Display for CellType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Device-level timing parameters of one NAND chip (Table 1, chip side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NandTiming {
    /// Cell array → page register fetch time (read busy).
    pub t_r: Ps,
    /// Page register → cell array program time (program busy).
    pub t_prog: Ps,
    /// Block erase busy time.
    pub t_bers: Ps,
    /// Page register ↔ IO latch per-byte transfer time; the device-level
    /// floor on the interface clock period (Eqs. 6, 8, 9). 12 ns from the
    /// MuxOneNAND datasheet [28].
    pub t_byte: Ps,
    /// Main data bytes per page.
    pub page_bytes: u32,
    /// Spare (OOB/ECC) bytes per page, transferred along with the page.
    pub spare_bytes: u32,
    /// Pages per erase block.
    pub pages_per_block: u32,
}

impl NandTiming {
    /// SLC per K9F1G08U0B class devices, calibrated to Table 3's 1-way rows.
    pub fn slc() -> NandTiming {
        NandTiming {
            t_r: Ps::us(25),
            t_prog: Ps::us(215),
            t_bers: Ps::ms(2),
            t_byte: Ps::ns(12),
            page_bytes: 2048,
            spare_bytes: 64,
            pages_per_block: 64,
        }
    }

    /// MLC per K9GAG08U0M class devices, calibrated to Table 3's 1-way rows.
    pub fn mlc() -> NandTiming {
        NandTiming {
            t_r: Ps::us(60),
            t_prog: Ps::us(830),
            t_bers: Ps::us(2500),
            t_byte: Ps::ns(12),
            page_bytes: 4096,
            spare_bytes: 128,
            pages_per_block: 128,
        }
    }

    pub fn for_cell(cell: CellType) -> NandTiming {
        match cell {
            CellType::Slc => NandTiming::slc(),
            CellType::Mlc => NandTiming::mlc(),
        }
    }

    /// Total bytes clocked over the bus per page (main + spare).
    pub fn transfer_bytes(&self) -> u32 {
        self.page_bytes + self.spare_bytes
    }

    /// SLC-mode timing on *this* device's geometry: the SLC datasheet's
    /// array latencies (t_R / t_PROG / t_BERS) with the host device's page
    /// and block shape unchanged. This is the per-tier timing of the
    /// tiered-flash subsystem — an MLC-capable chip driven with fast
    /// single-level programming (SLC-mode write buffering, as in
    /// SLC/MLC combined-flash SSDs). Keeping the geometry uniform is what
    /// lets one [`crate::nand::geometry::Geometry`] address both tiers.
    pub fn slc_mode(self) -> NandTiming {
        let slc = NandTiming::slc();
        NandTiming {
            t_r: slc.t_r,
            t_prog: slc.t_prog,
            t_bers: slc.t_bers,
            ..self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_parameters() {
        let t = NandTiming::slc();
        assert_eq!(t.page_bytes, 2048);
        assert_eq!(t.spare_bytes, 64);
        assert_eq!(t.transfer_bytes(), 2112);
        assert_eq!(t.t_byte, Ps::ns(12));
        assert!(t.t_prog > t.t_r, "t_PROG must dominate t_R (paper §2.1)");
    }

    #[test]
    fn mlc_slower_than_slc() {
        let s = NandTiming::slc();
        let m = NandTiming::mlc();
        // §1: MLC program time approximately 3x+ larger than SLC.
        assert!(m.t_prog.as_ps() >= 3 * s.t_prog.as_ps());
        assert!(m.t_r > s.t_r);
        assert_eq!(m.page_bytes, 4096);
    }

    #[test]
    fn for_cell_dispatch() {
        assert_eq!(NandTiming::for_cell(CellType::Slc), NandTiming::slc());
        assert_eq!(NandTiming::for_cell(CellType::Mlc), NandTiming::mlc());
    }

    /// SLC-mode keeps the host geometry (addressing stays uniform across
    /// tiers) while taking the SLC array latencies.
    #[test]
    fn slc_mode_swaps_latency_not_geometry() {
        let m = NandTiming::mlc().slc_mode();
        let s = NandTiming::slc();
        assert_eq!(m.t_prog, s.t_prog);
        assert_eq!(m.t_r, s.t_r);
        assert_eq!(m.t_bers, s.t_bers);
        assert_eq!(m.page_bytes, NandTiming::mlc().page_bytes);
        assert_eq!(m.pages_per_block, NandTiming::mlc().pages_per_block);
        assert_eq!(m.spare_bytes, NandTiming::mlc().spare_bytes);
        // SLC-mode on an SLC device is the identity.
        assert_eq!(NandTiming::slc().slc_mode(), NandTiming::slc());
    }
}
