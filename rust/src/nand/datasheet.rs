//! Datasheet timing and geometry constants for the simulated NAND devices.

use crate::util::time::Ps;

/// NAND flash cell type (bits per cell).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellType {
    /// Single-level cell: 1 bit/cell; fast program, small pages.
    Slc,
    /// Multi-level cell: 2 bits/cell; ~3–4× slower program (§1 of the paper).
    Mlc,
}

impl CellType {
    pub fn name(self) -> &'static str {
        match self {
            CellType::Slc => "SLC",
            CellType::Mlc => "MLC",
        }
    }
}

impl std::fmt::Display for CellType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Device-level timing parameters of one NAND chip (Table 1, chip side).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NandTiming {
    /// Cell array → page register fetch time (read busy).
    pub t_r: Ps,
    /// Page register → cell array program time (program busy).
    pub t_prog: Ps,
    /// Block erase busy time.
    pub t_bers: Ps,
    /// Page register ↔ IO latch per-byte transfer time; the device-level
    /// floor on the interface clock period (Eqs. 6, 8, 9). 12 ns from the
    /// MuxOneNAND datasheet [28].
    pub t_byte: Ps,
    /// Main data bytes per page.
    pub page_bytes: u32,
    /// Spare (OOB/ECC) bytes per page, transferred along with the page.
    pub spare_bytes: u32,
    /// Pages per erase block.
    pub pages_per_block: u32,
}

impl NandTiming {
    /// SLC per K9F1G08U0B class devices, calibrated to Table 3's 1-way rows.
    pub fn slc() -> NandTiming {
        NandTiming {
            t_r: Ps::us(25),
            t_prog: Ps::us(215),
            t_bers: Ps::ms(2),
            t_byte: Ps::ns(12),
            page_bytes: 2048,
            spare_bytes: 64,
            pages_per_block: 64,
        }
    }

    /// MLC per K9GAG08U0M class devices, calibrated to Table 3's 1-way rows.
    pub fn mlc() -> NandTiming {
        NandTiming {
            t_r: Ps::us(60),
            t_prog: Ps::us(830),
            t_bers: Ps::us(2500),
            t_byte: Ps::ns(12),
            page_bytes: 4096,
            spare_bytes: 128,
            pages_per_block: 128,
        }
    }

    pub fn for_cell(cell: CellType) -> NandTiming {
        match cell {
            CellType::Slc => NandTiming::slc(),
            CellType::Mlc => NandTiming::mlc(),
        }
    }

    /// Total bytes clocked over the bus per page (main + spare).
    pub fn transfer_bytes(&self) -> u32 {
        self.page_bytes + self.spare_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slc_parameters() {
        let t = NandTiming::slc();
        assert_eq!(t.page_bytes, 2048);
        assert_eq!(t.spare_bytes, 64);
        assert_eq!(t.transfer_bytes(), 2112);
        assert_eq!(t.t_byte, Ps::ns(12));
        assert!(t.t_prog > t.t_r, "t_PROG must dominate t_R (paper §2.1)");
    }

    #[test]
    fn mlc_slower_than_slc() {
        let s = NandTiming::slc();
        let m = NandTiming::mlc();
        // §1: MLC program time approximately 3x+ larger than SLC.
        assert!(m.t_prog.as_ps() >= 3 * s.t_prog.as_ps());
        assert!(m.t_r > s.t_r);
        assert_eq!(m.page_bytes, 4096);
    }

    #[test]
    fn for_cell_dispatch() {
        assert_eq!(NandTiming::for_cell(CellType::Slc), NandTiming::slc());
        assert_eq!(NandTiming::for_cell(CellType::Mlc), NandTiming::mlc());
    }
}
