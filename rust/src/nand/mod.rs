//! NAND flash memory behavioral model.
//!
//! Mirrors the chip structure of Fig. 1: a cell array, a page register, and
//! IO latches, with the datasheet timing parameters the paper simulates
//! (t_R, t_PROG, t_BYTE, page geometry). The chips named by the paper:
//!
//! * SLC — Samsung **K9F1G08U0B** (1 Gbit, 2 KiB + 64 B pages) [26]
//! * MLC — Samsung **K9GAG08U0M** (16 Gbit, 4 KiB + 128 B pages) [27]
//! * t_BYTE — Samsung **FK8G16Q2M MuxOneNAND** (12 ns) [28]
//!
//! The exact t_R/t_PROG values are calibrated so the 1-way rows of Table 3
//! match (see DESIGN.md §Calibration anchors and `datasheet` below).

pub mod chip;
pub mod datasheet;
pub mod geometry;

pub use chip::{Chip, ChipOp, ChipState};
pub use datasheet::{CellType, NandTiming};
pub use geometry::{Geometry, PageAddr};
