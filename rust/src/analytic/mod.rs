//! Closed-form analytic SSD performance model.
//!
//! Mirrors the steady state of the DES: per-page bus occupancy plus
//! Amdahl-style way-interleaving saturation (§5.3.1's analysis). The same
//! formulas are implemented as the Pallas kernels in
//! `python/compile/kernels/{timing,bandwidth,energy}.py`; integration tests
//! load the AOT artifact and assert this module and the HLO agree bit-for-
//! bit (f32-for-f32), and `tests/analytic_vs_hlo.rs` asserts the DES agrees
//! within tolerance.
//!
//! The DES remains ground truth: it additionally models queue depth, SATA
//! serialization, status polling and FTL effects. The analytic model is the
//! fast surrogate used for design-space exploration.

use crate::config::SsdConfig;
use crate::energy::PowerModel;
use crate::host::trace::RequestKind;
use crate::iface::timing::IfaceParams;

/// Plain-f64 design point, decoupled from the simulator types so the exact
/// same numbers can be fed to the AOT-compiled kernel.
#[derive(Debug, Clone, Copy)]
pub struct DesignPoint {
    /// Per-byte data time on the bus (ns).
    pub data_byte_ns: f64,
    /// Command+address+controller-overhead phase (ns).
    pub cmd_ns: f64,
    /// ECC page latency (ns).
    pub ecc_ns: f64,
    /// Status phase for programs (ns).
    pub status_ns: f64,
    /// Array read fetch t_R (ns).
    pub t_r_ns: f64,
    /// Array program t_PROG (ns).
    pub t_prog_ns: f64,
    /// Main page bytes.
    pub page_bytes: f64,
    /// Page + spare bytes (what the bus actually moves).
    pub transfer_bytes: f64,
    /// Way-interleaving degree.
    pub ways: f64,
    /// Channel count.
    pub channels: f64,
    /// Host-link cap (MB/s).
    pub sata_mbps: f64,
    /// Controller power (mW) for the energy metric.
    pub controller_mw: f64,
}

impl DesignPoint {
    /// Build the design point corresponding to an [`SsdConfig`], using the
    /// same derived constants as the DES.
    pub fn from_config(cfg: &SsdConfig) -> DesignPoint {
        let nand = cfg.nand_timing();
        let bus = crate::iface::bus::BusTiming::from_params(&cfg.params, cfg.iface);
        let ecc = crate::controller::ecc::EccModel::for_cell(cfg.cell);
        DesignPoint {
            data_byte_ns: bus.t_data_byte.as_ns_f64(),
            cmd_ns: bus.read_cmd().as_ns_f64(),
            ecc_ns: ecc.page_latency(nand.page_bytes).as_ns_f64(),
            status_ns: (bus.status_poll() + cfg.program_status_overhead).as_ns_f64(),
            t_r_ns: nand.t_r.as_ns_f64(),
            t_prog_ns: nand.t_prog.as_ns_f64(),
            page_bytes: nand.page_bytes as f64,
            transfer_bytes: nand.transfer_bytes() as f64,
            ways: cfg.ways as f64,
            channels: cfg.channels as f64,
            sata_mbps: cfg.sata.bandwidth_mbps,
            controller_mw: PowerModel::for_interface(cfg.iface).controller_mw,
        }
    }
}


/// Steady-state read bandwidth in MB/s.
///
/// Per-page bus occupancy `O = cmd + transfer + ecc`; per-way cycle
/// `O + t_R`. With `w` ways multiplexing the bus, the page period is
/// `max(O, (O + t_R)/w)` (bus-saturated vs. interleave-limited), scaled by
/// channels and capped by the host link.
pub fn read_bandwidth_mbps(p: &DesignPoint) -> f64 {
    let o = p.cmd_ns + p.transfer_bytes * p.data_byte_ns + p.ecc_ns;
    let cycle = o + p.t_r_ns;
    let period = o.max(cycle / p.ways);
    let per_channel = p.page_bytes / period * 1e3; // bytes/ns -> MB/s
    (per_channel * p.channels).min(p.sata_mbps)
}

/// Steady-state write bandwidth in MB/s. Same shape with `t_PROG` and the
/// post-program status phase.
pub fn write_bandwidth_mbps(p: &DesignPoint) -> f64 {
    let o = p.cmd_ns + p.transfer_bytes * p.data_byte_ns + p.ecc_ns + p.status_ns;
    let cycle = o + p.t_prog_ns;
    let period = o.max(cycle / p.ways);
    let per_channel = p.page_bytes / period * 1e3;
    (per_channel * p.channels).min(p.sata_mbps)
}

/// Bandwidth for either mode.
pub fn bandwidth_mbps(p: &DesignPoint, mode: RequestKind) -> f64 {
    match mode {
        RequestKind::Read => read_bandwidth_mbps(p),
        RequestKind::Write => write_bandwidth_mbps(p),
    }
}

/// Controller energy per byte (nJ/B) — the Table 5 metric.
pub fn energy_nj_per_byte(p: &DesignPoint, mode: RequestKind) -> f64 {
    p.controller_mw / bandwidth_mbps(p, mode)
}

/// Convenience: evaluate a full config.
pub fn evaluate(cfg: &SsdConfig, mode: RequestKind) -> (f64, f64) {
    let p = DesignPoint::from_config(cfg);
    (bandwidth_mbps(&p, mode), energy_nj_per_byte(&p, mode))
}

/// Minimum clock periods of all three interfaces (ns) — Eqs. (6), (8)/(9);
/// re-exported here so the analytic module is self-contained for the DSE.
pub fn tp_min_ns(params: &IfaceParams) -> [f64; 3] {
    [
        params.conv_tp_min_ns(),
        params.sync_only_tp_min_ns(),
        params.proposed_tp_min_board_ns(),
    ]
}

/// Paper Table 3 (SLC/MLC × write/read × way degree × interface), used by
/// calibration tests and the benchmark harness for paper-vs-measured
/// deltas. Values in MB/s.
pub mod paper {
    use crate::iface::timing::InterfaceKind;
    use crate::nand::datasheet::CellType;
    use crate::host::trace::RequestKind;

    pub const WAYS: [u16; 5] = [1, 2, 4, 8, 16];

    /// (cell, mode, [way-row][CONV, SYNC_ONLY, PROPOSED])
    pub const TABLE3: [(CellType, RequestKind, [[f64; 3]; 5]); 4] = [
        (
            CellType::Slc,
            RequestKind::Write,
            [
                [7.77, 8.38, 8.50],
                [15.22, 16.59, 17.52],
                [28.94, 31.90, 34.30],
                [39.78, 55.36, 63.00],
                [39.76, 60.44, 97.35],
            ],
        ),
        (
            CellType::Slc,
            RequestKind::Read,
            [
                [27.78, 36.66, 47.89],
                [42.78, 67.16, 70.47],
                [42.75, 67.13, 117.68],
                [42.72, 67.11, 117.64],
                [42.69, 67.11, 117.59],
            ],
        ),
        (
            CellType::Mlc,
            RequestKind::Write,
            [
                [4.43, 4.55, 4.65],
                [8.36, 8.85, 9.24],
                [15.24, 16.75, 18.13],
                [25.86, 29.72, 34.08],
                [32.45, 45.99, 57.23],
            ],
        ),
        (
            CellType::Mlc,
            RequestKind::Read,
            [
                [26.04, 33.58, 42.69],
                [41.59, 60.41, 77.19],
                [41.55, 64.76, 101.61],
                [41.52, 64.75, 110.56],
                [41.50, 64.73, 110.52],
            ],
        ),
    ];

    /// Table 4: constant-capacity channel/way sweep. Rows: (1,16), (2,8),
    /// (4,4); `None` = "max" (SATA-saturated).
    pub const CHANNEL_CONFIGS: [(u16, u16); 3] = [(1, 16), (2, 8), (4, 4)];
    pub const TABLE4: [(CellType, RequestKind, [[Option<f64>; 3]; 3]); 4] = [
        (
            CellType::Slc,
            RequestKind::Write,
            [
                [Some(39.76), Some(60.44), Some(97.35)],
                [Some(74.07), Some(101.99), Some(114.83)],
                [Some(103.76), Some(115.68), Some(123.52)],
            ],
        ),
        (
            CellType::Slc,
            RequestKind::Read,
            [
                [Some(42.69), Some(67.11), Some(117.59)],
                [Some(81.44), Some(126.70), Some(224.82)],
                [Some(155.35), Some(237.61), None],
            ],
        ),
        (
            CellType::Mlc,
            RequestKind::Write,
            [
                [Some(32.45), Some(45.99), Some(57.23)],
                [Some(48.72), Some(56.83), Some(64.75)],
                [Some(57.46), Some(63.55), Some(68.49)],
            ],
        ),
        (
            CellType::Mlc,
            RequestKind::Read,
            [
                [Some(41.50), Some(64.73), Some(110.52)],
                [Some(79.32), Some(122.48), Some(201.42)],
                [Some(150.94), Some(230.17), None],
            ],
        ),
    ];

    /// Table 5: SLC energy (nJ/B). Rows are way degrees 1..16.
    pub const TABLE5: [(RequestKind, [[f64; 3]; 5]); 2] = [
        (
            RequestKind::Write,
            [
                [2.90, 5.01, 5.47],
                [1.48, 2.53, 2.65],
                [0.78, 1.32, 1.36],
                [0.57, 0.76, 0.74],
                [0.57, 0.69, 0.48],
            ],
        ),
        (
            RequestKind::Read,
            [
                [0.81, 1.15, 0.97],
                [0.53, 0.63, 0.66],
                [0.53, 0.63, 0.40],
                [0.53, 0.63, 0.40],
                [0.53, 0.63, 0.40],
            ],
        ),
    ];

    pub fn iface_index(kind: InterfaceKind) -> usize {
        match kind {
            InterfaceKind::Conv => 0,
            InterfaceKind::SyncOnly => 1,
            InterfaceKind::Proposed => 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SsdConfig;
    use crate::iface::timing::InterfaceKind;
    use crate::nand::datasheet::CellType;

    fn cfg(iface: InterfaceKind, cell: CellType, ways: u16) -> SsdConfig {
        SsdConfig {
            iface,
            cell,
            ways,
            ..SsdConfig::default()
        }
    }

    /// The analytic model should reproduce the paper's Table 3 1-way
    /// anchors closely (these calibrate t_R/t_PROG/ECC).
    #[test]
    fn slc_one_way_anchors() {
        let read = |i| evaluate(&cfg(i, CellType::Slc, 1), RequestKind::Read).0;
        let write = |i| evaluate(&cfg(i, CellType::Slc, 1), RequestKind::Write).0;
        assert!((read(InterfaceKind::Conv) - 27.78).abs() < 1.0, "{}", read(InterfaceKind::Conv));
        assert!((read(InterfaceKind::SyncOnly) - 36.66).abs() < 1.2);
        assert!((read(InterfaceKind::Proposed) - 47.89).abs() < 1.5);
        assert!((write(InterfaceKind::Conv) - 7.77).abs() < 0.3);
        assert!((write(InterfaceKind::SyncOnly) - 8.38).abs() < 0.3);
        assert!((write(InterfaceKind::Proposed) - 8.50).abs() < 0.4);
    }

    #[test]
    fn mlc_one_way_anchors() {
        let read = |i| evaluate(&cfg(i, CellType::Mlc, 1), RequestKind::Read).0;
        let write = |i| evaluate(&cfg(i, CellType::Mlc, 1), RequestKind::Write).0;
        assert!((read(InterfaceKind::Conv) - 26.04).abs() < 1.0, "{}", read(InterfaceKind::Conv));
        assert!((write(InterfaceKind::Conv) - 4.43).abs() < 0.2, "{}", write(InterfaceKind::Conv));
        assert!((read(InterfaceKind::Proposed) - 42.69).abs() < 1.6);
        assert!((write(InterfaceKind::Proposed) - 4.65).abs() < 0.25);
    }

    #[test]
    fn saturation_degrees_match_paper() {
        // §5.3.1 Case II: CONV read saturates by 2-way, PROPOSED by 4-way.
        let bw = |i, w| evaluate(&cfg(i, CellType::Slc, w), RequestKind::Read).0;
        let conv2 = bw(InterfaceKind::Conv, 2);
        let conv16 = bw(InterfaceKind::Conv, 16);
        assert!((conv2 - conv16).abs() / conv16 < 0.02, "CONV saturated by 2-way");
        let prop4 = bw(InterfaceKind::Proposed, 4);
        let prop16 = bw(InterfaceKind::Proposed, 16);
        assert!((prop4 - prop16).abs() / prop16 < 0.02, "PROPOSED saturated by 4-way");
        let prop2 = bw(InterfaceKind::Proposed, 2);
        assert!(prop2 < 0.9 * prop4, "PROPOSED not yet saturated at 2-way");
    }

    #[test]
    fn headline_ratios_hold() {
        // §6: PROPOSED/CONV read 1.65–2.76x, write 1.09–2.45x (SLC).
        for &w in &paper::WAYS {
            let r = evaluate(&cfg(InterfaceKind::Proposed, CellType::Slc, w), RequestKind::Read).0
                / evaluate(&cfg(InterfaceKind::Conv, CellType::Slc, w), RequestKind::Read).0;
            assert!((1.5..3.1).contains(&r), "read ratio {r} at {w}-way");
            let wr = evaluate(&cfg(InterfaceKind::Proposed, CellType::Slc, w), RequestKind::Write).0
                / evaluate(&cfg(InterfaceKind::Conv, CellType::Slc, w), RequestKind::Write).0;
            assert!((1.0..2.8).contains(&wr), "write ratio {wr} at {w}-way");
        }
    }

    #[test]
    fn sata_caps_four_channel_read() {
        // Table 4: (4ch, 4way) SLC read reaches the SATA bound ("max").
        let mut c = cfg(InterfaceKind::Proposed, CellType::Slc, 4);
        c.channels = 4;
        let (bw, _) = evaluate(&c, RequestKind::Read);
        assert_eq!(bw, 300.0);
    }

    #[test]
    fn energy_crossover_with_ways() {
        // Fig. 10: PROPOSED is costlier at 1-way, cheapest at 16-way.
        let e = |i, w| evaluate(&cfg(i, CellType::Slc, w), RequestKind::Write).1;
        assert!(e(InterfaceKind::Proposed, 1) > e(InterfaceKind::Conv, 1));
        assert!(e(InterfaceKind::Proposed, 16) < e(InterfaceKind::Conv, 16));
    }

    #[test]
    fn table3_full_grid_within_tolerance() {
        // Shape reproduction: every cell within 15% of the paper, except
        // the known sub-linear mid-curve cells (documented in
        // EXPERIMENTS.md): 2-way PROPOSED SLC read and the >=8-way MLC
        // write column, where the paper's simulator shows sub-linear
        // interleaving the steady-state model doesn't capture.
        let mut worst: (f64, String) = (0.0, String::new());
        for (cell, mode, rows) in paper::TABLE3 {
            for (wi, &w) in paper::WAYS.iter().enumerate() {
                for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                    let ours = evaluate(&cfg(*iface, cell, w), mode).0;
                    let ref_v = rows[wi][ii];
                    let err = (ours - ref_v).abs() / ref_v;
                    let known_outlier = (cell == CellType::Slc
                        && mode == RequestKind::Read
                        && w == 2
                        && *iface == InterfaceKind::Proposed)
                        || (cell == CellType::Mlc && mode == RequestKind::Write && w >= 8);
                    if !known_outlier {
                        assert!(
                            err < 0.16,
                            "{cell} {mode:?} {w}-way {iface}: ours={ours:.2} paper={ref_v:.2} err={err:.3}"
                        );
                    }
                    if err > worst.0 {
                        worst = (err, format!("{cell} {mode:?} {w}-way {iface}"));
                    }
                }
            }
        }
        eprintln!("worst analytic-vs-paper error: {:.1}% at {}", worst.0 * 100.0, worst.1);
    }
}
