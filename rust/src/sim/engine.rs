//! The simulation loop: pop earliest event, advance the clock, dispatch to
//! the model, repeat.

use crate::sim::queue::EventQueue;
use crate::util::time::Ps;

/// Scheduling handle passed to the model on every event.
///
/// Wraps the event calendar and the simulation clock; the model may only
/// schedule into the present or future (scheduling into the past panics —
/// it is always a model bug).
pub struct Scheduler<Ev> {
    now: Ps,
    queue: EventQueue<Ev>,
    stopped: bool,
}

impl<Ev> Scheduler<Ev> {
    pub fn new() -> Self {
        Scheduler {
            now: Ps::ZERO,
            queue: EventQueue::with_capacity(1024),
            stopped: false,
        }
    }

    /// Current simulated time.
    #[inline]
    pub fn now(&self) -> Ps {
        self.now
    }

    /// Schedule `ev` to fire `delay` after now.
    #[inline]
    pub fn after(&mut self, delay: Ps, ev: Ev) {
        debug_assert!(delay >= Ps::ZERO, "negative delay {delay:?}");
        self.queue.push(self.now + delay, ev);
    }

    /// Schedule `ev` at absolute time `at` (must not be in the past).
    #[inline]
    pub fn at(&mut self, at: Ps, ev: Ev) {
        assert!(at >= self.now, "scheduling into the past: {at:?} < {:?}", self.now);
        self.queue.push(at, ev);
    }

    /// Schedule `ev` to fire immediately (after already-queued events at
    /// the current timestamp).
    #[inline]
    pub fn now_ev(&mut self, ev: Ev) {
        self.queue.push(self.now, ev);
    }

    /// Request the engine to stop after the current event.
    pub fn stop(&mut self) {
        self.stopped = true;
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Rewind to a pristine state (t = 0, no pending events, not stopped)
    /// while keeping the calendar's allocations — used when one scheduler
    /// is reused across many simulation runs (sweep workers).
    pub fn reset(&mut self) {
        self.now = Ps::ZERO;
        self.queue.clear();
        self.stopped = false;
    }

    // Calendar-driving hooks for the alternative engines in
    // [`crate::sim::sharded`]. Crate-private: models must not self-drive.

    /// Earliest pending timestamp without popping.
    #[inline]
    pub(crate) fn peek_next_time(&mut self) -> Option<Ps> {
        self.queue.next_time()
    }

    /// Advance the clock (monotonically) without dispatching.
    #[inline]
    pub(crate) fn set_now(&mut self, t: Ps) {
        debug_assert!(t >= self.now, "time went backwards");
        self.now = t;
    }

    /// Pop the next event if it fires exactly at `t` (same contract as
    /// [`crate::sim::queue::EventQueue::pop_if_at`]).
    #[inline]
    pub(crate) fn pop_at(&mut self, t: Ps) -> Option<Ev> {
        self.queue.pop_if_at(t)
    }

    /// Whether the model requested a stop.
    #[inline]
    pub(crate) fn is_stopped(&self) -> bool {
        self.stopped
    }
}

impl<Ev> Default for Scheduler<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

/// A simulation model: reacts to events by mutating state and scheduling
/// follow-up events.
pub trait Model {
    type Ev;
    fn handle(&mut self, sched: &mut Scheduler<Self::Ev>, ev: Self::Ev);
}

/// Result of an engine run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunResult {
    /// Simulated time at which the run ended.
    pub end_time: Ps,
    /// Total events dispatched.
    pub events: u64,
    /// True if the run ended because the event calendar drained (vs. the
    /// horizon or an explicit stop).
    pub drained: bool,
}

/// The DES driver.
pub struct Engine;

impl Engine {
    /// Run `model` until the calendar drains, `horizon` is reached, or the
    /// model calls [`Scheduler::stop`].
    ///
    /// Events beyond the horizon stay queued, so a run can be resumed by
    /// calling `run` again with a later horizon. Events sharing a timestamp
    /// are drained as one batch without re-searching the calendar
    /// (`pop_if_at`), in exact FIFO order.
    pub fn run<M: Model>(
        model: &mut M,
        sched: &mut Scheduler<M::Ev>,
        horizon: Ps,
    ) -> RunResult {
        let mut events: u64 = 0;
        loop {
            if sched.stopped {
                return RunResult {
                    end_time: sched.now,
                    events,
                    drained: false,
                };
            }
            let Some(at) = sched.queue.next_time() else {
                return RunResult {
                    end_time: sched.now,
                    events,
                    drained: true,
                };
            };
            if at > horizon {
                // Keep the event queued: runs must be resumable past a
                // horizon (regression: it used to be popped and dropped).
                sched.now = horizon;
                return RunResult {
                    end_time: horizon,
                    events,
                    drained: false,
                };
            }
            debug_assert!(at >= sched.now, "time went backwards");
            sched.now = at;
            // Drain the whole same-timestamp batch; follow-ups scheduled at
            // `at` by the handlers join the batch in FIFO order.
            while let Some(ev) = sched.queue.pop_if_at(at) {
                events += 1;
                model.handle(sched, ev);
                if sched.stopped {
                    return RunResult {
                        end_time: sched.now,
                        events,
                        drained: false,
                    };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A model that counts down: each Tick(n) schedules Tick(n-1) 10ns later.
    struct Countdown {
        fired: Vec<(Ps, u32)>,
    }
    #[derive(Debug)]
    enum Ev {
        Tick(u32),
    }
    impl Model for Countdown {
        type Ev = Ev;
        fn handle(&mut self, sched: &mut Scheduler<Ev>, ev: Ev) {
            let Ev::Tick(n) = ev;
            self.fired.push((sched.now(), n));
            if n > 0 {
                sched.after(Ps::ns(10), Ev::Tick(n - 1));
            }
        }
    }

    #[test]
    fn runs_to_drain() {
        let mut m = Countdown { fired: vec![] };
        let mut s = Scheduler::new();
        s.at(Ps::ZERO, Ev::Tick(5));
        let r = Engine::run(&mut m, &mut s, Ps::ms(1));
        assert!(r.drained);
        assert_eq!(r.events, 6);
        assert_eq!(r.end_time, Ps::ns(50));
        assert_eq!(m.fired.last(), Some(&(Ps::ns(50), 0)));
    }

    #[test]
    fn horizon_cuts_off() {
        let mut m = Countdown { fired: vec![] };
        let mut s = Scheduler::new();
        s.at(Ps::ZERO, Ev::Tick(1000));
        let r = Engine::run(&mut m, &mut s, Ps::ns(35));
        assert!(!r.drained);
        assert_eq!(r.end_time, Ps::ns(35));
        // Ticks at 0,10,20,30 fired; 40 was past the horizon.
        assert_eq!(r.events, 4);
    }

    /// Regression: an event beyond the horizon must stay queued so the run
    /// can resume with a later horizon (it used to be silently dropped).
    #[test]
    fn beyond_horizon_event_stays_queued_and_resumes() {
        let mut m = Countdown { fired: vec![] };
        let mut s = Scheduler::new();
        s.at(Ps::ZERO, Ev::Tick(10));
        let r1 = Engine::run(&mut m, &mut s, Ps::ns(35));
        assert_eq!(r1.events, 4);
        assert_eq!(s.pending(), 1, "the tick at 40ns must remain queued");
        assert_eq!(s.now(), Ps::ns(35));
        // Resume: the remaining 7 ticks (at 40..100ns) fire.
        let r2 = Engine::run(&mut m, &mut s, Ps::ms(1));
        assert!(r2.drained);
        assert_eq!(r2.events, 7);
        assert_eq!(r2.end_time, Ps::ns(100));
        assert_eq!(m.fired.len(), 11);
        assert_eq!(m.fired.last(), Some(&(Ps::ns(100), 0)));
    }

    struct Stopper;
    impl Model for Stopper {
        type Ev = u32;
        fn handle(&mut self, sched: &mut Scheduler<u32>, ev: u32) {
            if ev == 3 {
                sched.stop();
            }
            sched.after(Ps::ns(1), ev + 1);
        }
    }

    #[test]
    fn explicit_stop() {
        let mut m = Stopper;
        let mut s = Scheduler::new();
        s.at(Ps::ZERO, 0u32);
        let r = Engine::run(&mut m, &mut s, Ps::ms(1));
        assert!(!r.drained);
        assert_eq!(r.events, 4); // 0,1,2,3
    }

    #[test]
    fn stop_mid_batch_keeps_rest_of_batch_queued() {
        struct StopAt2 {
            seen: Vec<u32>,
        }
        impl Model for StopAt2 {
            type Ev = u32;
            fn handle(&mut self, s: &mut Scheduler<u32>, ev: u32) {
                self.seen.push(ev);
                if ev == 2 {
                    s.stop();
                }
            }
        }
        let mut m = StopAt2 { seen: vec![] };
        let mut s = Scheduler::new();
        for i in 0..10 {
            s.at(Ps::ns(5), i);
        }
        let r = Engine::run(&mut m, &mut s, Ps::ms(1));
        assert_eq!(r.events, 3); // 0, 1, 2
        assert_eq!(m.seen, vec![0, 1, 2]);
        assert_eq!(s.pending(), 7, "unreached batch events stay queued");
    }

    #[test]
    fn same_time_fifo_dispatch() {
        struct Recorder {
            order: Vec<u32>,
        }
        impl Model for Recorder {
            type Ev = u32;
            fn handle(&mut self, _s: &mut Scheduler<u32>, ev: u32) {
                self.order.push(ev);
            }
        }
        let mut m = Recorder { order: vec![] };
        let mut s = Scheduler::new();
        for i in 0..50 {
            s.at(Ps::ns(7), i);
        }
        Engine::run(&mut m, &mut s, Ps::ms(1));
        assert_eq!(m.order, (0..50).collect::<Vec<_>>());
    }

    /// Follow-ups scheduled with `now_ev` during a batch join the same
    /// batch after the already-queued events (FIFO by sequence).
    #[test]
    fn now_ev_joins_current_batch_in_order() {
        struct Chain {
            order: Vec<u32>,
        }
        impl Model for Chain {
            type Ev = u32;
            fn handle(&mut self, s: &mut Scheduler<u32>, ev: u32) {
                self.order.push(ev);
                if ev < 3 {
                    s.now_ev(ev + 100);
                }
            }
        }
        let mut m = Chain { order: vec![] };
        let mut s = Scheduler::new();
        for i in 0..3 {
            s.at(Ps::ns(9), i);
        }
        let r = Engine::run(&mut m, &mut s, Ps::ms(1));
        assert_eq!(m.order, vec![0, 1, 2, 100, 101, 102]);
        assert_eq!(r.end_time, Ps::ns(9));
        assert!(r.drained);
    }

    #[test]
    fn scheduler_reset_reuses_allocations() {
        let mut m = Countdown { fired: vec![] };
        let mut s = Scheduler::new();
        s.at(Ps::ZERO, Ev::Tick(3));
        Engine::run(&mut m, &mut s, Ps::ns(15));
        assert!(s.pending() > 0);
        s.reset();
        assert_eq!(s.pending(), 0);
        assert_eq!(s.now(), Ps::ZERO);
        // A fresh run on the reused scheduler behaves like a new one.
        let mut m2 = Countdown { fired: vec![] };
        s.at(Ps::ZERO, Ev::Tick(5));
        let r = Engine::run(&mut m2, &mut s, Ps::ms(1));
        assert_eq!(r.events, 6);
        assert_eq!(r.end_time, Ps::ns(50));
    }
}
