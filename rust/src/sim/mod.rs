//! Discrete-event simulation (DES) engine.
//!
//! The SSD models in this crate are *behavioral*, like the Seamless models
//! used by the paper: each NAND command phase, bus transfer, program/read
//! latency and host transfer is a timed event. The engine is deliberately
//! minimal — a time-ordered event calendar plus a user model that reacts to
//! events by scheduling more events — and allocation-free on the hot path.
//!
//! # Design
//!
//! * Time is [`crate::util::time::Ps`] (integer picoseconds).
//! * Events of the same timestamp fire in FIFO order (a monotonically
//!   increasing sequence number breaks ties), which makes simulations
//!   deterministic and independent of calendar internals.
//! * The model is a state machine implementing [`Model`]; it receives each
//!   event together with a [`Scheduler`] handle for scheduling follow-ups.
//! * The calendar is a two-level bucketed structure ([`EventQueue`]) tuned
//!   for near-monotonic event distributions; [`HeapEventQueue`] is the
//!   binary-heap reference/baseline it is tested and benchmarked against.
//!   The engine drains same-timestamp batches without re-searching the
//!   calendar (see [`Engine::run`]).

pub mod engine;
pub mod queue;
pub mod sharded;

pub use engine::{Engine, Model, RunResult, Scheduler};
pub use queue::{EventQueue, HeapEventQueue};
pub use sharded::{
    Emit, EventKey, Hub, HubEmit, ReferenceSim, ShardModel, ShardedSim,
    WindowedEngine,
};
