//! Time-ordered event calendar.
//!
//! A binary heap over `(time, seq)` with FIFO tie-breaking. This is the
//! simulator's hottest data structure; see `rust/benches/bench_engine.rs`
//! for its microbenchmark and EXPERIMENTS.md §Perf for the optimization
//! history.

use crate::util::time::Ps;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<Ev> {
    at: Ps,
    seq: u64,
    ev: Ev,
}

impl<Ev> PartialEq for Entry<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<Ev> Eq for Entry<Ev> {}
impl<Ev> PartialOrd for Entry<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Entry<Ev> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we need earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-heap event calendar with deterministic FIFO ordering for ties.
pub struct EventQueue<Ev> {
    heap: BinaryHeap<Entry<Ev>>,
    seq: u64,
}

impl<Ev> Default for EventQueue<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> EventQueue<Ev> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
        }
    }

    /// Schedule `ev` to fire at absolute time `at`.
    #[inline]
    pub fn push(&mut self, at: Ps, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Pop the earliest event, FIFO among equal timestamps.
    #[inline]
    pub fn pop(&mut self) -> Option<(Ps, Ev)> {
        self.heap.pop().map(|e| (e.at, e.ev))
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<Ps> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Ps::ns(30), "c");
        q.push(Ps::ns(10), "a");
        q.push(Ps::ns(20), "b");
        assert_eq!(q.pop(), Some((Ps::ns(10), "a")));
        assert_eq!(q.pop(), Some((Ps::ns(20), "b")));
        assert_eq!(q.pop(), Some((Ps::ns(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Ps::ns(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Ps::ns(5), i)));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(Ps::ns(10), 1);
        q.push(Ps::ns(5), 0);
        assert_eq!(q.pop(), Some((Ps::ns(5), 0)));
        q.push(Ps::ns(7), 2);
        assert_eq!(q.pop(), Some((Ps::ns(7), 2)));
        assert_eq!(q.pop(), Some((Ps::ns(10), 1)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(Ps::ns(42), ());
        assert_eq!(q.peek_time(), Some(Ps::ns(42)));
    }
}
