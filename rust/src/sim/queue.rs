//! Time-ordered event calendar.
//!
//! Two implementations with one contract — earliest time first, FIFO among
//! equal timestamps (exact `(time, seq)` order, never approximate):
//!
//! * [`EventQueue`] — the default: a two-level *bucketed calendar*. A
//!   near-term wheel of [`BUCKETS`] time buckets (each a small binary heap)
//!   covers one window of simulated time; events beyond the window wait in
//!   a sorted overflow tier and migrate in bulk when the wheel drains. Pops
//!   pay `O(log k)` for a bucket of `k` events instead of `O(log n)` over
//!   the whole calendar, and an occupancy bitmap makes the skip over empty
//!   buckets word-parallel. This is the simulator's hottest data structure;
//!   see `rust/benches/bench_engine.rs` for its microbenchmark and
//!   EXPERIMENTS.md §Perf for the optimization history.
//! * [`HeapEventQueue`] — the original single `BinaryHeap` calendar, kept
//!   as the reference implementation: the randomized tests below assert the
//!   bucketed calendar is observationally identical to it, and the perf
//!   harness uses it as the baseline the calendar is measured against.
//!
//! ## Ordering invariants of the bucketed calendar
//!
//! Let `W` be the bucket width and the window cover absolute buckets
//! `[base, base + BUCKETS)`; `cursor ∈ [base, base + BUCKETS)` is the scan
//! position. The structure maintains:
//!
//! 1. Every bucket with absolute index `< cursor` is empty.
//! 2. The overflow tier only holds events whose bucket is `>= base +
//!    BUCKETS`, so any wheel event precedes any overflow event.
//! 3. An event pushed with a time earlier than the cursor bucket is stored
//!    *in* the cursor bucket ("clamped"). Its heap position is still sorted
//!    by `(time, seq)`, and by (1) no earlier bucket is occupied, so the
//!    global pop order is unchanged.
//!
//! Together these make "pop the min of the first occupied bucket" return
//! the global `(time, seq)` minimum, bit-identical to the reference heap.

use crate::util::time::Ps;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<Ev> {
    at: Ps,
    seq: u64,
    ev: Ev,
}

impl<Ev> PartialEq for Entry<Ev> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<Ev> Eq for Entry<Ev> {}
impl<Ev> PartialOrd for Entry<Ev> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<Ev> Ord for Entry<Ev> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we need earliest-first.
        other
            .at
            .cmp(&self.at)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Buckets in the near-term wheel (power of two).
pub const BUCKETS: usize = 1024;
const OCC_WORDS: usize = BUCKETS / 64;
/// Default bucket width: 1 µs. The SSD models schedule most follow-ups
/// within tens of ns to hundreds of µs of `now`, so one window spans ~1 ms
/// of simulated time and same-batch events land in small per-bucket heaps.
pub const DEFAULT_BUCKET_PS: i64 = 1_000_000;

/// Bucketed calendar event queue with deterministic FIFO ordering for ties.
pub struct EventQueue<Ev> {
    /// The near-term wheel; slot `b % BUCKETS` holds absolute bucket `b`.
    wheel: Vec<BinaryHeap<Entry<Ev>>>,
    /// One bit per slot: set iff the bucket is non-empty.
    occ: [u64; OCC_WORDS],
    /// Total events in the wheel.
    wheel_len: usize,
    /// Absolute bucket index of the window start.
    base: i64,
    /// Absolute bucket index of the scan position (see module invariants).
    cursor: i64,
    /// Bucket width in picoseconds.
    bucket_ps: i64,
    /// Events beyond the window, ordered by `(time, seq)`.
    overflow: BinaryHeap<Entry<Ev>>,
    seq: u64,
}

impl<Ev> Default for EventQueue<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> EventQueue<Ev> {
    pub fn new() -> Self {
        Self::with_bucket_ps(DEFAULT_BUCKET_PS)
    }

    /// API-compat constructor: `cap` pre-sizes only the overflow tier.
    /// The wheel's per-bucket heaps grow on demand and keep their
    /// capacity across [`clear`](Self::clear), so a reused scheduler
    /// (sweep workers, see `coordinator/campaign.rs`) reaches steady
    /// state after its first run and allocates nothing thereafter.
    pub fn with_capacity(cap: usize) -> Self {
        let mut q = Self::new();
        q.overflow.reserve(cap);
        q
    }

    /// Calendar with an explicit bucket width (tuning / tests).
    pub fn with_bucket_ps(bucket_ps: i64) -> Self {
        assert!(bucket_ps > 0, "bucket width must be positive");
        EventQueue {
            wheel: (0..BUCKETS).map(|_| BinaryHeap::new()).collect(),
            occ: [0; OCC_WORDS],
            wheel_len: 0,
            base: 0,
            cursor: 0,
            bucket_ps,
            overflow: BinaryHeap::new(),
            seq: 0,
        }
    }

    #[inline]
    fn bucket_of(&self, at: Ps) -> i64 {
        at.as_ps().div_euclid(self.bucket_ps)
    }

    #[inline]
    fn slot_of(bucket: i64) -> usize {
        bucket.rem_euclid(BUCKETS as i64) as usize
    }

    #[inline]
    fn window_end(&self) -> i64 {
        self.base.saturating_add(BUCKETS as i64)
    }

    #[inline]
    fn mark(&mut self, slot: usize) {
        self.occ[slot / 64] |= 1u64 << (slot % 64);
    }

    #[inline]
    fn unmark_if_empty(&mut self, slot: usize) {
        if self.wheel[slot].is_empty() {
            self.occ[slot / 64] &= !(1u64 << (slot % 64));
        }
    }

    /// Distance (in buckets, 0-based) from `start_slot` to the first
    /// occupied slot, scanning circularly. `None` if the wheel is empty.
    fn scan_occ(&self, start_slot: usize) -> Option<usize> {
        let w0 = start_slot / 64;
        let b0 = start_slot % 64;
        let head = self.occ[w0] >> b0;
        if head != 0 {
            return Some(head.trailing_zeros() as usize);
        }
        // Branchless sweep: visit every remaining word exactly once (fixed
        // trip count — no data-dependent early-out for the predictor to
        // miss) and fold the occupancy into a summary bitmap; a single
        // trailing_zeros then locates the first non-empty word. Bit
        // `OCC_WORDS` stands for the full-circle wrap word (the low bits of
        // the start word).
        let mut summary: u32 = 0;
        for i in 1..OCC_WORDS {
            let w = self.occ[(w0 + i) % OCC_WORDS];
            summary |= ((w != 0) as u32) << i;
        }
        let tail = if b0 == 0 { 0 } else { self.occ[w0] & ((1u64 << b0) - 1) };
        summary |= ((tail != 0) as u32) << OCC_WORDS;
        if summary == 0 {
            return None;
        }
        let i = summary.trailing_zeros() as usize;
        let word = if i == OCC_WORDS { tail } else { self.occ[(w0 + i) % OCC_WORDS] };
        Some((64 - b0) + (i - 1) * 64 + word.trailing_zeros() as usize)
    }

    /// Wheel empty: restart the window at the overflow's earliest bucket and
    /// migrate every now-in-window overflow event. Returns false if there is
    /// nothing pending at all.
    fn advance_window(&mut self) -> bool {
        debug_assert_eq!(self.wheel_len, 0);
        let Some(head) = self.overflow.peek() else {
            return false;
        };
        self.base = self.bucket_of(head.at);
        self.cursor = self.base;
        let end = self.window_end();
        while let Some(head) = self.overflow.peek() {
            if self.bucket_of(head.at) >= end {
                break;
            }
            let e = self.overflow.pop().expect("peeked");
            let slot = Self::slot_of(self.bucket_of(e.at));
            self.wheel[slot].push(e);
            self.mark(slot);
            self.wheel_len += 1;
        }
        true
    }

    /// Schedule `ev` to fire at absolute time `at`.
    #[inline]
    pub fn push(&mut self, at: Ps, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        let e = Entry { at, seq, ev };
        let b = self.bucket_of(at);
        if b >= self.window_end() {
            self.overflow.push(e);
            return;
        }
        // Invariant 3: never place an event behind the scan cursor.
        let slot = Self::slot_of(b.max(self.cursor));
        self.wheel[slot].push(e);
        self.mark(slot);
        self.wheel_len += 1;
    }

    /// Earliest pending time, advancing the scan cursor to its bucket (and
    /// migrating overflow events if the wheel drained). Prefer this over
    /// [`peek_time`](Self::peek_time) on hot paths: the cursor advance is
    /// memoized so the empty-bucket skip is not re-paid.
    pub fn next_time(&mut self) -> Option<Ps> {
        if self.wheel_len == 0 && !self.advance_window() {
            return None;
        }
        let start = Self::slot_of(self.cursor);
        let d = self.scan_occ(start).expect("wheel_len > 0");
        self.cursor += d as i64;
        debug_assert!(self.cursor < self.window_end());
        let slot = (start + d) % BUCKETS;
        Some(self.wheel[slot].peek().expect("occupied slot").at)
    }

    /// Pop the earliest event, FIFO among equal timestamps.
    #[inline]
    pub fn pop(&mut self) -> Option<(Ps, Ev)> {
        self.next_time()?;
        let slot = Self::slot_of(self.cursor);
        let e = self.wheel[slot].pop().expect("cursor bucket occupied");
        self.wheel_len -= 1;
        self.unmark_if_empty(slot);
        Some((e.at, e.ev))
    }

    /// Pop the next event only if it fires exactly at `t`.
    ///
    /// Contract: `t` must be the time returned by the immediately preceding
    /// [`next_time`](Self::next_time)/[`pop`](Self::pop) — the cursor then
    /// already points at the batch's bucket, so draining a same-timestamp
    /// batch never re-scans the calendar. Events scheduled *at* `t` during
    /// the batch land in the same bucket (invariant 3) and are picked up in
    /// FIFO order.
    #[inline]
    pub fn pop_if_at(&mut self, t: Ps) -> Option<Ev> {
        if self.wheel_len == 0 {
            // Same-timestamp events can never hide in the overflow tier
            // (invariant 2: overflow buckets lie beyond the whole window).
            return None;
        }
        let slot = Self::slot_of(self.cursor);
        match self.wheel[slot].peek() {
            Some(head) if head.at == t => {
                let e = self.wheel[slot].pop().expect("peeked");
                self.wheel_len -= 1;
                self.unmark_if_empty(slot);
                Some(e.ev)
            }
            _ => None,
        }
    }

    /// Earliest scheduled time, if any (non-mutating; pays the empty-bucket
    /// scan on every call — hot paths use [`next_time`](Self::next_time)).
    pub fn peek_time(&self) -> Option<Ps> {
        if self.wheel_len == 0 {
            return self.overflow.peek().map(|e| e.at);
        }
        let start = Self::slot_of(self.cursor);
        let d = self.scan_occ(start).expect("wheel_len > 0");
        let slot = (start + d) % BUCKETS;
        self.wheel[slot].peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.wheel_len + self.overflow.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    pub fn clear(&mut self) {
        if self.wheel_len > 0 {
            for b in &mut self.wheel {
                b.clear();
            }
        }
        self.occ = [0; OCC_WORDS];
        self.wheel_len = 0;
        self.overflow.clear();
        self.base = 0;
        self.cursor = 0;
    }
}

/// Reference implementation: min-heap event calendar with deterministic
/// FIFO ordering for ties (the pre-calendar baseline; used as the oracle in
/// randomized tests and as the baseline in `bench_engine`).
pub struct HeapEventQueue<Ev> {
    heap: BinaryHeap<Entry<Ev>>,
    seq: u64,
}

impl<Ev> Default for HeapEventQueue<Ev> {
    fn default() -> Self {
        Self::new()
    }
}

impl<Ev> HeapEventQueue<Ev> {
    pub fn new() -> Self {
        HeapEventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn with_capacity(cap: usize) -> Self {
        HeapEventQueue {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
        }
    }

    /// Schedule `ev` to fire at absolute time `at`.
    #[inline]
    pub fn push(&mut self, at: Ps, ev: Ev) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { at, seq, ev });
    }

    /// Pop the earliest event, FIFO among equal timestamps.
    #[inline]
    pub fn pop(&mut self) -> Option<(Ps, Ev)> {
        self.heap.pop().map(|e| (e.at, e.ev))
    }

    /// Earliest scheduled time, if any.
    pub fn peek_time(&self) -> Option<Ps> {
        self.heap.peek().map(|e| e.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

// Float arithmetic is banned in non-test sim/ code (simlint R2 + the
// module-level clippy::float_arithmetic wall in lib.rs); the randomized
// oracles below legitimately use floats to *generate* arrival gaps.
#[cfg(test)]
#[allow(clippy::float_arithmetic)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(Ps::ns(30), "c");
        q.push(Ps::ns(10), "a");
        q.push(Ps::ns(20), "b");
        assert_eq!(q.pop(), Some((Ps::ns(10), "a")));
        assert_eq!(q.pop(), Some((Ps::ns(20), "b")));
        assert_eq!(q.pop(), Some((Ps::ns(30), "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(Ps::ns(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Ps::ns(5), i)));
        }
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(Ps::ns(10), 1);
        q.push(Ps::ns(5), 0);
        assert_eq!(q.pop(), Some((Ps::ns(5), 0)));
        q.push(Ps::ns(7), 2);
        assert_eq!(q.pop(), Some((Ps::ns(7), 2)));
        assert_eq!(q.pop(), Some((Ps::ns(10), 1)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(Ps::ns(42), ());
        assert_eq!(q.peek_time(), Some(Ps::ns(42)));
    }

    #[test]
    fn overflow_tier_roundtrip() {
        // Times spread over ~40 s with 1 µs buckets: everything beyond the
        // first 1.024 ms window exercises overflow + window advance.
        let mut q = EventQueue::new();
        let n = 2_000i64;
        for i in (0..n).rev() {
            q.push(Ps::us(i * 20_000), i);
        }
        assert_eq!(q.len(), n as usize);
        for i in 0..n {
            assert_eq!(q.pop(), Some((Ps::us(i * 20_000), i)), "i={i}");
        }
        assert!(q.is_empty());
    }

    #[test]
    fn clamped_push_behind_cursor_pops_first() {
        let mut q = EventQueue::new();
        q.push(Ps::us(100), 1u32);
        q.push(Ps::us(200), 2);
        // Pop the 100 µs event: the cursor advances to its bucket.
        assert_eq!(q.pop(), Some((Ps::us(100), 1)));
        // A push earlier than the cursor bucket must still pop first
        // (clamp path, invariant 3).
        q.push(Ps::us(50), 3);
        assert_eq!(q.peek_time(), Some(Ps::us(50)));
        assert_eq!(q.pop(), Some((Ps::us(50), 3)));
        assert_eq!(q.pop(), Some((Ps::us(200), 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_if_at_drains_one_batch_in_fifo_order() {
        let mut q = EventQueue::new();
        for i in 0..40u32 {
            q.push(Ps::us(7), i);
        }
        q.push(Ps::us(9), 999);
        let t = q.next_time().unwrap();
        assert_eq!(t, Ps::us(7));
        let mut batch = Vec::new();
        while let Some(ev) = q.pop_if_at(t) {
            batch.push(ev);
            // Events scheduled at the batch timestamp join the same batch.
            if ev == 5 {
                q.push(Ps::us(7), 1000);
            }
        }
        let mut expect: Vec<u32> = (0..40).collect();
        expect.push(1000);
        assert_eq!(batch, expect);
        assert_eq!(q.pop(), Some((Ps::us(9), 999)));
    }

    #[test]
    fn far_future_and_max_times() {
        let mut q = EventQueue::new();
        q.push(Ps::MAX, 2u8);
        q.push(Ps::ms(1000), 1);
        q.push(Ps::ns(1), 0);
        assert_eq!(q.pop(), Some((Ps::ns(1), 0)));
        assert_eq!(q.pop(), Some((Ps::ms(1000), 1)));
        assert_eq!(q.pop(), Some((Ps::MAX, 2)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn clear_resets() {
        let mut q = EventQueue::new();
        for i in 0..100i64 {
            q.push(Ps::us(i * 5_000), i);
        }
        q.pop();
        q.clear();
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        q.push(Ps::ns(3), 7);
        assert_eq!(q.pop(), Some((Ps::ns(3), 7)));
    }

    /// Randomized interleaved push/pop: the calendar must match the heap
    /// reference exactly — same times, same FIFO order among ties — across
    /// in-window, cross-window and overflow time scales.
    #[test]
    fn matches_heap_reference_randomized() {
        for seed in 0..u64::from(crate::proptest::effective_cases(20)) {
            let mut rng = Prng::new(0xCA1E_17DA + seed);
            let mut cal: EventQueue<u32> = EventQueue::with_bucket_ps(1 + (seed as i64 % 7) * 997);
            let mut heap: HeapEventQueue<u32> = HeapEventQueue::new();
            // `now` mimics the Scheduler's monotonic clock: pushes are
            // always >= the last popped time.
            let mut now = Ps::ZERO;
            let mut id = 0u32;
            for step in 0..4_000 {
                if rng.next_bool(0.55) || heap.is_empty() {
                    // Mixed scales: same-time, near-term, and far-future.
                    let delay = match rng.next_bounded(10) {
                        0 => Ps::ZERO,
                        1..=5 => Ps::ps(rng.next_bounded(2_000_000) as i64),
                        6..=8 => Ps::ps(rng.next_bounded(400_000_000) as i64),
                        _ => Ps::ps(rng.next_bounded(60_000_000_000) as i64),
                    };
                    cal.push(now + delay, id);
                    heap.push(now + delay, id);
                    id += 1;
                } else {
                    let expect = heap.pop();
                    let got = cal.pop();
                    assert_eq!(got, expect, "seed {seed} step {step}");
                    now = got.expect("heap non-empty").0;
                }
                assert_eq!(cal.len(), heap.len(), "seed {seed} step {step}");
                assert_eq!(cal.peek_time(), heap.peek_time(), "seed {seed} step {step}");
            }
            // Drain: remaining order must match exactly.
            loop {
                let expect = heap.pop();
                let got = cal.pop();
                assert_eq!(got, expect, "seed {seed} drain");
                if got.is_none() {
                    break;
                }
            }
        }
    }

    /// Randomized oracle over the *open-loop arrival* shape (PR 2's
    /// `Ev::Arrive` chain, which postdates the original oracle): one
    /// far-future arrival is pending at a time — popping it schedules a
    /// burst of near-term "service" events plus the next arrival at an
    /// exponential (Poisson) gap, and service events chain short
    /// follow-ups (the BusDone → ChipDone pattern). Arrival gaps span many
    /// bucket windows, so pushes constantly land in the overflow tier
    /// while same-instant burst members exercise FIFO ties; the calendar
    /// must match the heap reference exactly throughout.
    #[test]
    fn matches_heap_reference_on_open_loop_arrival_traces() {
        const ARRIVAL_TAG: u32 = 1 << 31;
        for seed in 0..u64::from(crate::proptest::effective_cases(12)) {
            let mut rng = Prng::new(0x09E2_A221 + seed);
            // Narrow buckets force the multi-window/overflow machinery.
            let mut cal: EventQueue<u32> =
                EventQueue::with_bucket_ps(1 + (seed as i64 % 7) * 431);
            let mut heap: HeapEventQueue<u32> = HeapEventQueue::new();
            let mean_gap_ps = 50_000.0 + seed as f64 * 400_000.0; // 50 ns – 4.5 µs
            let push = |cal: &mut EventQueue<u32>,
                        heap: &mut HeapEventQueue<u32>,
                        at: Ps,
                        ev: u32| {
                cal.push(at, ev);
                heap.push(at, ev);
            };
            let mut id = 0u32;
            let mut arrivals_left = 300u32;
            push(&mut cal, &mut heap, Ps::ZERO, ARRIVAL_TAG);
            loop {
                let expect = heap.pop();
                let got = cal.pop();
                assert_eq!(got, expect, "seed {seed}");
                assert_eq!(cal.len(), heap.len(), "seed {seed}");
                assert_eq!(cal.peek_time(), heap.peek_time(), "seed {seed}");
                let Some((now, ev)) = got else { break };
                if ev & ARRIVAL_TAG != 0 {
                    // An arrival admits a burst of service events "now"
                    // (same-instant FIFO ties) and near-now.
                    for _ in 0..1 + rng.next_bounded(4) {
                        let delay = Ps::ps(rng.next_bounded(3_000) as i64);
                        push(&mut cal, &mut heap, now + delay, id);
                        id += 1;
                    }
                    // Chain the next arrival at an exponential gap.
                    if arrivals_left > 0 {
                        arrivals_left -= 1;
                        let gap = (mean_gap_ps * rng.next_exponential()).round() as i64;
                        push(&mut cal, &mut heap, now + Ps::ps(gap), ARRIVAL_TAG | id);
                        id += 1;
                    }
                } else if rng.next_bool(0.6) && id < ARRIVAL_TAG {
                    // Service follow-up (bus phase -> array completion).
                    let delay = Ps::ps(1 + rng.next_bounded(40_000) as i64);
                    push(&mut cal, &mut heap, now + delay, id);
                    id += 1;
                }
            }
            assert!(cal.is_empty() && heap.is_empty(), "seed {seed}");
        }
    }

    /// The heap reference itself honours FIFO ties (oracle sanity).
    #[test]
    fn heap_reference_fifo_on_ties() {
        let mut q = HeapEventQueue::new();
        for i in 0..100 {
            q.push(Ps::ns(5), i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((Ps::ns(5), i)));
        }
    }

    /// The branchless occupancy sweep is value-identical to a naive linear
    /// scan over the bucket bitmap, including wrap-around and empty wheels.
    #[test]
    fn scan_occ_matches_naive_reference() {
        let naive = |occ: &[u64; OCC_WORDS], start: usize| -> Option<usize> {
            (0..BUCKETS).find(|&d| {
                let slot = (start + d) % BUCKETS;
                occ[slot / 64] & (1u64 << (slot % 64)) != 0
            })
        };
        let mut patterns: Vec<[u64; OCC_WORDS]> =
            vec![[0; OCC_WORDS], [u64::MAX; OCC_WORDS]];
        for slot in [0usize, 1, 63, 64, 65, BUCKETS - 1] {
            let mut occ = [0u64; OCC_WORDS];
            occ[slot / 64] |= 1 << (slot % 64);
            patterns.push(occ);
        }
        let mut rng = Prng::new(0xC0FFEE);
        for _ in 0..50 {
            let mut occ = [0u64; OCC_WORDS];
            for _ in 0..1 + rng.next_bounded(20) {
                let slot = rng.next_bounded(BUCKETS as u64) as usize;
                occ[slot / 64] |= 1 << (slot % 64);
            }
            patterns.push(occ);
        }
        let mut q: EventQueue<u32> = EventQueue::new();
        for occ in patterns {
            q.occ = occ;
            for start in [0usize, 1, 17, 63, 64, 100, 511, 512, BUCKETS - 1] {
                assert_eq!(
                    q.scan_occ(start),
                    naive(&occ, start),
                    "start {start}, occ {occ:?}"
                );
            }
        }
    }
}
