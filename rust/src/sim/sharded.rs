//! Conservative-time-window (lookahead) parallel DES.
//!
//! Two engines live here, both built on the same window discipline:
//!
//! * [`WindowedEngine`] drives an ordinary [`Model`] on the single global
//!   calendar, partitioning virtual time into windows `[w, w + lookahead)`.
//!   Dispatch order is *exactly* the `(time, seq)` order of [`Engine::run`],
//!   so results are bit-identical to the single-threaded engine by
//!   construction; its window count measures how much batch parallelism a
//!   given lookahead exposes.
//! * [`ShardedSim`] runs a set of *shard-local* models (one per channel) in
//!   true parallel: each shard owns a private calendar, every window
//!   `[w, w + lookahead)` is processed concurrently across shards, and
//!   cross-shard events are exchanged only at window boundaries. Two
//!   execution shapes are offered: [`ShardedSim::run`] for models that only
//!   talk shard-to-shard, and [`ShardedSim::run_hub`] — the mode `SsdSim`
//!   uses — which adds a serialized [`Hub`] commit step at every window
//!   boundary for state that cannot be sharded (FTL allocation, host-link
//!   admission, the cache): shards report completions via [`Emit::commit`],
//!   the hub consumes them in `(time, shard, seq)` order, and injects
//!   next-window work back through per-shard inboxes via
//!   [`HubEmit::send_at`].
//!
//! # Safety argument for the lookahead bound
//!
//! A conservative window of width `L` is safe iff no event processed inside
//! the window can cause another shard to need an event *earlier* than the
//! window's end. Shards interact only through explicit cross-shard sends,
//! and every send from a handler running at time `t ∈ [w, w+L)` must target
//! a time `≥ w + L` — which holds whenever the model's minimum cross-shard
//! latency is `≥ L` (for the SSD model: the minimum bus command/transfer
//! phase, [`crate::iface::bus::BusTiming::min_phase`] — nothing crosses a
//! channel boundary without occupying the bus for at least one command
//! phase). [`Emit::send_at`] asserts this at emission time, so a violated
//! bound is a loud model bug, never a silent reorder. The hub is held to
//! the same bound: [`HubEmit::send_at`] rejects injections that land inside
//! the window just committed.
//!
//! # Determinism
//!
//! Every event carries an explicit total-order key
//! `(time, source shard, per-source emission counter)` assigned when it is
//! emitted. Each shard drains its calendar in key order, and a shard's
//! handler sees only shard-local state, so the processing order — and
//! therefore every emission counter, and therefore every key — is identical
//! whether windows run serially, on 2 threads, on 8, or on the single
//! global calendar of [`ReferenceSim`]. Hub runs stay deterministic for the
//! same reason: the message batch handed to [`Hub::commit`] is *sorted* by
//! key before the hub sees it, so worker scheduling cannot leak into the
//! commit order, and hub injections carry [`HUB_SRC`] keys from a single
//! serial counter. That is what the randomized oracle tests in
//! `tests/sharded_engine.rs` check.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicI64, Ordering};
use std::sync::{Barrier, Mutex};

use crate::sim::engine::{Model, RunResult, Scheduler};
use crate::util::time::Ps;

/// Source id used for events seeded from outside any shard handler.
pub const SEED_SRC: u32 = u32::MAX;

/// Source id used for events injected by the serialized [`Hub`] commit
/// step. Distinct from [`SEED_SRC`] so hub injections and external seeds
/// can never collide on `(src, seq)`.
pub const HUB_SRC: u32 = u32::MAX - 1;

/// Total order over events: time, then source shard, then per-source
/// emission sequence. Unique per event (no two emissions share
/// `(src, seq)`), so dispatch order is independent of calendar internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct EventKey {
    pub at: Ps,
    pub src: u32,
    pub seq: u64,
}

/// Calendar entry ordered by key alone (payload need not be `Ord`).
struct Entry<P> {
    key: EventKey,
    payload: P,
}

impl<P> PartialEq for Entry<P> {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}
impl<P> Eq for Entry<P> {}
impl<P> PartialOrd for Entry<P> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<P> Ord for Entry<P> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// Emission collector handed to [`ShardModel::handle`]. Local events may
/// land anywhere `≥ now` (including inside the current window); cross-shard
/// events must land at or past the window boundary — see the module-level
/// safety argument. Completion reports for the serialized commit step go
/// through [`Emit::commit`] and are only legal under [`ShardedSim::run_hub`].
pub struct Emit<Ev, Msg = ()> {
    shard: u32,
    now: Ps,
    /// End of the current window; `Ps::ZERO` disables the check (reference
    /// executor, which has no windows).
    w_end: Ps,
    seq: u64,
    local: Vec<(EventKey, Ev)>,
    cross: Vec<(u32, EventKey, Ev)>,
    commits: Vec<(EventKey, Msg)>,
}

impl<Ev, Msg> Emit<Ev, Msg> {
    fn new(shard: u32, now: Ps, w_end: Ps, seq: u64) -> Self {
        Emit {
            shard,
            now,
            w_end,
            seq,
            local: Vec::new(),
            cross: Vec::new(),
            commits: Vec::new(),
        }
    }

    /// Current simulated time (the handled event's timestamp).
    #[inline]
    pub fn now(&self) -> Ps {
        self.now
    }

    /// The shard this handler runs on.
    #[inline]
    pub fn shard(&self) -> u32 {
        self.shard
    }

    fn next_key(&mut self, at: Ps) -> EventKey {
        let key = EventKey { at, src: self.shard, seq: self.seq };
        self.seq += 1;
        key
    }

    /// Schedule a shard-local event `delay` after now.
    pub fn local_after(&mut self, delay: Ps, ev: Ev) {
        debug_assert!(delay >= Ps::ZERO, "negative delay {delay:?}");
        self.local_at(self.now + delay, ev);
    }

    /// Schedule a shard-local event at absolute time `at` (not in the past).
    pub fn local_at(&mut self, at: Ps, ev: Ev) {
        assert!(at >= self.now, "scheduling into the past: {at:?} < {:?}", self.now);
        let key = self.next_key(at);
        self.local.push((key, ev));
    }

    /// Send an event to another shard, `delay` after now. Safe whenever
    /// `delay` ≥ the engine's lookahead.
    pub fn send_after(&mut self, shard: u32, delay: Ps, ev: Ev) {
        self.send_at(shard, self.now + delay, ev);
    }

    /// Send an event to another shard at absolute time `at`. Panics if `at`
    /// lands inside the current window — that would violate the conservative
    /// lookahead bound and could reorder execution.
    pub fn send_at(&mut self, shard: u32, at: Ps, ev: Ev) {
        assert!(
            at >= self.w_end,
            "lookahead violation: cross-shard event at {at:?} lands inside the \
             window ending at {:?} (shard {} -> {shard})",
            self.w_end,
            self.shard,
        );
        let key = self.next_key(at);
        self.cross.push((shard, key, ev));
    }

    /// Report a completion message to the serialized [`Hub`] commit step,
    /// keyed at the current event's timestamp. Messages from all shards are
    /// merged in `(time, shard, seq)` order at the next window boundary.
    /// Only legal under [`ShardedSim::run_hub`] — the hubless executors
    /// treat a committed message as a model bug and panic.
    pub fn commit(&mut self, msg: Msg) {
        let key = self.next_key(self.now);
        self.commits.push((key, msg));
    }
}

/// A shard-local simulation model. Unlike [`Model`], a handler sees only
/// this shard's state and communicates with other shards exclusively via
/// [`Emit::send_after`]/[`Emit::send_at`], and with the serialized commit
/// step (when one is attached) via [`Emit::commit`].
pub trait ShardModel: Send {
    type Ev: Send;
    /// Completion message consumed by the [`Hub`] commit step at window
    /// boundaries. `()` for models that run without a hub.
    type Msg: Send;
    fn handle(&mut self, now: Ps, ev: Self::Ev, out: &mut Emit<Self::Ev, Self::Msg>);
}

/// The serialized commit step of a hub-coupled sharded simulation
/// ([`ShardedSim::run_hub`]): global state that cannot be sharded. Runs on
/// the coordinating thread only — never concurrently with itself — once per
/// window, after every shard has drained the window.
pub trait Hub<M: ShardModel> {
    /// Earliest pending hub-side event, if any. Drives window placement
    /// exactly like a shard calendar: the next window starts at the minimum
    /// over all shard calendars and this.
    fn next_time(&mut self) -> Option<Ps>;

    /// Process one window's worth of global work: `msgs` holds every
    /// [`Emit::commit`] from the window `[w_start, w_end)`, already sorted
    /// by `(time, shard, seq)` key; hub-internal events due before `w_end`
    /// must be interleaved with them in time order by the implementation.
    /// New shard work is injected via `out` and must land at or past
    /// `w_end` (enforced by [`HubEmit::send_at`]).
    fn commit(
        &mut self,
        msgs: &[(EventKey, M::Msg)],
        w_end: Ps,
        out: &mut HubEmit<M::Ev>,
    );
}

/// Injection collector handed to [`Hub::commit`]. Keys use [`HUB_SRC`] with
/// a counter that persists across windows, so hub injections have a single
/// deterministic total order regardless of thread count.
pub struct HubEmit<Ev> {
    w_end: Ps,
    seq: u64,
    sends: Vec<(u32, EventKey, Ev)>,
}

impl<Ev> HubEmit<Ev> {
    fn new(w_end: Ps, seq: u64) -> Self {
        HubEmit { w_end, seq, sends: Vec::new() }
    }

    /// End of the window being committed (= earliest legal injection time).
    #[inline]
    pub fn w_end(&self) -> Ps {
        self.w_end
    }

    /// Inject an event onto `shard` at absolute time `at`. Panics if `at`
    /// lands inside the window just committed — the shards have already
    /// advanced past it, so the injection would be a causality violation.
    pub fn send_at(&mut self, shard: u32, at: Ps, ev: Ev) {
        assert!(
            at >= self.w_end,
            "hub lookahead violation: injection at {at:?} lands inside the \
             committed window ending at {:?} (-> shard {shard})",
            self.w_end,
        );
        let key = EventKey { at, src: HUB_SRC, seq: self.seq };
        self.seq += 1;
        self.sends.push((shard, key, ev));
    }
}

/// One shard's runtime state: the model plus its private calendar.
struct ShardRt<M: ShardModel> {
    model: M,
    heap: BinaryHeap<Reverse<Entry<M::Ev>>>,
    /// Emission counter for events *sourced* by this shard.
    seq: u64,
    /// Events dispatched on this shard (cumulative across runs).
    events: u64,
    /// Timestamp of the last dispatched event.
    last: Ps,
}

impl<M: ShardModel> ShardRt<M> {
    fn next_time(&self) -> Option<Ps> {
        self.heap.peek().map(|e| e.0.key.at)
    }
}

/// Drain one shard's calendar up to (exclusive) `w_end`, bounded by
/// `horizon` (inclusive). Cross-shard emissions are appended to `cross`;
/// commit messages for the hub (if any) to `commits`.
fn run_window<M: ShardModel>(
    id: u32,
    s: &mut ShardRt<M>,
    w_end: Ps,
    horizon: Ps,
    cross: &mut Vec<(u32, EventKey, M::Ev)>,
    commits: &mut Vec<(EventKey, M::Msg)>,
) {
    while let Some(at) = s.next_time() {
        if at >= w_end || at > horizon {
            break;
        }
        let Reverse(Entry { key, payload: ev }) = s.heap.pop().expect("peeked entry");
        debug_assert_eq!(key.at, at);
        let mut emit = Emit::new(id, at, w_end, s.seq);
        s.model.handle(at, ev, &mut emit);
        s.seq = emit.seq;
        s.events += 1;
        s.last = at;
        for (key, ev) in emit.local {
            s.heap.push(Reverse(Entry { key, payload: ev }));
        }
        for routed in emit.cross {
            debug_assert!(routed.1.at >= w_end, "Emit::send_at missed a violation");
            cross.push(routed);
        }
        commits.append(&mut emit.commits);
    }
}

/// Channel-sharded simulator: N shard-local models advanced in conservative
/// time windows, optionally across OS threads.
///
/// `threads = 1` processes shards in-place with zero synchronization (and is
/// the reference the parallel path must match bit-for-bit); `threads > 1`
/// runs a bulk-synchronous loop with persistent workers — one barrier round
/// per window, so wide windows amortize synchronization across many events.
pub struct ShardedSim<M: ShardModel> {
    shards: Vec<ShardRt<M>>,
    lookahead: Ps,
    seed_seq: u64,
    windows: u64,
}

impl<M: ShardModel> ShardedSim<M> {
    /// `lookahead` must be positive: a zero-width window cannot advance.
    pub fn new(models: Vec<M>, lookahead: Ps) -> Self {
        assert!(lookahead > Ps::ZERO, "lookahead must be positive");
        let shards = models
            .into_iter()
            .map(|model| ShardRt {
                model,
                heap: BinaryHeap::new(),
                seq: 0,
                events: 0,
                last: Ps::ZERO,
            })
            .collect();
        ShardedSim { shards, lookahead, seed_seq: 0, windows: 0 }
    }

    /// The configured window width.
    pub fn lookahead(&self) -> Ps {
        self.lookahead
    }

    /// Windows advanced by the most recent [`ShardedSim::run`].
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Pending events across all shards.
    pub fn pending(&self) -> usize {
        self.shards.iter().map(|s| s.heap.len()).sum()
    }

    /// Seed an initial event onto `shard` (keys use [`SEED_SRC`], so seeds
    /// order after same-time handler emissions, consistently everywhere).
    pub fn seed(&mut self, shard: u32, at: Ps, ev: M::Ev) {
        let key = EventKey { at, src: SEED_SRC, seq: self.seed_seq };
        self.seed_seq += 1;
        self.shards[shard as usize].heap.push(Reverse(Entry { key, payload: ev }));
    }

    /// Borrow one shard's model (for result extraction).
    pub fn model(&self, shard: u32) -> &M {
        &self.shards[shard as usize].model
    }

    /// Iterate all shard models.
    pub fn models(&self) -> impl Iterator<Item = &M> {
        self.shards.iter().map(|s| &s.model)
    }

    /// Consume the simulator, returning the shard models in shard order
    /// (state extraction after a run). Any still-queued beyond-horizon
    /// events are dropped with their calendars.
    pub fn into_models(self) -> Vec<M> {
        self.shards.into_iter().map(|s| s.model).collect()
    }

    fn total_events(&self) -> u64 {
        self.shards.iter().map(|s| s.events).sum()
    }

    fn drained_result(&self, base_events: u64) -> RunResult {
        RunResult {
            end_time: self.shards.iter().map(|s| s.last).fold(Ps::ZERO, Ps::max),
            events: self.total_events() - base_events,
            drained: true,
        }
    }

    /// Run until all calendars drain or `horizon` is passed. Events beyond
    /// the horizon stay queued, so runs are resumable like [`Engine::run`].
    ///
    /// [`Engine::run`]: crate::sim::engine::Engine::run
    pub fn run(&mut self, horizon: Ps, threads: usize) -> RunResult {
        self.windows = 0;
        let workers = threads.clamp(1, self.shards.len().max(1));
        if workers <= 1 {
            self.run_serial(horizon)
        } else {
            self.run_parallel(horizon, workers)
        }
    }

    fn run_serial(&mut self, horizon: Ps) -> RunResult {
        let base = self.total_events();
        let mut cross: Vec<(u32, EventKey, M::Ev)> = Vec::new();
        let mut no_hub: Vec<(EventKey, M::Msg)> = Vec::new();
        loop {
            let Some(w_start) = self.shards.iter().filter_map(ShardRt::next_time).min()
            else {
                return self.drained_result(base);
            };
            if w_start > horizon {
                return RunResult {
                    end_time: horizon,
                    events: self.total_events() - base,
                    drained: false,
                };
            }
            let w_end = w_start.saturating_add(self.lookahead);
            self.windows += 1;
            for (i, s) in self.shards.iter_mut().enumerate() {
                run_window(i as u32, s, w_end, horizon, &mut cross, &mut no_hub);
            }
            assert!(
                no_hub.is_empty(),
                "model committed messages but no hub is attached: use run_hub"
            );
            for (dest, key, ev) in cross.drain(..) {
                self.shards[dest as usize].heap.push(Reverse(Entry { key, payload: ev }));
            }
        }
    }

    /// Bulk-synchronous parallel loop. Per window: the coordinator (calling
    /// thread) publishes the window bound, workers drain their shards and
    /// post cross-shard events into per-owner inboxes, a barrier, owners
    /// drain their inboxes and publish their next event time, a barrier,
    /// and the coordinator picks the next window start.
    fn run_parallel(&mut self, horizon: Ps, workers: usize) -> RunResult {
        const IDLE: i64 = i64::MAX;
        let base = self.total_events();
        let n = self.shards.len();
        let chunk = n.div_ceil(workers);
        // `chunks_mut` may yield fewer chunks than requested workers (e.g.
        // 8 shards / 5 workers -> chunk 2 -> 4 chunks); size everything on
        // the actual chunk count or the barrier would deadlock.
        let workers = n.div_ceil(chunk);
        let lookahead = self.lookahead;

        let barrier = Barrier::new(workers + 1);
        let done = AtomicBool::new(false);
        let w_end_ps = AtomicI64::new(0);
        let next_times: Vec<AtomicI64> =
            (0..workers).map(|_| AtomicI64::new(IDLE)).collect();
        let inboxes: Vec<Mutex<Vec<(u32, EventKey, M::Ev)>>> =
            (0..workers).map(|_| Mutex::new(Vec::new())).collect();
        let panicked: Mutex<Option<String>> = Mutex::new(None);

        let mut t = self.shards.iter().filter_map(ShardRt::next_time).min();
        let mut windows = 0u64;
        std::thread::scope(|scope| {
            for (wi, shards) in self.shards.chunks_mut(chunk).enumerate() {
                let base_shard = (wi * chunk) as u32;
                let barrier = &barrier;
                let done = &done;
                let w_end_ps = &w_end_ps;
                let next_times = &next_times;
                let inboxes = &inboxes;
                let panicked = &panicked;
                scope.spawn(move || {
                    let mut out: Vec<(u32, EventKey, M::Ev)> = Vec::new();
                    let mut no_hub: Vec<(EventKey, M::Msg)> = Vec::new();
                    loop {
                        barrier.wait(); // window published
                        if done.load(Ordering::Acquire) {
                            return;
                        }
                        let w_end = Ps::ps(w_end_ps.load(Ordering::Acquire));
                        let res = catch_unwind(AssertUnwindSafe(|| {
                            for (j, s) in shards.iter_mut().enumerate() {
                                run_window(
                                    base_shard + j as u32,
                                    s,
                                    w_end,
                                    horizon,
                                    &mut out,
                                    &mut no_hub,
                                );
                            }
                            assert!(
                                no_hub.is_empty(),
                                "model committed messages but no hub is attached: use run_hub"
                            );
                        }));
                        if let Err(payload) = res {
                            let msg = payload
                                .downcast_ref::<String>()
                                .cloned()
                                .or_else(|| {
                                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                                })
                                .unwrap_or_else(|| "shard worker panicked".into());
                            panicked.lock().unwrap().get_or_insert(msg);
                            out.clear();
                            no_hub.clear();
                        }
                        for (dest, key, ev) in out.drain(..) {
                            let owner = dest as usize / chunk;
                            inboxes[owner].lock().unwrap().push((dest, key, ev));
                        }
                        barrier.wait(); // all cross events posted
                        for (dest, key, ev) in inboxes[wi].lock().unwrap().drain(..) {
                            let local = (dest - base_shard) as usize;
                            shards[local].heap.push(Reverse(Entry { key, payload: ev }));
                        }
                        let next = shards
                            .iter()
                            .filter_map(ShardRt::next_time)
                            .fold(Ps::MAX, Ps::min);
                        next_times[wi].store(
                            if next == Ps::MAX { IDLE } else { next.as_ps() },
                            Ordering::Release,
                        );
                        barrier.wait(); // next-times published
                    }
                });
            }

            // Coordinator.
            loop {
                let stop = match t {
                    None => true,
                    Some(at) => at > horizon,
                };
                if stop || panicked.lock().unwrap().is_some() {
                    done.store(true, Ordering::Release);
                    barrier.wait();
                    break;
                }
                let w_end = t.expect("checked above").saturating_add(lookahead);
                w_end_ps.store(w_end.as_ps(), Ordering::Release);
                windows += 1;
                barrier.wait(); // window published
                barrier.wait(); // all cross events posted
                barrier.wait(); // next-times published
                let min = next_times
                    .iter()
                    .map(|a| a.load(Ordering::Acquire))
                    .min()
                    .unwrap_or(IDLE);
                t = (min != IDLE).then(|| Ps::ps(min));
            }
        });
        self.windows = windows;

        if let Some(msg) = panicked.lock().unwrap().take() {
            panic!("shard worker panicked: {msg}");
        }
        match t {
            None => self.drained_result(base),
            Some(_) => RunResult {
                end_time: horizon,
                events: self.total_events() - base,
                drained: false,
            },
        }
    }

    /// Run with a serialized [`Hub`] commit step until both the shard
    /// calendars and the hub drain, or `horizon` is passed. Window
    /// placement extends [`ShardedSim::run`]'s rule with the hub's own
    /// calendar: each window starts at the minimum next event time across
    /// all shards *and* [`Hub::next_time`]. After the shards drain a
    /// window, the hub commits it — consuming the window's sorted
    /// [`Emit::commit`] batch plus its own due events — and its injections
    /// are delivered to the shard inboxes before the next window is placed.
    ///
    /// Horizon semantics: shards stop exactly at `horizon` like
    /// [`ShardedSim::run`]; the hub commits through the end of the window
    /// containing the horizon (window-quantized, identical at every thread
    /// count and in [`ReferenceSim::run_hub`]).
    pub fn run_hub<H: Hub<M>>(
        &mut self,
        horizon: Ps,
        threads: usize,
        hub: &mut H,
    ) -> RunResult {
        self.windows = 0;
        let workers = threads.clamp(1, self.shards.len().max(1));
        if workers <= 1 {
            self.run_hub_serial(horizon, hub)
        } else {
            self.run_hub_parallel(horizon, workers, hub)
        }
    }

    /// Next window start: earliest pending shard event or hub event.
    fn hub_window_start<H: Hub<M>>(&self, hub: &mut H) -> Option<Ps> {
        let shard_t = self.shards.iter().filter_map(ShardRt::next_time).min();
        match (shard_t, hub.next_time()) {
            (None, t) | (t, None) => t,
            (Some(a), Some(b)) => Some(a.min(b)),
        }
    }

    fn run_hub_serial<H: Hub<M>>(&mut self, horizon: Ps, hub: &mut H) -> RunResult {
        let base = self.total_events();
        let mut cross: Vec<(u32, EventKey, M::Ev)> = Vec::new();
        let mut msgs: Vec<(EventKey, M::Msg)> = Vec::new();
        let mut hub_seq: u64 = 0;
        loop {
            let Some(w_start) = self.hub_window_start(hub) else {
                return self.drained_result(base);
            };
            if w_start > horizon {
                return RunResult {
                    end_time: horizon,
                    events: self.total_events() - base,
                    drained: false,
                };
            }
            let w_end = w_start.saturating_add(self.lookahead);
            self.windows += 1;
            for (i, s) in self.shards.iter_mut().enumerate() {
                run_window(i as u32, s, w_end, horizon, &mut cross, &mut msgs);
            }
            for (dest, key, ev) in cross.drain(..) {
                self.shards[dest as usize].heap.push(Reverse(Entry { key, payload: ev }));
            }
            msgs.sort_unstable_by_key(|(k, _)| *k);
            let mut out = HubEmit::new(w_end, hub_seq);
            hub.commit(&msgs, w_end, &mut out);
            hub_seq = out.seq;
            msgs.clear();
            for (dest, key, ev) in out.sends {
                self.shards[dest as usize].heap.push(Reverse(Entry { key, payload: ev }));
            }
        }
    }

    /// Bulk-synchronous hub loop. Per window, four barrier rounds: the
    /// coordinator publishes the window bound; workers drain their shards
    /// and post cross-shard events + commit messages; the coordinator runs
    /// the hub commit serially and posts its injections into the per-owner
    /// inboxes; owners drain their inboxes and publish their next event
    /// time; the coordinator picks the next window start (shards ∪ hub).
    fn run_hub_parallel<H: Hub<M>>(
        &mut self,
        horizon: Ps,
        workers: usize,
        hub: &mut H,
    ) -> RunResult {
        const IDLE: i64 = i64::MAX;
        let base = self.total_events();
        let n = self.shards.len();
        let chunk = n.div_ceil(workers);
        // Size everything on the actual chunk count (see run_parallel).
        let workers = n.div_ceil(chunk);
        let lookahead = self.lookahead;

        let barrier = Barrier::new(workers + 1);
        let done = AtomicBool::new(false);
        let w_end_ps = AtomicI64::new(0);
        let next_times: Vec<AtomicI64> =
            (0..workers).map(|_| AtomicI64::new(IDLE)).collect();
        let inboxes: Vec<Mutex<Vec<(u32, EventKey, M::Ev)>>> =
            (0..workers).map(|_| Mutex::new(Vec::new())).collect();
        let commit_slot: Mutex<Vec<(EventKey, M::Msg)>> = Mutex::new(Vec::new());
        let panicked: Mutex<Option<String>> = Mutex::new(None);

        let mut t = self.hub_window_start(hub);
        let mut windows = 0u64;
        let mut hub_seq = 0u64;
        let mut msgs: Vec<(EventKey, M::Msg)> = Vec::new();
        std::thread::scope(|scope| {
            for (wi, shards) in self.shards.chunks_mut(chunk).enumerate() {
                let base_shard = (wi * chunk) as u32;
                let barrier = &barrier;
                let done = &done;
                let w_end_ps = &w_end_ps;
                let next_times = &next_times;
                let inboxes = &inboxes;
                let commit_slot = &commit_slot;
                let panicked = &panicked;
                scope.spawn(move || {
                    let mut out: Vec<(u32, EventKey, M::Ev)> = Vec::new();
                    let mut local_msgs: Vec<(EventKey, M::Msg)> = Vec::new();
                    loop {
                        barrier.wait(); // window published
                        if done.load(Ordering::Acquire) {
                            return;
                        }
                        let w_end = Ps::ps(w_end_ps.load(Ordering::Acquire));
                        let res = catch_unwind(AssertUnwindSafe(|| {
                            for (j, s) in shards.iter_mut().enumerate() {
                                run_window(
                                    base_shard + j as u32,
                                    s,
                                    w_end,
                                    horizon,
                                    &mut out,
                                    &mut local_msgs,
                                );
                            }
                        }));
                        if let Err(payload) = res {
                            let msg = payload
                                .downcast_ref::<String>()
                                .cloned()
                                .or_else(|| {
                                    payload.downcast_ref::<&str>().map(|s| s.to_string())
                                })
                                .unwrap_or_else(|| "shard worker panicked".into());
                            panicked.lock().unwrap().get_or_insert(msg);
                            out.clear();
                            local_msgs.clear();
                        }
                        for (dest, key, ev) in out.drain(..) {
                            let owner = dest as usize / chunk;
                            inboxes[owner].lock().unwrap().push((dest, key, ev));
                        }
                        if !local_msgs.is_empty() {
                            commit_slot.lock().unwrap().append(&mut local_msgs);
                        }
                        barrier.wait(); // cross events + commit messages posted
                        barrier.wait(); // hub committed, injections posted
                        for (dest, key, ev) in inboxes[wi].lock().unwrap().drain(..) {
                            let local = (dest - base_shard) as usize;
                            shards[local].heap.push(Reverse(Entry { key, payload: ev }));
                        }
                        let next = shards
                            .iter()
                            .filter_map(ShardRt::next_time)
                            .fold(Ps::MAX, Ps::min);
                        next_times[wi].store(
                            if next == Ps::MAX { IDLE } else { next.as_ps() },
                            Ordering::Release,
                        );
                        barrier.wait(); // next-times published
                    }
                });
            }

            // Coordinator: window placement + the serialized hub commit.
            loop {
                let stop = match t {
                    None => true,
                    Some(at) => at > horizon,
                };
                if stop || panicked.lock().unwrap().is_some() {
                    done.store(true, Ordering::Release);
                    barrier.wait();
                    break;
                }
                let w_end = t.expect("checked above").saturating_add(lookahead);
                w_end_ps.store(w_end.as_ps(), Ordering::Release);
                windows += 1;
                barrier.wait(); // window published
                barrier.wait(); // cross events + commit messages posted
                msgs.append(&mut commit_slot.lock().unwrap());
                msgs.sort_unstable_by_key(|(k, _)| *k);
                let mut hub_out = HubEmit::new(w_end, hub_seq);
                let res = catch_unwind(AssertUnwindSafe(|| {
                    hub.commit(&msgs, w_end, &mut hub_out);
                }));
                if let Err(payload) = res {
                    let msg = payload
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "hub commit panicked".into());
                    panicked.lock().unwrap().get_or_insert(msg);
                    hub_out.sends.clear();
                }
                hub_seq = hub_out.seq;
                msgs.clear();
                for (dest, key, ev) in hub_out.sends {
                    let owner = dest as usize / chunk;
                    inboxes[owner].lock().unwrap().push((dest, key, ev));
                }
                barrier.wait(); // hub committed, injections posted
                barrier.wait(); // next-times published
                let min = next_times
                    .iter()
                    .map(|a| a.load(Ordering::Acquire))
                    .min()
                    .unwrap_or(IDLE);
                let shard_next = (min != IDLE).then(|| Ps::ps(min));
                t = match (shard_next, hub.next_time()) {
                    (None, t) | (t, None) => t,
                    (Some(a), Some(b)) => Some(a.min(b)),
                };
            }
        });
        self.windows = windows;

        if let Some(msg) = panicked.lock().unwrap().take() {
            panic!("shard worker panicked: {msg}");
        }
        match t {
            None => self.drained_result(base),
            Some(_) => RunResult {
                end_time: horizon,
                events: self.total_events() - base,
                drained: false,
            },
        }
    }
}

/// Single-calendar oracle for [`ShardedSim`]: processes the *same* shard
/// models in strict global key order on one heap, with no windows at all.
/// Because keys are assigned identically, a correct `ShardedSim` run matches
/// this executor bit-for-bit — the randomized oracle test relies on it.
pub struct ReferenceSim<M: ShardModel> {
    models: Vec<M>,
    seqs: Vec<u64>,
    heap: BinaryHeap<Reverse<Entry<(u32, M::Ev)>>>,
    seed_seq: u64,
    events: u64,
    last: Ps,
}

impl<M: ShardModel> ReferenceSim<M> {
    pub fn new(models: Vec<M>) -> Self {
        let seqs = vec![0; models.len()];
        ReferenceSim {
            models,
            seqs,
            heap: BinaryHeap::new(),
            seed_seq: 0,
            events: 0,
            last: Ps::ZERO,
        }
    }

    /// Seed an initial event (key scheme identical to [`ShardedSim::seed`]).
    pub fn seed(&mut self, shard: u32, at: Ps, ev: M::Ev) {
        let key = EventKey { at, src: SEED_SRC, seq: self.seed_seq };
        self.seed_seq += 1;
        self.heap.push(Reverse(Entry { key, payload: (shard, ev) }));
    }

    pub fn model(&self, shard: u32) -> &M {
        &self.models[shard as usize]
    }

    pub fn models(&self) -> impl Iterator<Item = &M> {
        self.models.iter()
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    pub fn run(&mut self, horizon: Ps) -> RunResult {
        let base = self.events;
        loop {
            let Some(at) = self.heap.peek().map(|e| e.0.key.at) else {
                return RunResult {
                    end_time: self.last,
                    events: self.events - base,
                    drained: true,
                };
            };
            if at > horizon {
                return RunResult {
                    end_time: horizon,
                    events: self.events - base,
                    drained: false,
                };
            }
            let Reverse(Entry { key, payload: (dest, ev) }) =
                self.heap.pop().expect("peeked entry");
            let d = dest as usize;
            // w_end = ZERO disables the window check: the oracle has no
            // windows, so every cross-shard latency is admissible here.
            let mut emit = Emit::new(dest, key.at, Ps::ZERO, self.seqs[d]);
            self.models[d].handle(key.at, ev, &mut emit);
            assert!(
                emit.commits.is_empty(),
                "model committed messages but no hub is attached: use run_hub"
            );
            self.seqs[d] = emit.seq;
            self.events += 1;
            self.last = key.at;
            for (k, e) in emit.local {
                self.heap.push(Reverse(Entry { key: k, payload: (dest, e) }));
            }
            for (d2, k, e) in emit.cross {
                self.heap.push(Reverse(Entry { key: k, payload: (d2, e) }));
            }
        }
    }

    /// Single-heap oracle for [`ShardedSim::run_hub`]: identical window
    /// placement and commit batching, but every shard event pops off one
    /// global calendar in strict key order. A correct hub-coupled sharded
    /// run matches this executor bit-for-bit at any thread count.
    pub fn run_hub<H: Hub<M>>(
        &mut self,
        horizon: Ps,
        lookahead: Ps,
        hub: &mut H,
    ) -> RunResult {
        assert!(lookahead > Ps::ZERO, "lookahead must be positive");
        let base = self.events;
        let mut msgs: Vec<(EventKey, M::Msg)> = Vec::new();
        let mut hub_seq: u64 = 0;
        loop {
            let heap_t = self.heap.peek().map(|e| e.0.key.at);
            let w_start = match (heap_t, hub.next_time()) {
                (None, None) => {
                    return RunResult {
                        end_time: self.last,
                        events: self.events - base,
                        drained: true,
                    };
                }
                (None, t) | (t, None) => t.expect("one side pending"),
                (Some(a), Some(b)) => a.min(b),
            };
            if w_start > horizon {
                return RunResult {
                    end_time: horizon,
                    events: self.events - base,
                    drained: false,
                };
            }
            let w_end = w_start.saturating_add(lookahead);
            while let Some(at) = self.heap.peek().map(|e| e.0.key.at) {
                if at >= w_end || at > horizon {
                    break;
                }
                let Reverse(Entry { key, payload: (dest, ev) }) =
                    self.heap.pop().expect("peeked entry");
                let d = dest as usize;
                let mut emit = Emit::new(dest, key.at, w_end, self.seqs[d]);
                self.models[d].handle(key.at, ev, &mut emit);
                self.seqs[d] = emit.seq;
                self.events += 1;
                self.last = key.at;
                for (k, e) in emit.local {
                    self.heap.push(Reverse(Entry { key: k, payload: (dest, e) }));
                }
                for (d2, k, e) in emit.cross {
                    self.heap.push(Reverse(Entry { key: k, payload: (d2, e) }));
                }
                msgs.append(&mut emit.commits);
            }
            // Global pop order is (time, event-src, seq) — not the
            // (time, handler-shard, seq) order of the commit keys — so the
            // batch still needs the sort the sharded executors apply.
            msgs.sort_unstable_by_key(|(k, _)| *k);
            let mut out = HubEmit::new(w_end, hub_seq);
            hub.commit(&msgs, w_end, &mut out);
            hub_seq = out.seq;
            msgs.clear();
            for (dest, key, ev) in out.sends {
                self.heap.push(Reverse(Entry { key, payload: (dest, ev) }));
            }
        }
    }
}

/// Window-partitioned driver for an ordinary [`Model`] on the global
/// calendar. Dispatch order is exactly [`Engine::run`]'s `(time, seq)`
/// order — windows only group the timeline — so any model produces
/// bit-identical results under this engine at any `threads` setting. The
/// window count it records measures how many synchronization rounds a
/// sharded execution of the same run would need at this lookahead.
///
/// [`Engine::run`]: crate::sim::engine::Engine::run
pub struct WindowedEngine {
    lookahead: Ps,
    windows: u64,
}

impl WindowedEngine {
    pub fn new(lookahead: Ps) -> Self {
        assert!(lookahead > Ps::ZERO, "lookahead must be positive");
        WindowedEngine { lookahead, windows: 0 }
    }

    /// Windows advanced by the most recent [`WindowedEngine::run`].
    pub fn windows(&self) -> u64 {
        self.windows
    }

    /// Drop-in replacement for [`Engine::run`] with identical semantics
    /// (horizon resumability, stop-mid-batch, same-time FIFO batches).
    ///
    /// [`Engine::run`]: crate::sim::engine::Engine::run
    pub fn run<M: Model>(
        &mut self,
        model: &mut M,
        sched: &mut Scheduler<M::Ev>,
        horizon: Ps,
    ) -> RunResult {
        self.windows = 0;
        let mut events: u64 = 0;
        let mut w_end: Option<Ps> = None;
        loop {
            if sched.is_stopped() {
                return RunResult { end_time: sched.now(), events, drained: false };
            }
            let Some(at) = sched.peek_next_time() else {
                return RunResult { end_time: sched.now(), events, drained: true };
            };
            if at > horizon {
                sched.set_now(horizon);
                return RunResult { end_time: horizon, events, drained: false };
            }
            if w_end.map_or(true, |we| at >= we) {
                w_end = Some(at.saturating_add(self.lookahead));
                self.windows += 1;
            }
            sched.set_now(at);
            while let Some(ev) = sched.pop_at(at) {
                events += 1;
                model.handle(sched, ev);
                if sched.is_stopped() {
                    return RunResult { end_time: sched.now(), events, drained: false };
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::Engine;

    /// Shard-local churn with a periodic cross-shard credit: each Tick(n)
    /// schedules Tick(n-1) locally and, every 4th tick, credits the next
    /// shard one lookahead later (the minimal legal cross latency).
    #[derive(Debug, Clone, PartialEq, Eq)]
    struct Churn {
        shards: u32,
        lookahead: Ps,
        fired: Vec<(Ps, u32)>,
        credits: u32,
    }
    #[derive(Debug, Clone, Copy)]
    enum CEv {
        Tick(u32),
        Credit,
    }
    impl ShardModel for Churn {
        type Ev = CEv;
        type Msg = ();
        fn handle(&mut self, now: Ps, ev: CEv, out: &mut Emit<CEv>) {
            match ev {
                CEv::Tick(n) => {
                    self.fired.push((now, n));
                    if n > 0 {
                        out.local_after(Ps::ns(10), CEv::Tick(n - 1));
                        if n % 4 == 0 {
                            let dest = (out.shard() + 1) % self.shards;
                            out.send_after(dest, self.lookahead, CEv::Credit);
                        }
                    }
                }
                CEv::Credit => self.credits += 1,
            }
        }
    }

    fn churn_models(shards: u32, lookahead: Ps) -> Vec<Churn> {
        (0..shards)
            .map(|_| Churn { shards, lookahead, fired: vec![], credits: 0 })
            .collect()
    }

    fn seeded(shards: u32, lookahead: Ps) -> ShardedSim<Churn> {
        let mut sim = ShardedSim::new(churn_models(shards, lookahead), lookahead);
        for s in 0..shards {
            sim.seed(s, Ps::ns(s as i64), CEv::Tick(20 + s));
        }
        sim
    }

    #[test]
    fn serial_matches_reference() {
        let la = Ps::ns(25);
        let mut sharded = seeded(4, la);
        let mut oracle = ReferenceSim::new(churn_models(4, la));
        for s in 0..4 {
            oracle.seed(s, Ps::ns(s as i64), CEv::Tick(20 + s));
        }
        let r1 = sharded.run(Ps::ms(1), 1);
        let r2 = oracle.run(Ps::ms(1));
        assert_eq!(r1, r2);
        assert!(r1.drained);
        for s in 0..4 {
            assert_eq!(sharded.model(s), oracle.model(s), "shard {s} state diverged");
        }
        assert!(sharded.windows() > 1, "multi-window run expected");
    }

    #[test]
    fn parallel_matches_serial_bit_for_bit() {
        let la = Ps::ns(25);
        let mut serial = seeded(8, la);
        let r_serial = serial.run(Ps::ms(1), 1);
        for threads in [2, 3, 4, 8] {
            let mut par = seeded(8, la);
            let r_par = par.run(Ps::ms(1), threads);
            assert_eq!(r_serial, r_par, "threads={threads}");
            for s in 0..8 {
                assert_eq!(serial.model(s), par.model(s), "threads={threads} shard {s}");
            }
        }
    }

    #[test]
    fn horizon_cuts_and_resumes() {
        let la = Ps::ns(25);
        for threads in [1, 2] {
            let mut sim = seeded(4, la);
            let r1 = sim.run(Ps::ns(50), threads);
            assert!(!r1.drained, "threads={threads}");
            assert_eq!(r1.end_time, Ps::ns(50));
            assert!(sim.pending() > 0, "beyond-horizon events must stay queued");
            let r2 = sim.run(Ps::ms(1), threads);
            assert!(r2.drained);
            // The two-leg run dispatches exactly what one long run does.
            let mut whole = seeded(4, la);
            let rw = whole.run(Ps::ms(1), threads);
            assert_eq!(r1.events + r2.events, rw.events);
            assert_eq!(r2.end_time, rw.end_time);
            for s in 0..4 {
                assert_eq!(sim.model(s), whole.model(s));
            }
        }
    }

    #[test]
    #[should_panic(expected = "lookahead violation")]
    fn cross_send_inside_window_panics() {
        struct Bad;
        impl ShardModel for Bad {
            type Ev = ();
            type Msg = ();
            fn handle(&mut self, _now: Ps, _ev: (), out: &mut Emit<()>) {
                // Lookahead is 100ns but the send lands 1ns out: illegal.
                out.send_after(1, Ps::ns(1), ());
            }
        }
        let mut sim = ShardedSim::new(vec![Bad, Bad], Ps::ns(100));
        sim.seed(0, Ps::ZERO, ());
        sim.run(Ps::ms(1), 1);
    }

    #[test]
    #[should_panic(expected = "shard worker panicked")]
    fn parallel_worker_panic_propagates_without_hanging() {
        struct Bad;
        impl ShardModel for Bad {
            type Ev = ();
            type Msg = ();
            fn handle(&mut self, _now: Ps, _ev: (), out: &mut Emit<()>) {
                out.send_after(1, Ps::ns(1), ());
            }
        }
        let mut sim = ShardedSim::new(vec![Bad, Bad], Ps::ns(100));
        sim.seed(0, Ps::ZERO, ());
        sim.run(Ps::ms(1), 2);
    }

    #[test]
    fn fully_local_model_runs_in_one_window_per_burst() {
        // No cross events and a huge lookahead: everything fits one window.
        #[derive(Debug, PartialEq)]
        struct Local {
            sum: u64,
        }
        impl ShardModel for Local {
            type Ev = u32;
            type Msg = ();
            fn handle(&mut self, _now: Ps, ev: u32, out: &mut Emit<u32>) {
                self.sum += ev as u64;
                if ev > 0 {
                    out.local_after(Ps::ns(5), ev - 1);
                }
            }
        }
        let mut sim = ShardedSim::new(
            vec![Local { sum: 0 }, Local { sum: 0 }],
            Ps::ms(10),
        );
        sim.seed(0, Ps::ZERO, 100u32);
        sim.seed(1, Ps::ZERO, 100u32);
        let r = sim.run(Ps::ms(1), 2);
        assert!(r.drained);
        assert_eq!(r.events, 202);
        assert_eq!(sim.windows(), 1);
        assert_eq!(sim.model(0).sum, 5050);
    }

    // --- Hub-coupled execution: serialized commit step at boundaries ---

    /// Shard-local countdown that reports every third tick to the hub.
    #[derive(Debug, Clone, PartialEq, Eq)]
    struct HubChurn {
        fired: Vec<(Ps, u32)>,
    }
    impl ShardModel for HubChurn {
        type Ev = u32;
        type Msg = u32;
        fn handle(&mut self, now: Ps, ev: u32, out: &mut Emit<u32, u32>) {
            self.fired.push((now, ev));
            if ev % 3 == 0 {
                out.commit(ev);
            }
            if ev > 0 {
                out.local_after(Ps::ns(7), ev - 1);
            }
        }
    }

    /// Toy hub: seeds initial work from its own calendar, then hands out a
    /// bounded budget of fresh work round-robin as completions arrive.
    struct TestHub {
        shards: u32,
        rr: u32,
        budget: u32,
        timer: Option<Ps>,
        log: Vec<(Ps, u32, u32)>,
    }
    impl Hub<HubChurn> for TestHub {
        fn next_time(&mut self) -> Option<Ps> {
            self.timer
        }
        fn commit(&mut self, msgs: &[(EventKey, u32)], w_end: Ps, out: &mut HubEmit<u32>) {
            if self.timer.is_some_and(|t| t < w_end) {
                self.timer = None;
                for s in 0..self.shards {
                    out.send_at(s, w_end, 6 + s);
                }
            }
            for (key, v) in msgs {
                self.log.push((key.at, key.src, *v));
                if self.budget > 0 {
                    self.budget -= 1;
                    out.send_at(self.rr % self.shards, w_end + Ps::ns(3), 5);
                    self.rr += 1;
                }
            }
        }
    }

    fn hub_models(shards: u32) -> Vec<HubChurn> {
        (0..shards).map(|_| HubChurn { fired: vec![] }).collect()
    }

    fn test_hub(shards: u32) -> TestHub {
        TestHub { shards, rr: 1, budget: 40, timer: Some(Ps::ns(2)), log: vec![] }
    }

    #[test]
    fn hub_serial_matches_reference() {
        let la = Ps::ns(25);
        let mut sharded = ShardedSim::new(hub_models(4), la);
        let mut h1 = test_hub(4);
        let r1 = sharded.run_hub(Ps::ms(1), 1, &mut h1);
        assert!(r1.drained);
        assert!(!h1.log.is_empty(), "hub must have consumed completions");
        assert_eq!(h1.budget, 0, "budget must drain in a 1ms run");

        let mut oracle = ReferenceSim::new(hub_models(4));
        let mut h2 = test_hub(4);
        let r2 = oracle.run_hub(Ps::ms(1), la, &mut h2);
        assert_eq!(r1, r2);
        assert_eq!(h1.log, h2.log, "hub commit order diverged");
        for s in 0..4 {
            assert_eq!(sharded.model(s), oracle.model(s), "shard {s} state diverged");
        }
    }

    #[test]
    fn hub_parallel_matches_serial_bit_for_bit() {
        let la = Ps::ns(25);
        let mut serial = ShardedSim::new(hub_models(8), la);
        let mut hs = test_hub(8);
        let r_serial = serial.run_hub(Ps::ms(1), 1, &mut hs);
        for threads in [2, 3, 4, 8] {
            let mut par = ShardedSim::new(hub_models(8), la);
            let mut hp = test_hub(8);
            let r_par = par.run_hub(Ps::ms(1), threads, &mut hp);
            assert_eq!(r_serial, r_par, "threads={threads}");
            assert_eq!(hs.log, hp.log, "threads={threads} hub log diverged");
            for s in 0..8 {
                assert_eq!(serial.model(s), par.model(s), "threads={threads} shard {s}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "hub lookahead violation")]
    fn hub_injection_inside_window_panics() {
        struct BadHub;
        impl Hub<HubChurn> for BadHub {
            fn next_time(&mut self) -> Option<Ps> {
                None
            }
            fn commit(&mut self, _m: &[(EventKey, u32)], w_end: Ps, out: &mut HubEmit<u32>) {
                out.send_at(0, w_end - Ps::ns(1), 1);
            }
        }
        let mut sim = ShardedSim::new(hub_models(2), Ps::ns(100));
        sim.seed(0, Ps::ZERO, 1);
        sim.run_hub(Ps::ms(1), 1, &mut BadHub);
    }

    #[test]
    #[should_panic(expected = "no hub is attached")]
    fn commit_without_hub_panics() {
        let mut sim = ShardedSim::new(hub_models(2), Ps::ns(100));
        sim.seed(0, Ps::ZERO, 3); // 3 % 3 == 0 -> commits
        sim.run(Ps::ms(1), 1);
    }

    // --- WindowedEngine: bit-identity with Engine on an ordinary Model ---

    struct Recorder {
        order: Vec<(Ps, u32)>,
    }
    impl Model for Recorder {
        type Ev = u32;
        fn handle(&mut self, s: &mut Scheduler<u32>, ev: u32) {
            self.order.push((s.now(), ev));
            if ev % 3 == 0 && ev > 0 {
                s.now_ev(ev + 1000); // same-timestamp follow-up
            }
            if ev < 40 {
                s.after(Ps::ns((ev as i64 % 7) * 3), ev + 1);
            }
        }
    }

    fn recorder_seeds(s: &mut Scheduler<u32>) {
        for i in 0..6 {
            s.at(Ps::ns(i as i64 % 2), i); // duplicate timestamps on purpose
        }
    }

    #[test]
    fn windowed_engine_is_bit_identical_to_engine() {
        let mut m1 = Recorder { order: vec![] };
        let mut s1 = Scheduler::new();
        recorder_seeds(&mut s1);
        let r1 = Engine::run(&mut m1, &mut s1, Ps::ms(1));

        for la in [Ps::ps(1), Ps::ns(2), Ps::ns(50), Ps::ms(100)] {
            let mut m2 = Recorder { order: vec![] };
            let mut s2 = Scheduler::new();
            recorder_seeds(&mut s2);
            let mut we = WindowedEngine::new(la);
            let r2 = we.run(&mut m2, &mut s2, Ps::ms(1));
            assert_eq!(r1, r2, "lookahead {la}");
            assert_eq!(m1.order, m2.order, "dispatch order diverged at {la}");
            assert!(we.windows() >= 1);
        }
    }

    #[test]
    fn windowed_engine_honors_horizon_and_resume() {
        let mut m1 = Recorder { order: vec![] };
        let mut s1 = Scheduler::new();
        recorder_seeds(&mut s1);
        let a1 = Engine::run(&mut m1, &mut s1, Ps::ns(20));
        let a2 = Engine::run(&mut m1, &mut s1, Ps::ms(1));

        let mut m2 = Recorder { order: vec![] };
        let mut s2 = Scheduler::new();
        recorder_seeds(&mut s2);
        let mut we = WindowedEngine::new(Ps::ns(7));
        let b1 = we.run(&mut m2, &mut s2, Ps::ns(20));
        let b2 = we.run(&mut m2, &mut s2, Ps::ms(1));
        assert_eq!((a1, a2), (b1, b2));
        assert_eq!(m1.order, m2.order);
    }

    #[test]
    fn windowed_engine_stop_mid_batch() {
        struct StopAt2 {
            seen: Vec<u32>,
        }
        impl Model for StopAt2 {
            type Ev = u32;
            fn handle(&mut self, s: &mut Scheduler<u32>, ev: u32) {
                self.seen.push(ev);
                if ev == 2 {
                    s.stop();
                }
            }
        }
        let mut m = StopAt2 { seen: vec![] };
        let mut s = Scheduler::new();
        for i in 0..10 {
            s.at(Ps::ns(5), i);
        }
        let mut we = WindowedEngine::new(Ps::ns(1));
        let r = we.run(&mut m, &mut s, Ps::ms(1));
        assert_eq!(r.events, 3);
        assert_eq!(m.seen, vec![0, 1, 2]);
        assert_eq!(s.pending(), 7);
    }

    #[test]
    fn window_count_scales_with_lookahead() {
        // Ticks every 10ns for 400ns: lookahead 25ns ≈ 3 ticks/window,
        // lookahead 1ms = 1 window.
        let mk = || {
            let mut m = Recorder { order: vec![] };
            let mut s = Scheduler::new();
            s.at(Ps::ZERO, 0u32);
            (m, s)
        };
        let (mut m1, mut s1) = mk();
        let mut narrow = WindowedEngine::new(Ps::ns(25));
        narrow.run(&mut m1, &mut s1, Ps::ms(1));
        let (mut m2, mut s2) = mk();
        let mut wide = WindowedEngine::new(Ps::ms(100));
        wide.run(&mut m2, &mut s2, Ps::ms(1));
        assert!(narrow.windows() > wide.windows());
        assert_eq!(wide.windows(), 1);
    }
}
