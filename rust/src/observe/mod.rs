//! Bottleneck observability: per-phase occupancy accounting, stall-cause
//! attribution and Perfetto-loadable timelines over the DES hot path.
//!
//! The paper's headline claim is *architectural*: way interleaving
//! multiplexes the channel bus until the bus — not the NAND cells — is the
//! bottleneck (§2.2.1), and the DDR interface relieves exactly that
//! contention. Proving the reproduction exhibits the same bottleneck
//! structure needs more than bandwidth numbers; it needs to know, for every
//! resource and every picosecond, *what the resource was doing and why*.
//! This module is the busperf-style analyzer layer the ROADMAP names: it
//! partitions each resource's wall clock into four exhaustive, mutually
//! exclusive occupancy states and attributes every way-stall to a cause.
//!
//! ## Occupancy model
//!
//! Resource state in the DES is **piecewise-constant between events**: the
//! only writes to channel/way/chip state happen inside
//! [`crate::coordinator::ssd::SsdSim`]'s event handler. The observer
//! therefore needs no per-transition callbacks for correctness — after each
//! event it closes the interval `[last_t, now)` under the classification
//! recorded by the *previous* scan, then reclassifies every resource from
//! the post-event state. Same-timestamp event batches degenerate to
//! zero-length intervals where the last reclassification wins, which is
//! exactly right: the intermediate micro-states never occupied simulated
//! time. Because the partition is exhaustive, per resource the four
//! accumulators sum to the wall clock **exactly, in integer picoseconds** —
//! the randomized oracle in `rust/tests/observe.rs` enforces this.
//!
//! Per resource the states are:
//!
//! * **busy** — doing productive work (bus: a granted phase; way: owns the
//!   bus or its array is working; chip: array op in flight),
//! * **blocked** — has work ready but the shared bus is granted to a
//!   *different* way (ways only; buses and chips never block),
//! * **idle-queued** — work is pending but nothing is actively held back
//!   (bus free-but-ungranted transients, a chip whose page register waits
//!   for its data-out phase),
//! * **idle** — nothing to do.
//!
//! Way stalls are attributed to five causes: **bus contention** (blocked
//! behind another way's *host* traffic), **GC barrier** (blocked behind
//! GC / wear-leveling / migration / flush copy-back), **map fill**
//! (blocked behind the demand-paged mapping tier's translation-page
//! fills/write-backs, [`crate::controller::ftl::demand`]), **queue
//! starvation** (idle with the host link also idle — the host simply
//! isn't sending enough work) and **link backpressure** (idle while the
//! host link is saturated — the bottleneck is in front of the device).
//! The cause sums tie out: contention + barrier + map fill = Σ way
//! blocked, starvation + backpressure = Σ way idle.
//!
//! ## Why observation cannot perturb the simulation
//!
//! [`ObsState`] holds no scheduler handle: `scan` takes `&[ChannelState]`
//! and a [`HostView`] by value, reads, and returns. It never enqueues an
//! event, never mutates simulator state, and is consulted *after* the
//! event dispatch it observes. Disabled, the per-event cost is one
//! `Option` discriminant test. The golden tests in
//! `rust/tests/observe.rs` hold every existing scenario bit-identical
//! with observation on and off.
//!
//! ## Sinks
//!
//! [`ObserveReport`] carries the per-resource table (rendered as CSV by
//! `ddrnand analyze --csv` and summarized by [`crate::report::summarize`])
//! and, when `[observe] timeline = true`, a Chrome trace-event JSON
//! timeline: one Perfetto process per channel, tracks for the bus, each
//! way and each chip, instant marks for GC triggers and the windowed
//! engine's time-grid boundaries. [`validate_trace_json`] pins the schema.

use crate::controller::channel::ChannelState;
use crate::controller::way::PageJobKind;
use crate::iface::bus::BusPhaseKind;
use crate::util::time::Ps;

/// Occupancy states (indices into the per-resource accumulators).
const BUSY: u8 = 0;
const BLOCKED: u8 = 1;
const IDLE_QUEUED: u8 = 2;
const IDLE: u8 = 3;

/// Way stall/idle causes (valid only for the state they annotate).
const CAUSE_CONTENTION: u8 = 0;
const CAUSE_BARRIER: u8 = 1;
const CAUSE_STARVED: u8 = 2;
const CAUSE_BACKPRESSURE: u8 = 3;
const CAUSE_MAPFILL: u8 = 4;

/// Who holds a granted bus phase, for stall attribution: host data,
/// internal copy-back (GC / wear leveling / migration / cache flush), or
/// the demand-paged mapping tier's translation-page traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BusUser {
    Host,
    Internal,
    MapFill,
}

/// Which resource a utilization row describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceKind {
    /// The shared channel bus (NAND_IF + ECC).
    Bus,
    /// A way: the per-chip queue + phase machine multiplexed on the bus.
    Way,
    /// The NAND array behind a way.
    Chip,
}

impl ResourceKind {
    pub fn name(self) -> &'static str {
        match self {
            ResourceKind::Bus => "bus",
            ResourceKind::Way => "way",
            ResourceKind::Chip => "chip",
        }
    }
}

/// One resource's wall-clock partition. The four accumulators sum to the
/// report's `wall_ps` exactly (integer picoseconds; oracle-tested).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceUsage {
    pub channel: u16,
    pub kind: ResourceKind,
    /// Way index for `Way`/`Chip` rows; 0 for the bus.
    pub index: u16,
    pub busy_ps: u64,
    pub blocked_ps: u64,
    pub idle_queued_ps: u64,
    pub idle_ps: u64,
}

impl ResourceUsage {
    fn from_acc(channel: u16, kind: ResourceKind, index: u16, acc: &[u64; 4]) -> ResourceUsage {
        ResourceUsage {
            channel,
            kind,
            index,
            busy_ps: acc[BUSY as usize],
            blocked_ps: acc[BLOCKED as usize],
            idle_queued_ps: acc[IDLE_QUEUED as usize],
            idle_ps: acc[IDLE as usize],
        }
    }

    /// busy + blocked + idle-queued + idle (= wall clock).
    pub fn total_ps(&self) -> u64 {
        self.busy_ps + self.blocked_ps + self.idle_queued_ps + self.idle_ps
    }
}

/// Attributed way-stall totals, summed over every way.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct StallCauses {
    /// Blocked behind another way's *host* bus phase.
    pub bus_contention_ps: u64,
    /// Blocked behind GC / wear-leveling / migration / flush copy-back.
    pub gc_barrier_ps: u64,
    /// Blocked behind the mapping tier's translation-page fill reads and
    /// write-back programs (zero for fully-resident mapping).
    pub map_fill_ps: u64,
    /// Idle with the host link also idle: not enough offered work.
    pub queue_starvation_ps: u64,
    /// Idle while the host link is saturated: the bottleneck is upstream.
    pub link_backpressure_ps: u64,
}

/// The observer's end-of-run output, attached to
/// [`crate::coordinator::campaign::SimReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct ObserveReport {
    /// Observed wall clock: the later of the last host completion and the
    /// last simulated event (background GC may drain past the last host
    /// completion; its occupancy is real and is counted).
    pub wall_ps: u64,
    /// Per channel: the bus row, then a row per way, then a row per chip.
    pub resources: Vec<ResourceUsage>,
    pub stalls: StallCauses,
    /// GC activations observed (write plans that triggered a collection).
    pub gc_triggers: u64,
    /// Chrome trace-event JSON (`[observe] timeline = true` only).
    pub trace_json: Option<String>,
}

impl ObserveReport {
    /// Summed `[busy, blocked, idle_queued, idle]` over all rows of `kind`.
    pub fn totals(&self, kind: ResourceKind) -> [u64; 4] {
        let mut t = [0u64; 4];
        for r in self.resources.iter().filter(|r| r.kind == kind) {
            t[0] += r.busy_ps;
            t[1] += r.blocked_ps;
            t[2] += r.idle_queued_ps;
            t[3] += r.idle_ps;
        }
        t
    }

    fn share(&self, kind: ResourceKind, state: usize) -> f64 {
        let t = self.totals(kind);
        let total: u64 = t.iter().sum();
        if total == 0 {
            return 0.0;
        }
        t[state] as f64 / total as f64
    }

    /// Fraction of `kind`'s aggregate wall clock spent busy.
    pub fn busy_fraction(&self, kind: ResourceKind) -> f64 {
        self.share(kind, BUSY as usize)
    }

    /// Fraction of `kind`'s aggregate wall clock spent busy-but-blocked.
    /// The paper's way-interleaving saturation claim is this number: CONV's
    /// slow bus keeps ways blocked; PROPOSED's DDR bus relieves them
    /// (`rust/tests/observe.rs` asserts the strict ordering on E2's grid).
    pub fn blocked_share(&self, kind: ResourceKind) -> f64 {
        self.share(kind, BLOCKED as usize)
    }

    /// Fraction of `kind`'s aggregate wall clock spent idle-with-work.
    pub fn idle_queued_share(&self, kind: ResourceKind) -> f64 {
        self.share(kind, IDLE_QUEUED as usize)
    }
}

/// A read-only snapshot of the host front end at scan time.
#[derive(Debug, Clone, Copy)]
pub struct HostView {
    /// Is the host link's serialized transport occupied right now?
    pub link_busy: bool,
}

/// One buffered timeline event (Chrome trace-event `B`/`E`/`i`).
#[derive(Debug, Clone, Copy)]
struct TraceEvent {
    name: &'static str,
    ph: u8,
    ts: Ps,
    pid: u16,
    tid: u16,
}

/// Timeline buffer: spans and instants in per-track timestamp order.
#[derive(Debug)]
struct TimelineBuf {
    events: Vec<TraceEvent>,
    /// Windowed-engine time-grid pitch (the conservative lookahead).
    window: Ps,
    next_window: Ps,
}

/// The live observer: per-resource occupancy accounting over one run.
/// Built by [`crate::coordinator::ssd::SsdSim`] when `[observe] enabled`;
/// read-only over the simulation state (see the module docs for why this
/// cannot perturb dispatch order).
#[derive(Debug)]
pub struct ObsState {
    channels: usize,
    ways: usize,
    /// Close of the last accumulated interval.
    last_t: Ps,
    /// Observed wall clock (set by [`finalize`](Self::finalize)).
    wall: Ps,
    /// Current classification per resource (the state the *open* interval
    /// will be charged to).
    bus_state: Vec<u8>,
    way_state: Vec<u8>,
    way_cause: Vec<u8>,
    chip_state: Vec<u8>,
    /// `[busy, blocked, idle_queued, idle]` picoseconds per resource.
    bus_acc: Vec<[u64; 4]>,
    way_acc: Vec<[u64; 4]>,
    chip_acc: Vec<[u64; 4]>,
    stalls: StallCauses,
    /// Mirror of the DES bus grant: `(way, user)` per channel. The user
    /// drives stall attribution: internal traffic raises the GC barrier,
    /// map-fill traffic its own cause.
    bus_owner: Vec<Option<(u16, BusUser)>>,
    gc_triggers: u64,
    timeline: Option<TimelineBuf>,
}

impl ObsState {
    /// `window` is the sharded executor's lookahead (the timeline's
    /// time-grid pitch); only consulted when `timeline` is on.
    pub fn new(channels: usize, ways: usize, timeline: bool, window: Ps) -> ObsState {
        let nways = channels * ways;
        ObsState {
            channels,
            ways,
            last_t: Ps::ZERO,
            wall: Ps::ZERO,
            bus_state: vec![IDLE; channels],
            way_state: vec![IDLE; nways],
            way_cause: vec![CAUSE_STARVED; nways],
            chip_state: vec![IDLE; nways],
            bus_acc: vec![[0; 4]; channels],
            way_acc: vec![[0; 4]; nways],
            chip_acc: vec![[0; 4]; nways],
            stalls: StallCauses::default(),
            bus_owner: vec![None; channels],
            gc_triggers: 0,
            timeline: timeline.then(|| TimelineBuf {
                events: Vec::new(),
                window,
                next_window: window,
            }),
        }
    }

    // Track ids within a channel's process: bus, ways, chips, then the two
    // mark tracks (separate so each track's timestamps stay monotone —
    // span ends are pushed ahead of time, instants are not).
    fn tid_bus(&self) -> u16 {
        0
    }
    fn tid_way(&self, w: u16) -> u16 {
        1 + w
    }
    fn tid_chip(&self, w: u16) -> u16 {
        1 + self.ways as u16 + w
    }
    fn tid_gc(&self) -> u16 {
        1 + 2 * self.ways as u16
    }
    fn tid_window(&self) -> u16 {
        2 + 2 * self.ways as u16
    }

    fn push_event(&mut self, ev: TraceEvent) {
        if let Some(tl) = self.timeline.as_mut() {
            tl.events.push(ev);
        }
    }

    /// Close the open interval at `now` under the previous classification,
    /// then reclassify every resource from the post-event state. Called by
    /// the coordinator after each event dispatch.
    pub fn scan(&mut self, now: Ps, channels: &[ChannelState], host: HostView) {
        debug_assert!(now >= self.last_t, "time ran backwards: {now} < {}", self.last_t);
        if now > self.last_t {
            let dt = (now - self.last_t).as_ps() as u64;
            self.accumulate(dt);
            self.last_t = now;
        }
        // Time-grid marks: one instant per crossed window boundary batch
        // (the latest multiple <= now), on its own track so timestamps stay
        // monotone. These are *derived* marks — the grid the windowed
        // engine would use — emitted even under the serial engine so the
        // two timelines line up.
        let pitch = match self.timeline.as_ref() {
            Some(tl) if tl.window > Ps::ZERO && now >= tl.next_window => tl.window,
            _ => Ps::ZERO,
        };
        if pitch > Ps::ZERO {
            let mark = Ps::ps((now.as_ps() / pitch.as_ps()) * pitch.as_ps());
            let tid = self.tid_window();
            let tl = self.timeline.as_mut().expect("checked above");
            tl.events.push(TraceEvent {
                name: "window",
                ph: b'i',
                ts: mark,
                pid: 0,
                tid,
            });
            tl.next_window = mark + pitch;
        }
        self.classify(now, channels, host);
    }

    fn accumulate(&mut self, dt: u64) {
        for (st, acc) in self.bus_state.iter().zip(self.bus_acc.iter_mut()) {
            acc[*st as usize] += dt;
        }
        for (st, acc) in self.chip_state.iter().zip(self.chip_acc.iter_mut()) {
            acc[*st as usize] += dt;
        }
        for i in 0..self.way_state.len() {
            let st = self.way_state[i];
            self.way_acc[i][st as usize] += dt;
            match (st, self.way_cause[i]) {
                (BLOCKED, CAUSE_BARRIER) => self.stalls.gc_barrier_ps += dt,
                (BLOCKED, CAUSE_MAPFILL) => self.stalls.map_fill_ps += dt,
                (BLOCKED, _) => self.stalls.bus_contention_ps += dt,
                (IDLE, CAUSE_BACKPRESSURE) => self.stalls.link_backpressure_ps += dt,
                (IDLE, _) => self.stalls.queue_starvation_ps += dt,
                _ => {}
            }
        }
    }

    fn classify(&mut self, now: Ps, channels: &[ChannelState], host: HostView) {
        for (ch, chan) in channels.iter().enumerate() {
            let owner = self.bus_owner[ch];
            self.bus_state[ch] = if owner.is_some() {
                BUSY
            } else if chan.any_wants_bus(now) {
                IDLE_QUEUED
            } else {
                IDLE
            };
            for (w, way) in chan.ways.iter().enumerate() {
                let i = ch * self.ways + w;
                self.chip_state[i] = if way.array_busy(now) {
                    BUSY
                } else if way.inflight.is_some() || way.queue_len() > 0 {
                    // Page register held or work queued: occupied-but-not-
                    // working. The array itself never waits on anything,
                    // so chips have no blocked state.
                    IDLE_QUEUED
                } else {
                    IDLE
                };
                // Ways: bus ownership is checked *first* — during a command
                // transfer the in-flight job is already ArrayBusy with a
                // stale `array_done_at` (see `WayState::array_busy`), and
                // the transfer interval belongs to the owning way.
                let owns_bus = matches!(owner, Some((ow, _)) if ow as usize == w);
                let (state, cause) = if owns_bus || way.array_busy(now) {
                    (BUSY, CAUSE_CONTENTION)
                } else if way.wants_bus(now) {
                    match owner {
                        Some((_, BusUser::Internal)) => (BLOCKED, CAUSE_BARRIER),
                        Some((_, BusUser::MapFill)) => (BLOCKED, CAUSE_MAPFILL),
                        Some((_, BusUser::Host)) => (BLOCKED, CAUSE_CONTENTION),
                        None => (IDLE_QUEUED, CAUSE_CONTENTION),
                    }
                } else if way.inflight.is_some() || way.queue_len() > 0 {
                    // Array-done at a timestamp whose ChipDone is still in
                    // this event batch, or queued work behind an array op:
                    // pending, not held back.
                    (IDLE_QUEUED, CAUSE_CONTENTION)
                } else if host.link_busy {
                    (IDLE, CAUSE_BACKPRESSURE)
                } else {
                    (IDLE, CAUSE_STARVED)
                };
                self.way_state[i] = state;
                self.way_cause[i] = cause;
            }
        }
    }

    /// The DES granted the bus of `ch` to `way` for `[now, done)`.
    /// `user` classifies the traffic for stall attribution. The span's
    /// begin *and* end are pushed here — `done` is already known, and
    /// per-track serialization keeps timestamps monotone.
    pub fn bus_granted(
        &mut self,
        ch: usize,
        way: u16,
        user: BusUser,
        phase: BusPhaseKind,
        now: Ps,
        done: Ps,
    ) {
        self.bus_owner[ch] = Some((way, user));
        let tid = self.tid_bus();
        self.push_event(TraceEvent {
            name: phase.name(),
            ph: b'B',
            ts: now,
            pid: ch as u16,
            tid,
        });
        self.push_event(TraceEvent {
            name: phase.name(),
            ph: b'E',
            ts: done,
            pid: ch as u16,
            tid,
        });
    }

    /// The bus of `ch` completed its granted phase.
    pub fn bus_released(&mut self, ch: usize, _now: Ps) {
        self.bus_owner[ch] = None;
    }

    /// A queued job was dispatched on (`ch`, `way`): opens the way-track
    /// span (closed by [`job_completed`](Self::job_completed)).
    pub fn job_started(&mut self, ch: usize, way: u16, kind: PageJobKind, now: Ps) {
        let tid = self.tid_way(way);
        self.push_event(TraceEvent {
            name: job_name(kind),
            ph: b'B',
            ts: now,
            pid: ch as u16,
            tid,
        });
    }

    /// The in-flight job on (`ch`, `way`) finished its final bus phase.
    pub fn job_completed(&mut self, ch: usize, way: u16, kind: PageJobKind, now: Ps) {
        let tid = self.tid_way(way);
        self.push_event(TraceEvent {
            name: job_name(kind),
            ph: b'E',
            ts: now,
            pid: ch as u16,
            tid,
        });
    }

    /// The array op behind (`ch`, `way`) started: chip-track span over
    /// `[now, done)` (t_R / t_PROG / t_BERS).
    pub fn array_started(&mut self, ch: usize, way: u16, kind: PageJobKind, now: Ps, done: Ps) {
        let tid = self.tid_chip(way);
        let name = array_name(kind);
        self.push_event(TraceEvent {
            name,
            ph: b'B',
            ts: now,
            pid: ch as u16,
            tid,
        });
        self.push_event(TraceEvent {
            name,
            ph: b'E',
            ts: done,
            pid: ch as u16,
            tid,
        });
    }

    /// A write plan triggered garbage collection on `ch`.
    pub fn gc_trigger(&mut self, ch: usize, now: Ps) {
        self.gc_triggers += 1;
        let tid = self.tid_gc();
        self.push_event(TraceEvent {
            name: "gc_trigger",
            ph: b'i',
            ts: now,
            pid: ch as u16,
            tid,
        });
    }

    /// Close the books at `end` (the last host completion; clamped up to
    /// the last observed event so a draining GC tail stays counted).
    pub fn finalize(&mut self, end: Ps) {
        let end = end.max(self.last_t);
        if end > self.last_t {
            let dt = (end - self.last_t).as_ps() as u64;
            self.accumulate(dt);
            self.last_t = end;
        }
        self.wall = end;
    }

    /// Deterministically merge per-shard observer slices — one channel
    /// each, in channel order — into a whole-drive observer, as if a
    /// single observer had watched all channels (channel-sharded runs,
    /// [`crate::coordinator::ssd::SsdSim`]'s hub mode). Each slice is
    /// first finalized to the common `end` under its own last
    /// classification (resource state is piecewise-constant between that
    /// shard's events, so charging the tail interval to the last-scanned
    /// state is exact). Timeline events are re-homed to their channel's
    /// Perfetto process; the derived time-grid marks are identical on
    /// every shard, so only shard 0's are kept.
    pub fn merge_shards(shards: Vec<ObsState>, end: Ps) -> ObsState {
        assert!(!shards.is_empty(), "merge of zero shards");
        // Common close-of-books: the caller's end or the latest event on
        // any shard (a background drain tail), whichever is later — every
        // resource row must partition the same wall time.
        let end = shards.iter().fold(end, |e, s| e.max(s.last_t));
        let ways = shards[0].ways;
        let timeline_on = shards[0].timeline.is_some();
        let window = shards[0]
            .timeline
            .as_ref()
            .map(|t| t.window)
            .unwrap_or(Ps::ZERO);
        let channels = shards.len();
        let mut merged = ObsState::new(channels, ways, timeline_on, window);
        for (ch, mut s) in shards.into_iter().enumerate() {
            assert_eq!(s.channels, 1, "shard slices are single-channel");
            assert_eq!(s.ways, ways, "shards disagree on way count");
            s.finalize(end);
            merged.bus_acc[ch] = s.bus_acc[0];
            for w in 0..ways {
                merged.way_acc[ch * ways + w] = s.way_acc[w];
                merged.chip_acc[ch * ways + w] = s.chip_acc[w];
            }
            merged.stalls.bus_contention_ps += s.stalls.bus_contention_ps;
            merged.stalls.gc_barrier_ps += s.stalls.gc_barrier_ps;
            merged.stalls.map_fill_ps += s.stalls.map_fill_ps;
            merged.stalls.queue_starvation_ps += s.stalls.queue_starvation_ps;
            merged.stalls.link_backpressure_ps += s.stalls.link_backpressure_ps;
            merged.gc_triggers += s.gc_triggers;
            if let (Some(dst), Some(src)) = (merged.timeline.as_mut(), s.timeline.as_mut()) {
                let win_tid = 2 + 2 * ways as u16;
                for mut e in src.events.drain(..) {
                    if e.tid == win_tid && ch != 0 {
                        continue; // identical grid on every shard
                    }
                    e.pid = ch as u16;
                    dst.events.push(e);
                }
            }
        }
        merged.last_t = end;
        merged.wall = end;
        merged
    }

    /// Snapshot the accumulated accounting into a report.
    pub fn report(&self) -> ObserveReport {
        let mut resources = Vec::with_capacity(self.channels * (1 + 2 * self.ways));
        for ch in 0..self.channels {
            resources.push(ResourceUsage::from_acc(
                ch as u16,
                ResourceKind::Bus,
                0,
                &self.bus_acc[ch],
            ));
            for w in 0..self.ways {
                resources.push(ResourceUsage::from_acc(
                    ch as u16,
                    ResourceKind::Way,
                    w as u16,
                    &self.way_acc[ch * self.ways + w],
                ));
            }
            for w in 0..self.ways {
                resources.push(ResourceUsage::from_acc(
                    ch as u16,
                    ResourceKind::Chip,
                    w as u16,
                    &self.chip_acc[ch * self.ways + w],
                ));
            }
        }
        ObserveReport {
            wall_ps: self.wall.as_ps() as u64,
            resources,
            stalls: self.stalls,
            gc_triggers: self.gc_triggers,
            trace_json: self
                .timeline
                .as_ref()
                .map(|tl| tl.to_json(self.channels, self.ways)),
        }
    }
}

fn job_name(kind: PageJobKind) -> &'static str {
    match kind {
        PageJobKind::Read => "read",
        PageJobKind::Program => "program",
        PageJobKind::Erase => "erase",
    }
}

fn array_name(kind: PageJobKind) -> &'static str {
    match kind {
        PageJobKind::Read => "t_R",
        PageJobKind::Program => "t_PROG",
        PageJobKind::Erase => "t_BERS",
    }
}

/// Append one trace event. `ts` is microseconds written as an exact
/// decimal (integer µs + 6 fractional digits = the full picosecond), and
/// `args.ps` repeats the timestamp in integer picoseconds so validators
/// and property tests can difference durations exactly.
fn write_event(out: &mut String, first: &mut bool, e: &TraceEvent) {
    use std::fmt::Write;
    if !*first {
        out.push(',');
    }
    *first = false;
    let ps = e.ts.as_ps();
    let _ = write!(
        out,
        "{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{}.{:06},\"pid\":{},\"tid\":{},\"args\":{{\"ps\":{}}}}}",
        e.name,
        e.ph as char,
        ps / 1_000_000,
        ps % 1_000_000,
        e.pid,
        e.tid,
        ps
    );
}

fn write_meta(out: &mut String, first: &mut bool, name: &str, pid: u16, tid: u16, value: &str) {
    use std::fmt::Write;
    if !*first {
        out.push(',');
    }
    *first = false;
    let _ = write!(
        out,
        "{{\"name\":\"{name}\",\"ph\":\"M\",\"ts\":0,\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\"{value}\"}}}}"
    );
}

impl TimelineBuf {
    /// Serialize to Chrome trace-event JSON (object form). Track names are
    /// all static identifiers the writer controls, so no string escaping
    /// is needed. Loadable directly in Perfetto (`ui.perfetto.dev`) — the
    /// walkthrough lives in EXPERIMENTS.md §Bottlenecks.
    fn to_json(&self, channels: usize, ways: usize) -> String {
        let mut out = String::with_capacity(128 + self.events.len() * 100 + channels * 200);
        out.push_str("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[");
        let mut first = true;
        for ch in 0..channels as u16 {
            write_meta(&mut out, &mut first, "process_name", ch, 0, &format!("channel {ch}"));
            write_meta(&mut out, &mut first, "thread_name", ch, 0, "bus");
            for w in 0..ways as u16 {
                write_meta(
                    &mut out,
                    &mut first,
                    "thread_name",
                    ch,
                    1 + w,
                    &format!("way {w}"),
                );
                write_meta(
                    &mut out,
                    &mut first,
                    "thread_name",
                    ch,
                    1 + ways as u16 + w,
                    &format!("chip {w}"),
                );
            }
            write_meta(
                &mut out,
                &mut first,
                "thread_name",
                ch,
                1 + 2 * ways as u16,
                "gc",
            );
        }
        write_meta(
            &mut out,
            &mut first,
            "thread_name",
            0,
            2 + 2 * ways as u16,
            "window",
        );
        for e in &self.events {
            write_event(&mut out, &mut first, e);
        }
        out.push_str("]}");
        out
    }
}

/// Validate a Chrome trace-event JSON timeline against the pinned schema:
///
/// * top level is an object with `displayTimeUnit` and a `traceEvents`
///   array;
/// * every event is an object carrying string `name`/`ph` and numeric
///   `ts`/`pid`/`tid`, with `ph` one of `B`, `E`, `i`, `M`;
/// * every `B`/`E`/`i` carries `args.ps`, a non-negative integer
///   picosecond timestamp consistent with the µs `ts`;
/// * per `(pid, tid)` track, `args.ps` is monotone non-decreasing;
/// * per track, `B`/`E` events are stack-balanced with matching names and
///   every span is closed by the end of the trace.
///
/// This is the gate the CI observe lane and `ddrnand analyze --trace` run
/// before publishing a timeline.
pub fn validate_trace_json(text: &str) -> Result<(), String> {
    use crate::bench::json::{self, Value};
    // BTreeMap, not HashMap: the unclosed-span sweep below iterates the
    // per-track state, and hash order would make *which* error is reported
    // depend on the hasher seed (simlint rule R1).
    use std::collections::BTreeMap;

    fn get<'a>(obj: &'a [(String, Value)], key: &str) -> Option<&'a Value> {
        obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
    fn num(obj: &[(String, Value)], key: &str) -> Option<f64> {
        match get(obj, key) {
            Some(Value::Num(n)) => Some(*n),
            _ => None,
        }
    }
    fn string<'a>(obj: &'a [(String, Value)], key: &str) -> Option<&'a str> {
        match get(obj, key) {
            Some(Value::Str(s)) => Some(s.as_str()),
            _ => None,
        }
    }

    let root = json::parse(text).map_err(|e| format!("not valid JSON: {e}"))?;
    let obj = root
        .as_object()
        .ok_or_else(|| "top level must be an object".to_string())?;
    if string(obj, "displayTimeUnit").is_none() {
        return Err("missing displayTimeUnit".to_string());
    }
    let events = match get(obj, "traceEvents") {
        Some(Value::Array(a)) => a,
        _ => return Err("missing traceEvents array".to_string()),
    };

    let mut last_ps: BTreeMap<(i64, i64), i64> = BTreeMap::new();
    let mut stacks: BTreeMap<(i64, i64), Vec<String>> = BTreeMap::new();
    for (i, ev) in events.iter().enumerate() {
        let e = ev
            .as_object()
            .ok_or_else(|| format!("event {i}: not an object"))?;
        let name = string(e, "name").ok_or_else(|| format!("event {i}: missing name"))?;
        let ph = string(e, "ph").ok_or_else(|| format!("event {i}: missing ph"))?;
        let pid = num(e, "pid").ok_or_else(|| format!("event {i}: missing pid"))? as i64;
        let tid = num(e, "tid").ok_or_else(|| format!("event {i}: missing tid"))? as i64;
        let ts = num(e, "ts").ok_or_else(|| format!("event {i}: missing ts"))?;
        match ph {
            "M" => continue,
            "B" | "E" | "i" => {}
            other => return Err(format!("event {i}: unknown ph {other:?}")),
        }
        let args = match get(e, "args") {
            Some(Value::Object(a)) => a.as_slice(),
            _ => return Err(format!("event {i}: missing args")),
        };
        let ps_f = num(args, "ps").ok_or_else(|| format!("event {i}: missing args.ps"))?;
        if ps_f < 0.0 || ps_f.fract() != 0.0 {
            return Err(format!("event {i}: args.ps={ps_f} is not a non-negative integer"));
        }
        let ps = ps_f as i64;
        if ((ts * 1e6).round() as i64) != ps {
            return Err(format!(
                "event {i}: ts={ts}us disagrees with args.ps={ps}"
            ));
        }
        let track = (pid, tid);
        let last = last_ps.entry(track).or_insert(-1);
        if ps < *last {
            return Err(format!(
                "event {i}: ts went backwards on track pid={pid} tid={tid}: {ps} < {last}"
            ));
        }
        *last = ps;
        match ph {
            "B" => stacks.entry(track).or_default().push(name.to_string()),
            "E" => {
                let open = stacks.entry(track).or_default().pop().ok_or_else(|| {
                    format!("event {i}: E without matching B on pid={pid} tid={tid}")
                })?;
                if open != name {
                    return Err(format!(
                        "event {i}: E name {name:?} does not close open span {open:?}"
                    ));
                }
            }
            _ => {}
        }
    }
    for ((pid, tid), stack) in &stacks {
        if let Some(open) = stack.last() {
            return Err(format!(
                "unclosed span {open:?} on track pid={pid} tid={tid}"
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ecc::EccModel;
    use crate::controller::nand_if::NandIf;
    use crate::controller::sched::{self, SchedKind};
    use crate::controller::way::{JobPhase, PageJob, WayState};
    use crate::iface::timing::{IfaceParams, InterfaceKind};
    use crate::nand::chip::Chip;
    use crate::nand::datasheet::NandTiming;

    fn chan(nways: usize) -> ChannelState {
        let ways = (0..nways)
            .map(|_| WayState::new(Chip::new(NandTiming::slc(), 8)))
            .collect();
        ChannelState::new(
            NandIf::new(&IfaceParams::default(), InterfaceKind::Proposed),
            EccModel::default(),
            ways,
            sched::build(SchedKind::RoundRobin, [8, 4, 2, 1]),
        )
    }

    fn job(kind: PageJobKind) -> PageJob {
        PageJob {
            req: 0,
            stream: 0,
            class: 1,
            kind,
            block: 0,
            page: 0,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    const IDLE_HOST: HostView = HostView { link_busy: false };

    /// Hand-driven scenario: the four states partition the wall clock
    /// exactly and stalls attribute to the right causes.
    #[test]
    fn occupancy_partitions_wall_clock() {
        let mut obs = ObsState::new(1, 2, false, Ps::ZERO);
        let mut ch = chan(2);

        // t=0: both ways get work; way 0 is granted the bus for 10ns of
        // host traffic; way 1 is blocked behind it.
        ch.ways[0].push(job(PageJobKind::Read));
        ch.ways[1].push(job(PageJobKind::Read));
        obs.bus_granted(0, 0, BusUser::Host, BusPhaseKind::Cmd, Ps::ZERO, Ps::ns(10));
        obs.scan(Ps::ZERO, std::slice::from_ref(&ch), IDLE_HOST);

        // t=10ns: grant done; way 0's array busy until 30ns; the bus goes
        // to way 1 — internal traffic this time.
        obs.bus_released(0, Ps::ns(10));
        ch.ways[0].take_job(0);
        let mut j = job(PageJobKind::Read);
        j.phase = JobPhase::ArrayBusy;
        ch.ways[0].inflight = Some(j);
        ch.ways[0].array_done_at = Ps::ns(30);
        obs.bus_granted(0, 1, BusUser::Internal, BusPhaseKind::Cmd, Ps::ns(10), Ps::ns(20));
        obs.scan(Ps::ns(10), std::slice::from_ref(&ch), IDLE_HOST);

        // t=20ns: way 1's grant done, its array busy too; nothing queued.
        obs.bus_released(0, Ps::ns(20));
        ch.ways[1].take_job(0);
        ch.ways[1].inflight = Some(j);
        ch.ways[1].array_done_at = Ps::ns(40);
        obs.scan(Ps::ns(20), std::slice::from_ref(&ch), IDLE_HOST);

        // t=30ns: way 0's array completes (in the DES a ChipDone event
        // fires here, so the observer always scans at array completions —
        // ignore the pending data-out phase; this is a classification
        // test, not a full DES run).
        ch.ways[0].inflight = None;
        obs.scan(Ps::ns(30), std::slice::from_ref(&ch), IDLE_HOST);

        // t=40ns: way 1 drains too, and the host link is now saturated.
        ch.ways[1].inflight = None;
        obs.scan(
            Ps::ns(40),
            std::slice::from_ref(&ch),
            HostView { link_busy: true },
        );
        obs.finalize(Ps::ns(50));

        let r = obs.report();
        assert_eq!(r.wall_ps, 50_000);
        for res in &r.resources {
            assert_eq!(res.total_ps(), r.wall_ps, "{res:?}");
        }
        // Way 0: busy 0-10 (bus) + 10-30 (array), idle 30-50.
        let w0 = &r.resources[1];
        assert_eq!((w0.kind, w0.index), (ResourceKind::Way, 0));
        assert_eq!(w0.busy_ps, 30_000);
        assert_eq!(w0.idle_ps, 20_000);
        // Way 1: blocked 0-10 behind way 0's *host* grant, busy 10-40.
        let w1 = &r.resources[2];
        assert_eq!(w1.blocked_ps, 10_000);
        assert_eq!(w1.busy_ps, 30_000);
        assert_eq!(r.stalls.bus_contention_ps, 10_000);
        assert_eq!(r.stalls.gc_barrier_ps, 0);
        // Idle 30-40 with a free link is starvation; 40-50 the link was
        // busy: backpressure (both ways).
        assert_eq!(r.stalls.queue_starvation_ps, 10_000);
        assert_eq!(r.stalls.link_backpressure_ps, 20_000);
        // Cause sums tie out against the way accumulators.
        let way = r.totals(ResourceKind::Way);
        assert_eq!(
            r.stalls.bus_contention_ps + r.stalls.gc_barrier_ps + r.stalls.map_fill_ps,
            way[BLOCKED as usize]
        );
        assert_eq!(
            r.stalls.queue_starvation_ps + r.stalls.link_backpressure_ps,
            way[IDLE as usize]
        );
        // Bus: busy 0-20, idle-queued never (grants were back-to-back and
        // the array phases left no waiter), idle 20-50.
        let bus = &r.resources[0];
        assert_eq!(bus.busy_ps, 20_000);
        assert_eq!(bus.idle_ps, 30_000);
    }

    /// A GC-internal grant attributes the other way's wait to the GC
    /// barrier, not bus contention.
    #[test]
    fn internal_grant_is_a_gc_barrier() {
        let mut obs = ObsState::new(1, 2, false, Ps::ZERO);
        let mut ch = chan(2);
        ch.ways[0].push(job(PageJobKind::Program));
        ch.ways[1].push(job(PageJobKind::Read));
        obs.bus_granted(0, 0, BusUser::Internal, BusPhaseKind::Cmd, Ps::ZERO, Ps::ns(10));
        obs.scan(Ps::ZERO, std::slice::from_ref(&ch), IDLE_HOST);
        obs.finalize(Ps::ns(10));
        let r = obs.report();
        assert_eq!(r.stalls.gc_barrier_ps, 10_000);
        assert_eq!(r.stalls.bus_contention_ps, 0);
    }

    /// A mapping-tier grant raises its own stall cause — a way waiting
    /// behind a translation-page fill is neither host contention nor a
    /// GC barrier.
    #[test]
    fn map_fill_grant_attributes_to_map_cause() {
        let mut obs = ObsState::new(1, 2, false, Ps::ZERO);
        let mut ch = chan(2);
        ch.ways[0].push(job(PageJobKind::Read));
        ch.ways[1].push(job(PageJobKind::Read));
        obs.bus_granted(0, 0, BusUser::MapFill, BusPhaseKind::Cmd, Ps::ZERO, Ps::ns(10));
        obs.scan(Ps::ZERO, std::slice::from_ref(&ch), IDLE_HOST);
        obs.finalize(Ps::ns(10));
        let r = obs.report();
        assert_eq!(r.stalls.map_fill_ps, 10_000);
        assert_eq!(r.stalls.gc_barrier_ps, 0);
        assert_eq!(r.stalls.bus_contention_ps, 0);
        let way = r.totals(ResourceKind::Way);
        assert_eq!(r.stalls.map_fill_ps, way[BLOCKED as usize]);
    }

    /// The timeline writer round-trips through the pinned-schema
    /// validator, and the exact-µs decimal matches the integer args.ps.
    #[test]
    fn timeline_writer_validates() {
        let mut obs = ObsState::new(2, 2, true, Ps::ns(25));
        let ch: Vec<ChannelState> = vec![chan(2), chan(2)];
        obs.job_started(0, 0, PageJobKind::Read, Ps::ZERO);
        obs.bus_granted(0, 0, BusUser::Host, BusPhaseKind::Cmd, Ps::ZERO, Ps::ps(12_345_678_901));
        obs.scan(Ps::ZERO, &ch, IDLE_HOST);
        obs.bus_released(0, Ps::ps(12_345_678_901));
        obs.array_started(
            0,
            0,
            PageJobKind::Read,
            Ps::ps(12_345_678_901),
            Ps::ps(20_000_000_000),
        );
        obs.gc_trigger(1, Ps::ps(13_000_000_000));
        obs.scan(Ps::ps(13_000_000_000), &ch, IDLE_HOST);
        obs.bus_granted(
            0,
            0,
            BusUser::Host,
            BusPhaseKind::DataOut,
            Ps::ps(20_000_000_000),
            Ps::ps(21_000_000_000),
        );
        obs.bus_released(0, Ps::ps(21_000_000_000));
        obs.job_completed(0, 0, PageJobKind::Read, Ps::ps(21_000_000_000));
        obs.finalize(Ps::ps(21_000_000_000));
        let r = obs.report();
        let json = r.trace_json.expect("timeline enabled");
        validate_trace_json(&json).expect("pinned schema");
        // Exact decimal: 12_345_678_901 ps = 12345.678901 us.
        assert!(json.contains("\"ts\":12345.678901"), "{json}");
        assert!(json.contains("\"ps\":12345678901"));
        assert!(json.contains("\"name\":\"gc_trigger\""));
        assert!(json.contains("\"name\":\"window\""), "time-grid marks");
        assert!(json.contains("\"name\":\"channel 1\""));
        assert_eq!(r.gc_triggers, 1);
    }

    #[test]
    fn validator_rejects_malformed_timelines() {
        assert!(validate_trace_json("not json").is_err());
        assert!(validate_trace_json("{}").is_err());
        assert!(
            validate_trace_json("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[{\"name\":\"x\",\"ph\":\"Q\",\"ts\":0,\"pid\":0,\"tid\":0,\"args\":{\"ps\":0}}]}")
                .is_err(),
            "unknown phase"
        );
        // E without B.
        assert!(
            validate_trace_json("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[{\"name\":\"x\",\"ph\":\"E\",\"ts\":0,\"pid\":0,\"tid\":0,\"args\":{\"ps\":0}}]}")
                .is_err()
        );
        // Unclosed B.
        assert!(
            validate_trace_json("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[{\"name\":\"x\",\"ph\":\"B\",\"ts\":0,\"pid\":0,\"tid\":0,\"args\":{\"ps\":0}}]}")
                .is_err()
        );
        // Non-monotone track.
        assert!(
            validate_trace_json(
                "{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\
                 {\"name\":\"x\",\"ph\":\"B\",\"ts\":1.000000,\"pid\":0,\"tid\":0,\"args\":{\"ps\":1000000}},\
                 {\"name\":\"x\",\"ph\":\"E\",\"ts\":0.000000,\"pid\":0,\"tid\":0,\"args\":{\"ps\":0}}]}"
            )
            .is_err()
        );
        // ts/args.ps disagreement.
        assert!(
            validate_trace_json("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[{\"name\":\"x\",\"ph\":\"i\",\"ts\":2.000000,\"pid\":0,\"tid\":0,\"args\":{\"ps\":7}}]}")
                .is_err()
        );
        // Different tracks do not share a span stack.
        assert!(
            validate_trace_json(
                "{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\
                 {\"name\":\"x\",\"ph\":\"B\",\"ts\":0.000000,\"pid\":0,\"tid\":0,\"args\":{\"ps\":0}},\
                 {\"name\":\"x\",\"ph\":\"E\",\"ts\":1.000000,\"pid\":0,\"tid\":1,\"args\":{\"ps\":1000000}}]}"
            )
            .is_err()
        );
    }

    /// Two single-channel shard slices merge into the whole-drive layout:
    /// per-channel rows concatenate in shard order, stall causes and GC
    /// triggers sum, and every row still partitions the common wall clock.
    #[test]
    fn merge_shards_concatenates_slices() {
        // Shard 0: way 0 holds a 10ns host grant, way 1 blocked behind it.
        let mut a = ObsState::new(1, 2, false, Ps::ZERO);
        let mut ch_a = chan(2);
        ch_a.ways[0].push(job(PageJobKind::Read));
        ch_a.ways[1].push(job(PageJobKind::Read));
        a.bus_granted(0, 0, BusUser::Host, BusPhaseKind::Cmd, Ps::ZERO, Ps::ns(10));
        a.scan(Ps::ZERO, std::slice::from_ref(&ch_a), IDLE_HOST);
        a.bus_released(0, Ps::ns(10));
        ch_a.ways[0].take_job(0);
        ch_a.ways[1].take_job(0);
        a.scan(Ps::ns(10), std::slice::from_ref(&ch_a), IDLE_HOST);
        a.gc_trigger(0, Ps::ns(10));

        // Shard 1: completely idle, never scanned past t=0.
        let mut b = ObsState::new(1, 2, false, Ps::ZERO);
        let ch_b = chan(2);
        b.scan(Ps::ZERO, std::slice::from_ref(&ch_b), IDLE_HOST);

        let merged = ObsState::merge_shards(vec![a, b], Ps::ns(20));
        let r = merged.report();
        assert_eq!(r.wall_ps, 20_000);
        assert_eq!(r.resources.len(), 2 * (1 + 2 + 2));
        for res in &r.resources {
            assert_eq!(res.total_ps(), r.wall_ps, "{res:?}");
        }
        // Channel 0's bus: busy 0-10, idle 10-20. Channel 1's: idle 0-20.
        let bus0 = &r.resources[0];
        assert_eq!((bus0.channel, bus0.kind), (0, ResourceKind::Bus));
        assert_eq!(bus0.busy_ps, 10_000);
        let bus1 = &r.resources[5];
        assert_eq!((bus1.channel, bus1.kind), (1, ResourceKind::Bus));
        assert_eq!(bus1.idle_ps, 20_000);
        // Shard 0's way-1 block and both shards' idle tails sum.
        assert_eq!(r.stalls.bus_contention_ps, 10_000);
        assert_eq!(r.gc_triggers, 1);
        let way = r.totals(ResourceKind::Way);
        assert_eq!(
            r.stalls.queue_starvation_ps + r.stalls.link_backpressure_ps,
            way[IDLE as usize]
        );
    }

    #[test]
    fn empty_run_reports_all_idle() {
        let mut obs = ObsState::new(2, 4, false, Ps::ZERO);
        let ch: Vec<ChannelState> = vec![chan(4), chan(4)];
        obs.scan(Ps::ZERO, &ch, IDLE_HOST);
        obs.finalize(Ps::us(1));
        let r = obs.report();
        assert_eq!(r.resources.len(), 2 * (1 + 4 + 4));
        for res in &r.resources {
            assert_eq!(res.idle_ps, 1_000_000, "{res:?}");
            assert_eq!(res.total_ps(), r.wall_ps);
        }
        assert_eq!(r.busy_fraction(ResourceKind::Bus), 0.0);
        assert_eq!(r.blocked_share(ResourceKind::Way), 0.0);
        assert_eq!(r.stalls.queue_starvation_ps, 2 * 4 * 1_000_000);
    }
}
