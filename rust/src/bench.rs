//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*` targets (`harness = false`): warmup, a
//! fixed sample count, and mean/median/stddev reporting. Deliberately
//! simple — the paper benches measure *simulated* quantities; this harness
//! is for the §Perf wall-clock measurements.
//!
//! [`PerfLog`] is the machine-readable side: every perf-relevant number a
//! bench emits is also recorded as a `(name, metric, value)` triple and
//! written as JSON (`BENCH_engine.json` at the repo root), so each perf PR
//! leaves a measured trajectory that tooling and EXPERIMENTS.md §Perf can
//! diff across commits. No serde offline — the writer emits the small
//! schema by hand.

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of timing one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10.3} ms/iter (median {:.3}, sd {:.3}, n={})",
            self.name, s.mean, s.median, s.stddev, s.n
        )
    }
}

/// Time `f` with `warmup` discarded runs and `samples` measured runs.
/// Returns per-iteration milliseconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let summary = Summary::from_samples(&times).expect("samples > 0");
    BenchResult {
        name: name.to_string(),
        samples: times,
        summary,
    }
}

/// Measure a throughput-style quantity: runs `f` once, expects it to return
/// (units, elapsed-seconds), reports units/s.
pub fn throughput<F: FnOnce() -> (u64, f64)>(name: &str, f: F) -> String {
    let (units, secs) = f();
    format!(
        "{:<44} {:>12} units in {:.3}s = {}/s",
        name,
        units,
        secs,
        crate::util::fmt::fmt_si(units as f64 / secs)
    )
}

/// One recorded perf number.
#[derive(Debug, Clone)]
pub struct PerfRecord {
    /// What was measured (e.g. `event_queue_100k_ops/calendar`).
    pub name: String,
    /// The unit/kind of the value (e.g. `ms_per_iter`, `events_per_sec`).
    pub metric: String,
    pub value: f64,
    /// Samples behind the value (1 for throughput-style one-shots).
    pub n: usize,
}

/// Collects [`PerfRecord`]s and serializes them as the
/// `ddrnand-bench-v1` JSON schema.
#[derive(Debug, Default)]
pub struct PerfLog {
    /// Which bench produced the log (e.g. `bench_engine`).
    pub bench: String,
    pub records: Vec<PerfRecord>,
}

impl PerfLog {
    pub fn new(bench: &str) -> PerfLog {
        PerfLog {
            bench: bench.to_string(),
            records: Vec::new(),
        }
    }

    /// Record one number.
    pub fn push(&mut self, name: &str, metric: &str, value: f64, n: usize) {
        self.records.push(PerfRecord {
            name: name.to_string(),
            metric: metric.to_string(),
            value,
            n,
        });
    }

    /// Record a [`BenchResult`] (mean/median/stddev ms per iteration).
    pub fn push_bench(&mut self, key: &str, r: &BenchResult) {
        self.push(key, "ms_per_iter_mean", r.summary.mean, r.summary.n);
        self.push(key, "ms_per_iter_median", r.summary.median, r.summary.n);
        self.push(key, "ms_per_iter_stddev", r.summary.stddev, r.summary.n);
    }

    /// Serialize to the `ddrnand-bench-v1` JSON schema.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.records.len() * 96);
        out.push_str("{\n");
        out.push_str("  \"schema\": \"ddrnand-bench-v1\",\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape_json(&self.bench)));
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        out.push_str(&format!("  \"created_unix\": {unix},\n"));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            let comma = if i + 1 == self.records.len() { "" } else { "," };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"metric\": \"{}\", \"value\": {}, \"n\": {}}}{comma}\n",
                escape_json(&r.name),
                escape_json(&r.metric),
                json_num(r.value),
                r.n,
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON log to `path` and announce it on stdout.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("perf log: {} records -> {}", self.records.len(), path.display());
        Ok(())
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf; clamp to null-safe representations.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.summary.n, 10);
        assert_eq!(n, 12); // warmup + samples
        assert!(r.summary.mean >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn throughput_formats() {
        let s = throughput("events", || (2_000_000, 0.1));
        assert!(s.contains("20.00M"), "{s}");
    }

    #[test]
    fn perf_log_json_schema() {
        let mut log = PerfLog::new("bench_test");
        log.push("queue/calendar", "ms_per_iter_mean", 1.25, 20);
        log.push("speedup \"q\"", "ratio", 1.7, 1);
        log.push("bad", "nan", f64::NAN, 1);
        let json = log.to_json();
        assert!(json.contains("\"schema\": \"ddrnand-bench-v1\""));
        assert!(json.contains("\"bench\": \"bench_test\""));
        assert!(json.contains("\"name\": \"queue/calendar\""));
        assert!(json.contains("\"value\": 1.25"));
        assert!(json.contains("speedup \\\"q\\\""));
        assert!(json.contains("\"value\": null"));
        // Exactly one trailing record without a comma, valid bracket close.
        assert!(json.trim_end().ends_with('}'));
        assert_eq!(json.matches("\"name\":").count(), 3);
    }

    #[test]
    fn perf_log_push_bench() {
        let r = bench("x", 0, 5, || {});
        let mut log = PerfLog::new("b");
        log.push_bench("x", &r);
        assert_eq!(log.records.len(), 3);
        assert_eq!(log.records[0].metric, "ms_per_iter_mean");
        assert_eq!(log.records[0].n, 5);
    }
}
