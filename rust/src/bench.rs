//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*` targets (`harness = false`): warmup, a
//! fixed sample count, and mean/median/stddev reporting. Deliberately
//! simple — the paper benches measure *simulated* quantities; this harness
//! is for the §Perf wall-clock measurements.
//!
//! [`PerfLog`] is the machine-readable side: every perf-relevant number a
//! bench emits is also recorded as a `(name, metric, value)` triple and
//! written as JSON (`BENCH_engine.json` at the repo root), so each perf PR
//! leaves a measured trajectory that tooling and EXPERIMENTS.md §Perf can
//! diff across commits. No serde offline — the writer emits the small
//! schema by hand.

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of timing one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10.3} ms/iter (median {:.3}, sd {:.3}, n={})",
            self.name, s.mean, s.median, s.stddev, s.n
        )
    }
}

/// Time `f` with `warmup` discarded runs and `samples` measured runs.
/// Returns per-iteration milliseconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        // simlint: allow(nondet, "wall clock is the measurand: the perf harness times real runs")
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let summary = Summary::from_samples(&times).expect("samples > 0");
    BenchResult {
        name: name.to_string(),
        samples: times,
        summary,
    }
}

/// Measure a throughput-style quantity: runs `f` once, expects it to return
/// (units, elapsed-seconds), reports units/s.
pub fn throughput<F: FnOnce() -> (u64, f64)>(name: &str, f: F) -> String {
    let (units, secs) = f();
    format!(
        "{:<44} {:>12} units in {:.3}s = {}/s",
        name,
        units,
        secs,
        crate::util::fmt::fmt_si(units as f64 / secs)
    )
}

/// One recorded perf number.
#[derive(Debug, Clone)]
pub struct PerfRecord {
    /// What was measured (e.g. `event_queue_100k_ops/calendar`).
    pub name: String,
    /// The unit/kind of the value (e.g. `ms_per_iter`, `events_per_sec`).
    pub metric: String,
    pub value: f64,
    /// Samples behind the value (1 for throughput-style one-shots).
    pub n: usize,
    /// Engine threads the measurement ran with (1 = the serial engine).
    /// Mandatory in `ddrnand-bench-v2`: a perf number without its thread
    /// count cannot be compared across the parallel-engine trajectory.
    pub threads: u16,
    /// Window override in picoseconds (0 = derived from bus timing).
    pub window_ps: u64,
}

/// Collects [`PerfRecord`]s and serializes them as the
/// `ddrnand-bench-v2` JSON schema.
#[derive(Debug, Default)]
pub struct PerfLog {
    /// Which bench produced the log (e.g. `bench_engine`).
    pub bench: String,
    pub records: Vec<PerfRecord>,
}

impl PerfLog {
    pub fn new(bench: &str) -> PerfLog {
        PerfLog {
            bench: bench.to_string(),
            records: Vec::new(),
        }
    }

    /// Record one number measured on the serial engine (threads 1, no
    /// window override).
    pub fn push(&mut self, name: &str, metric: &str, value: f64, n: usize) {
        self.push_tagged(name, metric, value, n, 1, 0);
    }

    /// Record one number with its engine configuration tag.
    pub fn push_tagged(
        &mut self,
        name: &str,
        metric: &str,
        value: f64,
        n: usize,
        threads: u16,
        window_ps: u64,
    ) {
        self.records.push(PerfRecord {
            name: name.to_string(),
            metric: metric.to_string(),
            value,
            n,
            threads,
            window_ps,
        });
    }

    /// Record a [`BenchResult`] (mean/median/stddev ms per iteration).
    pub fn push_bench(&mut self, key: &str, r: &BenchResult) {
        self.push(key, "ms_per_iter_mean", r.summary.mean, r.summary.n);
        self.push(key, "ms_per_iter_median", r.summary.median, r.summary.n);
        self.push(key, "ms_per_iter_stddev", r.summary.stddev, r.summary.n);
    }

    /// Serialize to the `ddrnand-bench-v2` JSON schema.
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.records.len() * 96);
        out.push_str("{\n");
        out.push_str("  \"schema\": \"ddrnand-bench-v2\",\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape_json(&self.bench)));
        // simlint: allow(nondet, "created_unix stamps the bench log metadata, never sim state")
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        out.push_str(&format!("  \"created_unix\": {unix},\n"));
        out.push_str("  \"results\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            let comma = if i + 1 == self.records.len() { "" } else { "," };
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"metric\": \"{}\", \"value\": {}, \"n\": {}, \
                 \"threads\": {}, \"window_ps\": {}}}{comma}\n",
                escape_json(&r.name),
                escape_json(&r.metric),
                json_num(r.value),
                r.n,
                r.threads,
                r.window_ps,
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON log to `path` and announce it on stdout.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("perf log: {} records -> {}", self.records.len(), path.display());
        Ok(())
    }
}

/// Summary of a validated perf log.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchLogSummary {
    /// Which bench produced the log.
    pub bench: String,
    /// Number of result records.
    pub results: usize,
}

/// Validate `text` against the `ddrnand-bench-v2` schema: a JSON object
/// with `"schema": "ddrnand-bench-v2"`, a string `"bench"`, and a
/// `"results"` array whose records each carry a string `name`, a string
/// `metric`, a numeric-or-null `value`, an integer `n >= 1`, an integer
/// `threads >= 1` and an integer `window_ps >= 0`. The engine tags are
/// mandatory (v2): a perf number whose thread count is unknown cannot be
/// placed on the parallel-engine trajectory, so a record omitting them is
/// schema drift, not a permissible old-style entry. Unknown top-level keys
/// (e.g. `created_unix`, `note`) are allowed. Used by the CI pipeline
/// (`rust/tests/bench_schema.rs`) so schema drift in the committed
/// artifact or the writer fails loudly instead of rotting.
pub fn validate_bench_json(text: &str) -> Result<BenchLogSummary, String> {
    let value = json::parse(text)?;
    let top = value
        .as_object()
        .ok_or_else(|| "top level must be a JSON object".to_string())?;
    let schema = top
        .iter()
        .find(|(k, _)| k == "schema")
        .ok_or_else(|| "missing \"schema\" key".to_string())?;
    match &schema.1 {
        json::Value::Str(s) if s == "ddrnand-bench-v2" => {}
        other => return Err(format!("bad schema value: {other:?}")),
    }
    let bench = match top.iter().find(|(k, _)| k == "bench") {
        Some((_, json::Value::Str(s))) => s.clone(),
        Some((_, other)) => return Err(format!("\"bench\" must be a string, got {other:?}")),
        None => return Err("missing \"bench\" key".to_string()),
    };
    let results = match top.iter().find(|(k, _)| k == "results") {
        Some((_, json::Value::Array(rs))) => rs,
        Some((_, other)) => return Err(format!("\"results\" must be an array, got {other:?}")),
        None => return Err("missing \"results\" key".to_string()),
    };
    for (i, r) in results.iter().enumerate() {
        let rec = r
            .as_object()
            .ok_or_else(|| format!("results[{i}] must be an object"))?;
        let field = |name: &str| {
            rec.iter()
                .find(|(k, _)| k == name)
                .map(|(_, v)| v)
                .ok_or_else(|| format!("results[{i}] missing \"{name}\""))
        };
        if !matches!(field("name")?, json::Value::Str(_)) {
            return Err(format!("results[{i}].name must be a string"));
        }
        if !matches!(field("metric")?, json::Value::Str(_)) {
            return Err(format!("results[{i}].metric must be a string"));
        }
        match field("value")? {
            json::Value::Null => {}
            // `1e999` is lexically valid JSON but overflows f64 to ∞; a
            // non-finite value in the log means an empty/NaN accumulator
            // leaked through a writer — reject it loudly.
            json::Value::Num(v) if v.is_finite() => {}
            json::Value::Num(v) => {
                return Err(format!("results[{i}].value must be finite, got {v}"));
            }
            _ => return Err(format!("results[{i}].value must be a number or null")),
        }
        match field("n")? {
            json::Value::Num(n) if *n >= 1.0 && n.fract() == 0.0 => {}
            other => return Err(format!("results[{i}].n must be an integer >= 1, got {other:?}")),
        }
        match field("threads")? {
            json::Value::Num(t) if *t >= 1.0 && t.fract() == 0.0 => {}
            other => {
                return Err(format!(
                    "results[{i}].threads must be an integer >= 1, got {other:?}"
                ))
            }
        }
        match field("window_ps")? {
            json::Value::Num(w) if *w >= 0.0 && w.fract() == 0.0 => {}
            other => {
                return Err(format!(
                    "results[{i}].window_ps must be an integer >= 0, got {other:?}"
                ))
            }
        }
    }
    Ok(BenchLogSummary {
        bench,
        results: results.len(),
    })
}

/// One metric extracted from a validated perf log.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchMetric {
    pub name: String,
    pub metric: String,
    /// `None` when the writer recorded a non-finite value as JSON null.
    pub value: Option<f64>,
    pub threads: u16,
    pub window_ps: u64,
}

/// Parse a perf log into its metric records. Validates the full
/// `ddrnand-bench-v2` schema first, so extraction can assume well-formed
/// records.
pub fn parse_bench_metrics(text: &str) -> Result<Vec<BenchMetric>, String> {
    validate_bench_json(text)?;
    let value = json::parse(text)?;
    let top = value.as_object().expect("validated: top is an object");
    let results = match top.iter().find(|(k, _)| k == "results") {
        Some((_, json::Value::Array(rs))) => rs,
        _ => unreachable!("validated: results is an array"),
    };
    let mut out = Vec::with_capacity(results.len());
    for r in results {
        let rec = r.as_object().expect("validated: record is an object");
        let get = |name: &str| rec.iter().find(|(k, _)| k == name).map(|(_, v)| v);
        let text_of = |name: &str| match get(name) {
            Some(json::Value::Str(s)) => s.clone(),
            _ => unreachable!("validated: string field"),
        };
        let num_of = |name: &str| match get(name) {
            Some(json::Value::Num(v)) => *v,
            _ => unreachable!("validated: numeric field"),
        };
        let value = match get("value") {
            Some(json::Value::Num(v)) => Some(*v),
            _ => None,
        };
        out.push(BenchMetric {
            name: text_of("name"),
            metric: text_of("metric"),
            value,
            threads: num_of("threads") as u16,
            window_ps: num_of("window_ps") as u64,
        });
    }
    Ok(out)
}

/// Metrics the CI regression gate guards. Higher is strictly better for
/// these; wall-clock `ms_per_iter_*` records are too machine-sensitive to
/// block on and stay advisory.
fn gated_metric(metric: &str) -> bool {
    metric == "events_per_sec" || metric == "ratio"
}

/// Compare a freshly measured perf log against a committed baseline.
/// Returns the blocking regressions: any higher-is-better metric
/// (`events_per_sec`, speedup `ratio`s) present in the baseline — matched
/// on (name, metric, threads, window_ps) — that is missing from the new
/// log, went null, or dropped by more than `tolerance` (0.15 = 15%). An
/// empty baseline (the bootstrap artifact before CI's first measured run)
/// gates nothing. A log failing schema validation is an error, not a pass.
pub fn regression_gate(
    baseline: &str,
    current: &str,
    tolerance: f64,
) -> Result<Vec<String>, String> {
    let base = parse_bench_metrics(baseline).map_err(|e| format!("baseline: {e}"))?;
    let cur = parse_bench_metrics(current).map_err(|e| format!("current: {e}"))?;
    let mut failures = Vec::new();
    for b in base.iter().filter(|b| gated_metric(&b.metric)) {
        let Some(bv) = b.value else { continue };
        if bv <= 0.0 {
            continue;
        }
        let Some(c) = cur.iter().find(|c| {
            c.name == b.name
                && c.metric == b.metric
                && c.threads == b.threads
                && c.window_ps == b.window_ps
        }) else {
            failures.push(format!(
                "{} [{}] threads={} window_ps={}: in baseline but missing from the new log",
                b.name, b.metric, b.threads, b.window_ps
            ));
            continue;
        };
        let cv = c.value.unwrap_or(f64::NAN);
        // `!(>=)` so a NaN (null) measurement fails rather than passes.
        if !(cv >= bv * (1.0 - tolerance)) {
            failures.push(format!(
                "{} [{}] threads={} window_ps={}: {bv:.4} -> {cv:.4} ({:+.1}%) \
                 exceeds the {:.0}% drop tolerance",
                b.name,
                b.metric,
                b.threads,
                b.window_ps,
                (cv / bv - 1.0) * 100.0,
                tolerance * 100.0
            ));
        }
    }
    Ok(failures)
}

/// Minimal JSON parser (serde is unavailable offline) — just enough to
/// validate the `ddrnand-bench-v2` schema and, since the observer layer
/// landed, the Chrome trace-event timelines
/// ([`crate::observe::validate_trace_json`]). Numbers parse as f64 (exact
/// for integers below 2^53 — every picosecond count the validators
/// compare); strings support the escapes `escape_json` emits plus
/// `\uXXXX`.
pub mod json {
    #[derive(Debug, Clone, PartialEq)]
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Array(Vec<Value>),
        /// Key order preserved; duplicate keys kept as-is (first match wins
        /// in the validator).
        Object(Vec<(String, Value)>),
    }

    impl Value {
        pub fn as_object(&self) -> Option<&[(String, Value)]> {
            match self {
                Value::Object(kv) => Some(kv),
                _ => None,
            }
        }
    }

    pub fn parse(text: &str) -> Result<Value, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
            *pos += 1;
        }
    }

    fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
        skip_ws(b, pos);
        if *pos < b.len() && b[*pos] == c {
            *pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, *pos))
        }
    }

    fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b'{') => parse_object(b, pos),
            Some(b'[') => parse_array(b, pos),
            Some(b'"') => Ok(Value::Str(parse_string(b, pos)?)),
            Some(b't') => parse_lit(b, pos, "true", Value::Bool(true)),
            Some(b'f') => parse_lit(b, pos, "false", Value::Bool(false)),
            Some(b'n') => parse_lit(b, pos, "null", Value::Null),
            Some(_) => parse_number(b, pos),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Value) -> Result<Value, String> {
        if b[*pos..].starts_with(lit.as_bytes()) {
            *pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", *pos))
        }
    }

    fn parse_number(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        let start = *pos;
        while *pos < b.len()
            && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            *pos += 1;
        }
        std::str::from_utf8(&b[start..*pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
        debug_assert_eq!(b[*pos], b'"');
        *pos += 1;
        let mut out = String::new();
        loop {
            match b.get(*pos) {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    *pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    *pos += 1;
                    match b.get(*pos) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = b
                                .get(*pos + 1..*pos + 5)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| "bad \\u codepoint".to_string())?,
                            );
                            *pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", *pos)),
                    }
                    *pos += 1;
                }
                Some(_) => {
                    // Multi-byte UTF-8 sequences pass through untouched.
                    let s = &b[*pos..];
                    let ch_len = match s[0] {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?;
                    out.push_str(chunk);
                    *pos += chunk.len();
                }
            }
        }
    }

    fn parse_array(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        expect(b, pos, b'[')?;
        let mut items = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b']') {
            *pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(parse_value(b, pos)?);
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b']') => {
                    *pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
            }
        }
    }

    fn parse_object(b: &[u8], pos: &mut usize) -> Result<Value, String> {
        expect(b, pos, b'{')?;
        let mut kv = Vec::new();
        skip_ws(b, pos);
        if b.get(*pos) == Some(&b'}') {
            *pos += 1;
            return Ok(Value::Object(kv));
        }
        loop {
            skip_ws(b, pos);
            if b.get(*pos) != Some(&b'"') {
                return Err(format!("expected object key at byte {}", *pos));
            }
            let key = parse_string(b, pos)?;
            expect(b, pos, b':')?;
            let val = parse_value(b, pos)?;
            kv.push((key, val));
            skip_ws(b, pos);
            match b.get(*pos) {
                Some(b',') => *pos += 1,
                Some(b'}') => {
                    *pos += 1;
                    return Ok(Value::Object(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
            }
        }
    }
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// JSON has no NaN/Inf; clamp to null-safe representations.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.summary.n, 10);
        assert_eq!(n, 12); // warmup + samples
        assert!(r.summary.mean >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn throughput_formats() {
        let s = throughput("events", || (2_000_000, 0.1));
        assert!(s.contains("20.00M"), "{s}");
    }

    #[test]
    fn perf_log_json_schema() {
        let mut log = PerfLog::new("bench_test");
        log.push("queue/calendar", "ms_per_iter_mean", 1.25, 20);
        log.push_tagged("speedup \"q\"", "ratio", 1.7, 1, 4, 500_000);
        log.push("bad", "nan", f64::NAN, 1);
        let json = log.to_json();
        assert!(json.contains("\"schema\": \"ddrnand-bench-v2\""));
        assert!(json.contains("\"bench\": \"bench_test\""));
        assert!(json.contains("\"name\": \"queue/calendar\""));
        assert!(json.contains("\"value\": 1.25"));
        assert!(json.contains("speedup \\\"q\\\""));
        assert!(json.contains("\"value\": null"));
        // push defaults to the serial engine; push_tagged records the run's
        // engine configuration verbatim.
        assert!(json.contains("\"threads\": 1, \"window_ps\": 0"));
        assert!(json.contains("\"threads\": 4, \"window_ps\": 500000"));
        // Exactly one trailing record without a comma, valid bracket close.
        assert!(json.trim_end().ends_with('}'));
        assert_eq!(json.matches("\"name\":").count(), 3);
        assert_eq!(json.matches("\"threads\":").count(), 3);
    }

    /// Regression for the Welford ±∞ leak: a record whose value overflows
    /// f64 (the only way JSON can smuggle in an infinity) is rejected, and
    /// the writer's own output for a NaN record (null) still validates.
    #[test]
    fn validator_rejects_non_finite_values() {
        let inf = r#"{"schema": "ddrnand-bench-v2", "bench": "b",
            "results": [{"name": "x", "metric": "m", "value": 1e999, "n": 1,
                         "threads": 1, "window_ps": 0}]}"#;
        let err = validate_bench_json(inf).unwrap_err();
        assert!(err.contains("finite"), "{err}");
        let neg = inf.replace("1e999", "-1e999");
        assert!(validate_bench_json(&neg).is_err());
        // The writer emits null for non-finite values; null stays valid.
        let mut log = PerfLog::new("b");
        log.push("x", "m", f64::INFINITY, 1);
        validate_bench_json(&log.to_json()).expect("writer output must validate");
    }

    /// The channel-shard speedup record (`sharded_ssd_grid/.../
    /// speedup_vs_1thread`, a `ratio`) is a gated metric: once the measured
    /// baseline is promoted, losing more than the tolerance — or the record
    /// itself — blocks CI.
    #[test]
    fn gate_covers_sharded_ssd_grid_speedup() {
        let record = |value: f64| {
            let mut log = PerfLog::new("bench_engine");
            log.push_tagged(
                "sharded_ssd_grid/4_threads/speedup_vs_1thread",
                "ratio",
                value,
                1,
                4,
                50_000_000,
            );
            log.to_json()
        };
        let baseline = record(1.8);
        // Within tolerance: passes.
        assert!(regression_gate(&baseline, &record(1.75), 0.15).unwrap().is_empty());
        // 1.8 -> 1.2 is a 33% drop: blocked.
        let failures = regression_gate(&baseline, &record(1.2), 0.15).unwrap();
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("sharded_ssd_grid"), "{}", failures[0]);
        // Dropping the record entirely is also blocked.
        let empty = PerfLog::new("bench_engine").to_json();
        let failures = regression_gate(&baseline, &empty, 0.15).unwrap();
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("missing"), "{}", failures[0]);
    }

    #[test]
    fn perf_log_push_bench() {
        let r = bench("x", 0, 5, || {});
        let mut log = PerfLog::new("b");
        log.push_bench("x", &r);
        assert_eq!(log.records.len(), 3);
        assert_eq!(log.records[0].metric, "ms_per_iter_mean");
        assert_eq!(log.records[0].n, 5);
    }
}
