//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*` targets (`harness = false`): warmup, a
//! fixed sample count, and mean/median/stddev reporting. Deliberately
//! simple — the paper benches measure *simulated* quantities; this harness
//! is for the §Perf wall-clock measurements.

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of timing one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let s = &self.summary;
        format!(
            "{:<44} {:>10.3} ms/iter (median {:.3}, sd {:.3}, n={})",
            self.name, s.mean, s.median, s.stddev, s.n
        )
    }
}

/// Time `f` with `warmup` discarded runs and `samples` measured runs.
/// Returns per-iteration milliseconds.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let summary = Summary::from_samples(&times).expect("samples > 0");
    BenchResult {
        name: name.to_string(),
        samples: times,
        summary,
    }
}

/// Measure a throughput-style quantity: runs `f` once, expects it to return
/// (units, elapsed-seconds), reports units/s.
pub fn throughput<F: FnOnce() -> (u64, f64)>(name: &str, f: F) -> String {
    let (units, secs) = f();
    format!(
        "{:<44} {:>12} units in {:.3}s = {}/s",
        name,
        units,
        secs,
        crate::util::fmt::fmt_si(units as f64 / secs)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let mut n = 0u64;
        let r = bench("noop", 2, 10, || n += 1);
        assert_eq!(r.summary.n, 10);
        assert_eq!(n, 12); // warmup + samples
        assert!(r.summary.mean >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn throughput_formats() {
        let s = throughput("events", || (2_000_000, 0.1));
        assert!(s.contains("20.00M"), "{s}");
    }
}
