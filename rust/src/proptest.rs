//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! Provides seeded case generation with failure reporting and linear input
//! shrinking. Used by `rust/tests/ftl_properties.rs` and the invariant
//! tests sprinkled through the modules.

use crate::util::prng::Prng;

/// Hard cap on randomized cases under Miri: the interpreter is ~100x
/// slower than native, so the CI Miri lane runs a handful of cases per
/// property (native runs keep full counts).
const MIRI_CASE_CAP: u32 = 4;

/// Effective case count for a randomized suite that asks for `requested`
/// cases.
///
/// The `DDRNAND_PROPTEST_CASES` environment variable, when set to a
/// positive integer, caps the count (CI's Miri lane sets a small value;
/// the cap never *raises* a suite's own request). Under Miri the
/// `MIRI_CASE_CAP` applies as well, so the lane stays fast even when the
/// env var is not forwarded into the interpreter's isolated environment.
pub fn effective_cases(requested: u32) -> u32 {
    let capped = match std::env::var("DDRNAND_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<u32>().ok())
    {
        Some(n) if n >= 1 => requested.min(n),
        _ => requested,
    };
    if cfg!(miri) {
        capped.min(MIRI_CASE_CAP)
    } else {
        capped
    }
}

/// Run `cases` random property checks. `gen` draws an input from the PRNG;
/// `prop` returns `Err(reason)` on violation. On failure the harness tries
/// to shrink via `shrink` (smaller inputs first) and panics with the
/// minimal reproduction and its seed. The case count is subject to
/// [`effective_cases`] (env/Miri reduction); the drawing order is
/// unchanged, so any case that runs reproduces identically at full count.
pub fn check<T, G, P, S>(name: &str, cases: u32, seed: u64, mut gen: G, mut prop: P, shrink: S)
where
    T: Clone + std::fmt::Debug,
    G: FnMut(&mut Prng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    S: Fn(&T) -> Vec<T>,
{
    let cases = effective_cases(cases);
    let mut rng = Prng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            // Surface the reproduction seed immediately, before shrinking:
            // if shrinking itself panics or stalls, the CI log still holds
            // everything needed to reproduce the failure.
            eprintln!(
                "property '{name}' failed at case {case}; reproduce with seed {seed} \
                 (shrinking now...)"
            );
            // Greedy shrink: first failing smaller candidate, repeat.
            let mut minimal = input.clone();
            let mut why = reason;
            loop {
                let mut shrunk = false;
                for cand in shrink(&minimal) {
                    if let Err(r) = prop(&cand) {
                        minimal = cand;
                        why = r;
                        shrunk = true;
                        break;
                    }
                }
                if !shrunk {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed})\n  minimal input: {minimal:?}\n  reason: {why}"
            );
        }
    }
}

/// Shrinker for vectors: halves, then drop-one.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.is_empty() {
        return out;
    }
    out.push(v[..v.len() / 2].to_vec());
    out.push(v[v.len() / 2..].to_vec());
    if v.len() <= 16 {
        for i in 0..v.len() {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrinker for unsigned integers: 0, half, decrement.
pub fn shrink_u64(v: u64) -> Vec<u64> {
    let mut out = Vec::new();
    if v > 0 {
        out.push(0);
        if v / 2 != 0 {
            out.push(v / 2);
        }
        out.push(v - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            "sum-commutes",
            100,
            42,
            |rng| (rng.next_bounded(1000), rng.next_bounded(1000)),
            |&(a, b)| {
                count += 1;
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
            |_| vec![],
        );
        // The env/Miri reduction caps the count, so compare against the
        // effective number, not the literal request.
        assert_eq!(count, effective_cases(100));
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn failing_property_reports_minimal() {
        check(
            "all-below-500",
            1000,
            7,
            // Every draw fails, so the property trips on case 0 regardless
            // of any DDRNAND_PROPTEST_CASES / Miri case reduction.
            |rng| 500 + rng.next_bounded(500),
            |&v| {
                if v < 500 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 500"))
                }
            },
            |&v| shrink_u64(v),
        );
    }

    #[test]
    fn shrink_vec_produces_smaller() {
        let v = vec![1, 2, 3, 4];
        for s in shrink_vec(&v) {
            assert!(s.len() < v.len());
        }
    }

    #[test]
    fn shrink_u64_candidates() {
        assert!(shrink_u64(0).is_empty());
        let c = shrink_u64(100);
        assert!(c.contains(&0) && c.contains(&50) && c.contains(&99));
    }
}
