//! Fixed-width table rendering and paper-vs-measured comparisons.

use crate::coordinator::campaign::SimReport;

/// A simple fixed-width text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Table {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render with per-column widths and a separator line.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                // Right-align numeric-looking cells, left-align text.
                let c = &cells[i];
                let numeric = c
                    .chars()
                    .next()
                    .is_some_and(|ch| ch.is_ascii_digit() || ch == '-');
                if numeric {
                    line.push_str(&format!("{:>w$}", c, w = widths[i]));
                } else {
                    line.push_str(&format!("{:<w$}", c, w = widths[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a measured-vs-paper cell with delta percentage.
pub fn vs_paper(measured: f64, paper: Option<f64>) -> String {
    match paper {
        Some(p) => format!("{measured:.2} ({:+.1}%)", (measured - p) / p * 100.0),
        None => format!("{measured:.2} (max)"),
    }
}

/// One-line summary of a simulation report. Open-loop runs (those with an
/// offered load) append the offered MB/s and the latency percentiles.
pub fn summarize(r: &SimReport) -> String {
    let mut s = format!(
        "{:<9} {:>3} ch={} way={:<2} {:<5}  {:>8.2} MB/s  {:>6.3} nJ/B  busU={:>5.1}%  sataU={:>5.1}%  {} reqs in {}",
        r.iface,
        r.cell,
        r.channels,
        r.ways,
        r.mode,
        r.bandwidth_mbps,
        r.energy_nj_per_byte,
        r.bus_utilization * 100.0,
        r.sata_utilization * 100.0,
        r.requests,
        r.sim_time,
    );
    if r.offered_mbps > 0.0 {
        s.push_str(&format!(
            "\n  open loop: offered {:.1} MB/s, latency p50/p95/p99 = {:.1}/{:.1}/{:.1} us",
            r.offered_mbps, r.latency_p50_us, r.latency_p95_us, r.latency_p99_us
        ));
    }
    if r.gc_pages_programmed > 0 || r.wl_pages_programmed > 0 {
        // The gc/clean p99 pair only exists when some host request's own
        // plan carried GC work (cache-flush- or WL-only amplification
        // leaves the GC-hit population empty).
        let p99_pair = if r.gc_requests > 0 {
            format!("{:.1}/{:.1}", r.latency_p99_gc_us, r.latency_p99_clean_us)
        } else {
            "n/a".to_string()
        };
        s.push_str(&format!(
            "\n  steady state: WAF {:.3}, copy-back {} reads / {} programs (+{} wear-level), \
             {} GC-hit reqs, p99 gc/clean = {} us, wear spread {}, gc energy {:.1}%",
            r.waf,
            r.gc_pages_read,
            r.gc_pages_programmed,
            r.wl_pages_programmed,
            r.gc_requests,
            p99_pair,
            r.wear_spread,
            r.gc_energy_share * 100.0
        ));
    }
    if !r.streams.is_empty() {
        s.push_str(&format!(
            "\n  streams (Jain fairness {:.3}):",
            r.fairness
        ));
        for t in &r.streams {
            s.push_str(&format!(
                "\n    s{} class {}: {} reqs, {:.2} MB/s, p50/p95/p99 = {:.1}/{:.1}/{:.1} us",
                t.stream,
                t.class,
                t.requests,
                t.bandwidth_mbps,
                t.latency_p50_us,
                t.latency_p95_us,
                t.latency_p99_us
            ));
        }
    }
    if let Some(o) = &r.observe {
        use crate::observe::ResourceKind;
        let pct = |num: u64, den: u64| {
            if den == 0 {
                0.0
            } else {
                num as f64 / den as f64 * 100.0
            }
        };
        s.push_str("\n  bottlenecks (busy / blocked / queued / idle):");
        for kind in [ResourceKind::Bus, ResourceKind::Way, ResourceKind::Chip] {
            let [busy, blocked, queued, idle] = o.totals(kind);
            let total = busy + blocked + queued + idle;
            s.push_str(&format!(
                "\n    {:<4} {:>5.1}% / {:>5.1}% / {:>5.1}% / {:>5.1}%",
                kind.name(),
                pct(busy, total),
                pct(blocked, total),
                pct(queued, total),
                pct(idle, total),
            ));
        }
        s.push_str(&format!(
            "\n    stalls: bus contention {}, GC barrier {}, map fill {}, starvation {}, \
             link backpressure {} (ps); {} GC triggers",
            o.stalls.bus_contention_ps,
            o.stalls.gc_barrier_ps,
            o.stalls.map_fill_ps,
            o.stalls.queue_starvation_ps,
            o.stalls.link_backpressure_ps,
            o.gc_triggers,
        ));
    }
    if r.map_hits + r.map_misses > 0 {
        let wait = if r.map_deferred > 0 {
            format!("{:.1} us", r.map_wait_mean_us)
        } else {
            "n/a".to_string()
        };
        s.push_str(&format!(
            "\n  mapping: {:.1}% hit rate ({} hits / {} misses), {} fill reads / \
             {} write-backs, {} deferred, mean map wait {}",
            r.map_hit_rate * 100.0,
            r.map_hits,
            r.map_misses,
            r.map_pages_read,
            r.map_pages_programmed,
            r.map_deferred,
            wait,
        ));
    }
    if r.mig_pages_programmed > 0 || r.slc_reads + r.mlc_reads > 0 {
        let share = if (r.slc_reads + r.mlc_reads) > 0 {
            format!("{:.1}%", r.slc_read_share * 100.0)
        } else {
            "n/a".to_string()
        };
        s.push_str(&format!(
            "\n  tiering: {} migration reads / {} programs, SLC read share {} \
             ({} SLC / {} MLC), mig energy {:.1}%",
            r.mig_pages_read,
            r.mig_pages_programmed,
            share,
            r.slc_reads,
            r.mlc_reads,
            r.mig_energy_share * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["name", "mbps"]);
        t.row(vec!["CONV", "27.78"]);
        t.row(vec!["PROPOSED", "117.59"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].contains("CONV"));
        // numeric right-aligned: widths equal
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn vs_paper_formats() {
        assert_eq!(vs_paper(110.0, Some(100.0)), "110.00 (+10.0%)");
        assert_eq!(vs_paper(300.0, None), "300.00 (max)");
    }
}
