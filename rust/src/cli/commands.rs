//! Subcommand implementations.

use crate::analytic;
use crate::cli::args::Args;
use crate::config::{ArrivalKind, EngineConfig, MapMode, SsdConfig, SteadyConfig};
use crate::controller::sched::SchedKind;
use crate::coordinator::campaign::run_trace;
use crate::coordinator::experiments as exp;
use crate::coordinator::pool::ThreadPool;
use crate::dse;
use crate::host::link::HostLinkKind;
use crate::host::trace::{RequestKind, Trace, TraceGen};
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::nand::datasheet::CellType;
use crate::report;
use crate::runtime::{iface_params_row, Runtime, MC_S};
use crate::util::prng::Prng;
use anyhow::{anyhow, Context, Result};

fn pool(args: &mut Args) -> Result<ThreadPool> {
    Ok(ThreadPool::new(args.get_usize("jobs", 0).map_err(anyhow::Error::msg)?))
}

fn requests(args: &mut Args) -> Result<usize> {
    args.get_usize("requests", exp::DEFAULT_REQUESTS)
        .map_err(anyhow::Error::msg)
}

/// `--threads N`: per-simulation engine threads (the channel-sharded
/// executor; default 1 = the classic serial engine). Distinct from
/// `--jobs`, which sizes the sweep-level worker pool.
fn engine(args: &mut Args) -> Result<EngineConfig> {
    let threads = args.get_usize("threads", 1).map_err(anyhow::Error::msg)?;
    if threads == 0 || threads > 256 {
        return Err(anyhow!("--threads must be in 1..=256, got {threads}"));
    }
    Ok(EngineConfig {
        threads: threads as u16,
        ..EngineConfig::default()
    })
}

/// One shard per channel: engine threads beyond the channel count buy
/// nothing, so the simulator clamps them. Surface the clamp as a note —
/// never an error, existing configs keep loading (threads > 1 with a
/// single channel simply runs the sharded executor serially).
fn note_thread_clamp(cfg: &SsdConfig) {
    let threads = cfg.engine.threads;
    if threads as u32 > cfg.channels as u32 {
        eprintln!(
            "note: [engine] threads = {threads} exceeds the {} channel shard(s); \
             clamping to {}",
            cfg.channels, cfg.channels
        );
    }
}

pub fn cmd_table2(_args: &mut Args) -> Result<()> {
    println!("{}", exp::table2_text());
    Ok(())
}

pub fn cmd_sweep_ways(args: &mut Args) -> Result<()> {
    let n = requests(args)?;
    let p = pool(args)?;
    let eng = engine(args)?;
    let cells = exp::run_table3_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells("E2 / Fig. 8 + Table 3 — way-interleaving sweep (MB/s)", &cells, false)
    );
    println!("{}", exp::headline(&cells));
    Ok(())
}

pub fn cmd_sweep_channels(args: &mut Args) -> Result<()> {
    let n = requests(args)?;
    let p = pool(args)?;
    let eng = engine(args)?;
    let cells = exp::run_table4_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells(
            "E3 / Fig. 9 + Table 4 — channel/way configurations at constant capacity (MB/s)",
            &cells,
            false
        )
    );
    Ok(())
}

pub fn cmd_energy(args: &mut Args) -> Result<()> {
    let n = requests(args)?;
    let p = pool(args)?;
    let eng = engine(args)?;
    let cells = exp::run_table5_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells("E4 / Fig. 10 + Table 5 — controller energy per byte (nJ/B, SLC)", &cells, true)
    );
    Ok(())
}

pub fn cmd_paper(args: &mut Args) -> Result<()> {
    let n = requests(args)?;
    let p = pool(args)?;
    let eng = engine(args)?;
    println!("{}", exp::table2_text());
    let t3 = exp::run_table3_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells("E2 / Fig. 8 + Table 3 — way-interleaving sweep (MB/s)", &t3, false)
    );
    let t4 = exp::run_table4_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells("E3 / Fig. 9 + Table 4 — channel sweep (MB/s)", &t4, false)
    );
    let t5 = exp::run_table5_with(n, &p, eng);
    println!(
        "{}",
        exp::render_cells("E4 / Fig. 10 + Table 5 — energy (nJ/B, SLC)", &t5, true)
    );
    println!("{}", exp::headline(&t3));
    Ok(())
}

/// E6 — `ddrnand sweep-load`: sweep offered MB/s across interfaces × way
/// counts and print the throughput–latency hockey stick plus the
/// saturation knee of every configuration (EXPERIMENTS.md §Load).
pub fn cmd_sweep_load(args: &mut Args) -> Result<()> {
    let mut spec = exp::LoadSweepSpec {
        requests: requests(args)?,
        ..exp::LoadSweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    spec.mode = match args.get("mode").as_deref() {
        None | Some("read") => RequestKind::Read,
        Some("write") => RequestKind::Write,
        Some(other) => return Err(anyhow!("unknown --mode {other} (read|write)")),
    };
    spec.cell = match args.get("cell").as_deref() {
        None | Some("slc") => CellType::Slc,
        Some("mlc") => CellType::Mlc,
        Some(other) => return Err(anyhow!("unknown --cell {other} (slc|mlc)")),
    };
    if let Some(w) = args.get("ways") {
        spec.ways = w
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|e| anyhow!("--ways {s:?}: {e}"))
            })
            .collect::<Result<Vec<u16>>>()?;
        if spec.ways.is_empty() || spec.ways.contains(&0) {
            return Err(anyhow!("--ways needs a comma-separated list of counts >= 1"));
        }
    }
    spec.points = args.get_usize("points", spec.points).map_err(anyhow::Error::msg)?;
    spec.max_mbps = args
        .get_f64("max-mbps", spec.max_mbps)
        .map_err(anyhow::Error::msg)?;
    if spec.points == 0 || !(spec.max_mbps > 0.0) {
        return Err(anyhow!("--points and --max-mbps must be positive"));
    }
    spec.arrival = match args.get("arrival").as_deref() {
        None | Some("poisson") => ArrivalKind::Poisson,
        Some("bursty") => ArrivalKind::Bursty,
        Some(other) => return Err(anyhow!("unknown --arrival {other} (poisson|bursty)")),
    };
    spec.burst = args
        .get_usize("burst", spec.burst as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.burst == 0 {
        return Err(anyhow!("--burst must be >= 1"));
    }
    let csv = args.has("csv");
    let cells = exp::run_load_sweep(&spec, &p);
    println!(
        "{}",
        exp::render_load_sweep(
            &format!(
                "E6 — open-loop offered-load sweep ({} {} {}, {} arrivals; achieved MB/s and latency percentiles vs offered MB/s)",
                spec.cell.name(),
                spec.mode.name(),
                if spec.channels == 1 { "1-channel".to_string() } else { format!("{}-channel", spec.channels) },
                match spec.arrival {
                    ArrivalKind::Poisson => "poisson",
                    ArrivalKind::Bursty => "bursty",
                },
            ),
            &cells,
            csv
        )
    );
    Ok(())
}

/// E7 — `ddrnand sweep-steady`: preconditioned drives under sustained
/// random writes, swept over over-provisioning × interface × way count;
/// prints write amplification and the GC tax on p99 latency per point
/// (EXPERIMENTS.md §Steady-State).
pub fn cmd_sweep_steady(args: &mut Args) -> Result<()> {
    let mut spec = exp::SteadySweepSpec {
        requests: requests(args)?,
        ..exp::SteadySweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    spec.cell = match args.get("cell").as_deref() {
        None | Some("slc") => CellType::Slc,
        Some("mlc") => CellType::Mlc,
        Some(other) => return Err(anyhow!("unknown --cell {other} (slc|mlc)")),
    };
    if let Some(w) = args.get("ways") {
        spec.ways = w
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|e| anyhow!("--ways {s:?}: {e}"))
            })
            .collect::<Result<Vec<u16>>>()?;
        if spec.ways.is_empty() || spec.ways.contains(&0) {
            return Err(anyhow!("--ways needs a comma-separated list of counts >= 1"));
        }
    }
    if let Some(o) = args.get("op") {
        spec.over_provision = o
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow!("--op {s:?}: {e}"))
            })
            .collect::<Result<Vec<f64>>>()?;
        if spec.over_provision.is_empty()
            || spec
                .over_provision
                .iter()
                .any(|&v| !(v > 0.0 && v < 0.5))
        {
            return Err(anyhow!(
                "--op needs comma-separated over-provisioning fractions in (0, 0.5)"
            ));
        }
    }
    let offered = args
        .get_f64("offered-mbps", spec.offered_mbps.unwrap_or(0.0))
        .map_err(anyhow::Error::msg)?;
    if offered < 0.0 || !offered.is_finite() {
        return Err(anyhow!(
            "--offered-mbps must be >= 0 (0 = closed loop), got {offered}"
        ));
    }
    spec.offered_mbps = if offered > 0.0 { Some(offered) } else { None };
    spec.arrival = match args.get("arrival").as_deref() {
        None | Some("poisson") => ArrivalKind::Poisson,
        Some("bursty") => ArrivalKind::Bursty,
        Some(other) => return Err(anyhow!("unknown --arrival {other} (poisson|bursty)")),
    };
    spec.burst = args
        .get_usize("burst", spec.burst as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.burst == 0 {
        return Err(anyhow!("--burst must be >= 1"));
    }
    spec.blocks_per_chip = args
        .get_usize("blocks", spec.blocks_per_chip as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.blocks_per_chip < 16 {
        return Err(anyhow!("--blocks must be >= 16 (GC needs room to work)"));
    }
    spec.wear_level_spread = args
        .get_usize("wl-spread", spec.wear_level_spread as usize)
        .map_err(anyhow::Error::msg)? as u32;
    // The shared headroom rule config validation enforces for TOML: every
    // op point must leave GC spare blocks beyond its trigger threshold or
    // the sweep would live-lock-assert mid-run (the sweep runs the
    // default tuning).
    if let Some(&op) = spec.over_provision.iter().find(|&&op| {
        let steady = SteadyConfig {
            over_provision: op,
            ..SteadyConfig::default()
        };
        !steady.gc_headroom_ok(spec.blocks_per_chip)
    }) {
        return Err(anyhow!(
            "--op {op} is too small for --blocks {}: GC needs spare blocks beyond \
             its trigger threshold (raise --blocks or --op)",
            spec.blocks_per_chip
        ));
    }
    let csv = args.has("csv");
    let cells = exp::run_steady_state(&spec, &p);
    println!(
        "{}",
        exp::render_steady_sweep(
            &format!(
                "E7 — steady-state sweep ({} random write, {}, {}; WAF and GC-attributed p99 vs over-provisioning)",
                spec.cell.name(),
                if spec.channels == 1 { "1-channel".to_string() } else { format!("{}-channel", spec.channels) },
                match spec.offered_mbps {
                    Some(o) => format!("open loop {o:.1} MB/s offered"),
                    None => "closed loop".to_string(),
                },
            ),
            &cells,
            csv
        )
    );
    Ok(())
}

/// E8 — `ddrnand sweep-tiered`: fixed-capacity MLC-geometry drives whose
/// SLC-tier chip fraction is swept from pure MLC (0) to all-SLC (1), per
/// interface × way count; prints write latency, migration traffic and WAF
/// per point (EXPERIMENTS.md §Tiering).
pub fn cmd_sweep_tiered(args: &mut Args) -> Result<()> {
    let mut spec = exp::TieredSweepSpec {
        requests: requests(args)?,
        ..exp::TieredSweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    if let Some(w) = args.get("ways") {
        spec.ways = w
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|e| anyhow!("--ways {s:?}: {e}"))
            })
            .collect::<Result<Vec<u16>>>()?;
        if spec.ways.is_empty() || spec.ways.contains(&0) {
            return Err(anyhow!("--ways needs a comma-separated list of counts >= 1"));
        }
    }
    if let Some(f) = args.get("fractions") {
        spec.slc_fractions = f
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|e| anyhow!("--fractions {s:?}: {e}"))
            })
            .collect::<Result<Vec<f64>>>()?;
        if spec.slc_fractions.is_empty()
            || spec
                .slc_fractions
                .iter()
                .any(|&v| !(0.0..=1.0).contains(&v))
        {
            return Err(anyhow!(
                "--fractions needs comma-separated SLC-tier fractions in [0, 1] \
                 (0 = pure MLC baseline)"
            ));
        }
    }
    if spec.slc_fractions.iter().any(|&f| f > 0.0)
        && spec
            .ways
            .iter()
            .any(|&w| (spec.channels as u32) * (w as u32) < 2)
    {
        return Err(anyhow!(
            "tiering needs at least 2 chips: every --ways entry must give \
             channels x ways >= 2"
        ));
    }
    if let Some(i) = args.get("ifaces") {
        spec.ifaces = i
            .split(',')
            .map(|s| match s.trim() {
                "conv" => Ok(InterfaceKind::Conv),
                "sync_only" => Ok(InterfaceKind::SyncOnly),
                "proposed" => Ok(InterfaceKind::Proposed),
                other => Err(anyhow!("--ifaces {other:?} (conv|sync_only|proposed)")),
            })
            .collect::<Result<Vec<InterfaceKind>>>()?;
        if spec.ifaces.is_empty() {
            return Err(anyhow!("--ifaces needs at least one interface"));
        }
    }
    let offered = args
        .get_f64("offered-mbps", spec.offered_mbps.unwrap_or(0.0))
        .map_err(anyhow::Error::msg)?;
    if offered < 0.0 || !offered.is_finite() {
        return Err(anyhow!(
            "--offered-mbps must be >= 0 (0 = closed loop), got {offered}"
        ));
    }
    spec.offered_mbps = if offered > 0.0 { Some(offered) } else { None };
    spec.arrival = match args.get("arrival").as_deref() {
        None | Some("poisson") => ArrivalKind::Poisson,
        Some("bursty") => ArrivalKind::Bursty,
        Some(other) => return Err(anyhow!("unknown --arrival {other} (poisson|bursty)")),
    };
    spec.burst = args
        .get_usize("burst", spec.burst as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.burst == 0 {
        return Err(anyhow!("--burst must be >= 1"));
    }
    spec.blocks_per_chip = args
        .get_usize("blocks", spec.blocks_per_chip as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.blocks_per_chip < 16 {
        return Err(anyhow!("--blocks must be >= 16 (migration and GC need room)"));
    }
    spec.migrate_free_blocks = args
        .get_usize("migrate-free", spec.migrate_free_blocks as usize)
        .map_err(anyhow::Error::msg)? as u32;
    let gc_floor = SteadyConfig::default().gc_threshold_blocks;
    let migrate = spec.migrate_free_blocks;
    if migrate <= gc_floor || migrate >= spec.blocks_per_chip {
        return Err(anyhow!(
            "--migrate-free must be in ({gc_floor}, --blocks): migration must fire \
             above the GC trigger"
        ));
    }
    spec.steady = args.has("steady");
    if spec.steady {
        spec.over_provision = args
            .get_f64("op", spec.over_provision)
            .map_err(anyhow::Error::msg)?;
        if !(spec.over_provision > 0.0 && spec.over_provision < 0.5) {
            return Err(anyhow!("--op must be in (0, 0.5)"));
        }
        let steady = SteadyConfig {
            over_provision: spec.over_provision,
            ..SteadyConfig::default()
        };
        if !steady.gc_headroom_ok(spec.blocks_per_chip) {
            return Err(anyhow!(
                "--op {} is too small for --blocks {}: GC needs spare blocks beyond \
                 its trigger threshold",
                spec.over_provision,
                spec.blocks_per_chip
            ));
        }
    }
    // Pre-flight every grid point through the shared config validation
    // (capacity feasibility included), so an impossible combination is a
    // clean error here instead of a panic mid-sweep.
    for iface in spec.ifaces.clone() {
        for &ways in &spec.ways {
            for &fraction in &spec.slc_fractions {
                if let Err(errs) = exp::tiered_point_config(&spec, iface, ways, fraction) {
                    return Err(anyhow!(
                        "sweep point ({iface}, {ways} ways, fraction {fraction}) is \
                         invalid: {}",
                        errs.join("; ")
                    ));
                }
            }
        }
    }
    let csv = args.has("csv");
    let cells = exp::run_tiered_sweep(&spec, &p);
    println!(
        "{}",
        exp::render_tiered_sweep(
            &format!(
                "E8 — tiered SLC/MLC sweep (MLC geometry, {}, {}{}; write latency and \
                 migration traffic vs SLC-tier fraction)",
                if spec.channels == 1 {
                    "1-channel".to_string()
                } else {
                    format!("{}-channel", spec.channels)
                },
                match spec.offered_mbps {
                    Some(o) => format!("open loop {o:.1} MB/s offered"),
                    None => "closed loop".to_string(),
                },
                if spec.steady { ", steady-state composed" } else { "" },
            ),
            &cells,
            csv
        )
    );
    Ok(())
}

/// E9 — `ddrnand sweep-qos`: a latency-critical random-read tenant
/// against a saturating bulk sequential-write tenant, swept over way
/// scheduler × interface × way count; prints per-tenant achieved
/// throughput, latency percentiles and the fairness index per point
/// (EXPERIMENTS.md §QoS).
pub fn cmd_sweep_qos(args: &mut Args) -> Result<()> {
    let mut spec = exp::QosSweepSpec {
        requests: requests(args)?,
        ..exp::QosSweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    spec.cell = match args.get("cell").as_deref() {
        None | Some("slc") => CellType::Slc,
        Some("mlc") => CellType::Mlc,
        Some(other) => return Err(anyhow!("unknown --cell {other} (slc|mlc)")),
    };
    if let Some(w) = args.get("ways") {
        spec.ways = w
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|e| anyhow!("--ways {s:?}: {e}"))
            })
            .collect::<Result<Vec<u16>>>()?;
        if spec.ways.is_empty() || spec.ways.contains(&0) {
            return Err(anyhow!("--ways needs a comma-separated list of counts >= 1"));
        }
    }
    if let Some(i) = args.get("ifaces") {
        spec.ifaces = i
            .split(',')
            .map(|s| match s.trim() {
                "conv" => Ok(InterfaceKind::Conv),
                "sync_only" => Ok(InterfaceKind::SyncOnly),
                "proposed" => Ok(InterfaceKind::Proposed),
                other => Err(anyhow!("--ifaces {other:?} (conv|sync_only|proposed)")),
            })
            .collect::<Result<Vec<InterfaceKind>>>()?;
        if spec.ifaces.is_empty() {
            return Err(anyhow!("--ifaces needs at least one interface"));
        }
    }
    if let Some(s) = args.get("schedulers") {
        spec.schedulers = s
            .split(',')
            .map(|v| {
                SchedKind::parse(v.trim()).ok_or_else(|| {
                    anyhow!("--schedulers {v:?} (round_robin|read_priority|weighted_qos)")
                })
            })
            .collect::<Result<Vec<SchedKind>>>()?;
        if spec.schedulers.is_empty() {
            return Err(anyhow!("--schedulers needs at least one policy"));
        }
    }
    if let Some(l) = args.get("link") {
        spec.link = HostLinkKind::parse(&l)
            .ok_or_else(|| anyhow!("--link {l:?} (sata|multi_queue)"))?;
    }
    spec.read_mbps = args
        .get_f64("read-mbps", spec.read_mbps)
        .map_err(anyhow::Error::msg)?;
    spec.write_mbps = args
        .get_f64("write-mbps", spec.write_mbps)
        .map_err(anyhow::Error::msg)?;
    if !(spec.read_mbps > 0.0 && spec.read_mbps.is_finite())
        || !(spec.write_mbps > 0.0 && spec.write_mbps.is_finite())
    {
        return Err(anyhow!("--read-mbps and --write-mbps must be positive"));
    }
    spec.blocks_per_chip = args
        .get_usize("blocks", spec.blocks_per_chip as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.blocks_per_chip < 16 {
        return Err(anyhow!("--blocks must be >= 16"));
    }
    // Pre-flight every grid point through the shared config validation so
    // an impossible combination is a clean error, not a mid-sweep panic.
    for &iface in &spec.ifaces {
        for &ways in &spec.ways {
            for &sched in &spec.schedulers {
                if let Err(errs) = exp::qos_point_config(&spec, iface, ways, sched) {
                    return Err(anyhow!(
                        "sweep point ({iface}, {ways} ways, {}) is invalid: {}",
                        sched.name(),
                        errs.join("; ")
                    ));
                }
            }
        }
    }
    let csv = args.has("csv");
    let cells = exp::run_qos_sweep(&spec, &p);
    println!(
        "{}",
        exp::render_qos_sweep(
            &format!(
                "E9 — QoS sweep ({} read tenant {:.1} MB/s vs write tenant {:.1} MB/s, {} link, \
                 {}; per-tenant latency and fairness vs way-scheduling policy)",
                spec.cell.name(),
                spec.read_mbps,
                spec.write_mbps,
                spec.link.name(),
                if spec.channels == 1 {
                    "1-channel".to_string()
                } else {
                    format!("{}-channel", spec.channels)
                },
            ),
            &cells,
            csv
        )
    );
    Ok(())
}

/// E10 — `ddrnand analyze`: run a grid with the `[observe]` occupancy
/// accounting enabled and print the per-resource utilization table plus
/// stall-cause attribution; `--trace FILE` additionally records the
/// Chrome-trace timeline of a single grid point for Perfetto
/// (EXPERIMENTS.md §Bottlenecks).
pub fn cmd_analyze(args: &mut Args) -> Result<()> {
    let mut spec = exp::ObserveSweepSpec {
        requests: requests(args)?,
        ..exp::ObserveSweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    spec.mode = match args.get("mode").as_deref() {
        None | Some("write") => RequestKind::Write,
        Some("read") => RequestKind::Read,
        Some(other) => return Err(anyhow!("unknown --mode {other} (read|write)")),
    };
    spec.cell = match args.get("cell").as_deref() {
        None | Some("slc") => CellType::Slc,
        Some("mlc") => CellType::Mlc,
        Some(other) => return Err(anyhow!("unknown --cell {other} (slc|mlc)")),
    };
    if let Some(w) = args.get("ways") {
        spec.ways = w
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u16>()
                    .map_err(|e| anyhow!("--ways {s:?}: {e}"))
            })
            .collect::<Result<Vec<u16>>>()?;
        if spec.ways.is_empty() || spec.ways.contains(&0) {
            return Err(anyhow!("--ways needs a comma-separated list of counts >= 1"));
        }
    }
    if let Some(i) = args.get("ifaces") {
        spec.ifaces = i
            .split(',')
            .map(|s| match s.trim() {
                "conv" => Ok(InterfaceKind::Conv),
                "sync_only" => Ok(InterfaceKind::SyncOnly),
                "proposed" => Ok(InterfaceKind::Proposed),
                other => Err(anyhow!("--ifaces {other:?} (conv|sync_only|proposed)")),
            })
            .collect::<Result<Vec<InterfaceKind>>>()?;
        if spec.ifaces.is_empty() {
            return Err(anyhow!("--ifaces needs at least one interface"));
        }
    }
    spec.blocks_per_chip = args
        .get_usize("blocks", spec.blocks_per_chip as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if spec.blocks_per_chip < 16 {
        return Err(anyhow!("--blocks must be >= 16"));
    }
    let trace_out = args.get("trace");
    if trace_out.is_some() {
        // A merged timeline across grid points would interleave unrelated
        // runs on the same tracks; require the grid to be a single point.
        if spec.ifaces.len() * spec.ways.len() != 1 {
            return Err(anyhow!(
                "--trace needs exactly one grid point (single --ifaces entry, single --ways entry)"
            ));
        }
        spec.timeline = true;
    }
    // Pre-flight every grid point through the shared config validation so
    // an impossible combination is a clean error, not a mid-sweep panic.
    for &iface in &spec.ifaces {
        for &ways in &spec.ways {
            if let Err(errs) = exp::observe_point_config(&spec, iface, ways) {
                return Err(anyhow!(
                    "sweep point ({iface}, {ways} ways) is invalid: {}",
                    errs.join("; ")
                ));
            }
        }
    }
    let csv = args.has("csv");
    let cells = exp::run_observe_sweep(&spec, &p);
    if let Some(path) = trace_out {
        let cell = cells.first().expect("validated single grid point");
        let json = cell
            .report
            .observe
            .as_ref()
            .and_then(|o| o.trace_json.as_deref())
            .ok_or_else(|| anyhow!("timeline missing from the observed run"))?;
        // The writer's output is schema-validated before it touches disk,
        // so a malformed file can never be shipped to Perfetto silently.
        crate::observe::validate_trace_json(json)
            .map_err(|e| anyhow!("internal: timeline failed its own schema: {e}"))?;
        std::fs::write(&path, json).with_context(|| format!("writing {path}"))?;
        eprintln!("wrote Chrome-trace timeline to {path} (open in https://ui.perfetto.dev)");
    }
    println!(
        "{}",
        exp::render_observe_sweep(
            &format!(
                "E10 — bottleneck sweep ({} {}, {}; per-resource occupancy and stall attribution)",
                spec.cell.name(),
                spec.mode.name(),
                if spec.channels == 1 {
                    "1-channel".to_string()
                } else {
                    format!("{}-channel", spec.channels)
                },
            ),
            &cells,
            csv
        )
    );
    Ok(())
}

/// Peak resident-set size of this process in MiB (Linux `VmHWM`), `None`
/// where /proc is unavailable. Used by `--rss-budget-mb` so CI can pin the
/// memory footprint of multi-TB mapping runs.
fn peak_rss_mb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let kb: u64 = status
        .lines()
        .find(|l| l.starts_with("VmHWM:"))?
        .split_whitespace()
        .nth(1)?
        .parse()
        .ok()?;
    Some(kb / 1024)
}

/// E11 — `ddrnand sweep-map`: run the demand-paged mapping-tier grid
/// (cache capacity × workload locality) and print hit rate, translation
/// traffic, and the bandwidth cost per point (EXPERIMENTS.md §Mapping).
pub fn cmd_sweep_map(args: &mut Args) -> Result<()> {
    let mut spec = exp::MapSweepSpec {
        requests: args
            .get_usize("requests", exp::MapSweepSpec::default().requests)
            .map_err(anyhow::Error::msg)?,
        ..exp::MapSweepSpec::default()
    };
    let p = pool(args)?;
    spec.engine = engine(args)?;
    spec.mode = match args.get("mode").as_deref() {
        None | Some("write") => RequestKind::Write,
        Some("read") => RequestKind::Read,
        Some(other) => return Err(anyhow!("unknown --mode {other} (read|write)")),
    };
    spec.map_mode = match args.get("map-mode").as_deref() {
        None | Some("demand") => MapMode::Demand,
        Some("fmmu") => MapMode::Fmmu,
        Some(other) => return Err(anyhow!("unknown --map-mode {other} (demand|fmmu)")),
    };
    spec.cell = match args.get("cell").as_deref() {
        None | Some("slc") => CellType::Slc,
        Some("mlc") => CellType::Mlc,
        Some(other) => return Err(anyhow!("unknown --cell {other} (slc|mlc)")),
    };
    spec.channels = args
        .get_usize("channels", spec.channels as usize)
        .map_err(anyhow::Error::msg)? as u16;
    spec.ways = args
        .get_usize("ways", spec.ways as usize)
        .map_err(anyhow::Error::msg)? as u16;
    spec.blocks_per_chip = args
        .get_usize("blocks", spec.blocks_per_chip as usize)
        .map_err(anyhow::Error::msg)? as u32;
    spec.entries_per_page = args
        .get_usize("entries", spec.entries_per_page as usize)
        .map_err(anyhow::Error::msg)? as u32;
    if let Some(list) = args.get("cache-pages") {
        spec.cache_pages = list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<u64>()
                    .map_err(|e| anyhow!("--cache-pages {s:?}: {e}"))
            })
            .collect::<Result<Vec<u64>>>()?;
        if spec.cache_pages.is_empty() || spec.cache_pages.contains(&0) {
            return Err(anyhow!("--cache-pages needs a comma-separated list of sizes >= 1"));
        }
    }
    if let Some(list) = args.get("hot") {
        spec.locality = list
            .split(',')
            .map(|s| {
                let (f, p) = s
                    .trim()
                    .split_once(':')
                    .ok_or_else(|| anyhow!("--hot {s:?}: expected FRAC:PROB"))?;
                let f: f64 = f.parse().map_err(|e| anyhow!("--hot fraction {f:?}: {e}"))?;
                let p: f64 = p.parse().map_err(|e| anyhow!("--hot probability {p:?}: {e}"))?;
                if !(0.0..=1.0).contains(&f) || !(0.0..=1.0).contains(&p) {
                    return Err(anyhow!("--hot {s:?}: both values must be within [0, 1]"));
                }
                Ok((f, p))
            })
            .collect::<Result<Vec<(f64, f64)>>>()?;
        if spec.locality.is_empty() {
            return Err(anyhow!("--hot needs at least one FRAC:PROB point"));
        }
    }
    // Pre-flight every grid point through the shared config validation so
    // an impossible combination is a clean error, not a mid-sweep panic.
    for &cache_pages in &spec.cache_pages {
        if let Err(errs) = exp::map_point_config(&spec, cache_pages) {
            return Err(anyhow!(
                "sweep point ({cache_pages} cache pages) is invalid: {}",
                errs.join("; ")
            ));
        }
    }
    let csv = args.has("csv");
    let rss_budget = args.get_usize("rss-budget-mb", 0).map_err(anyhow::Error::msg)?;
    let cells = exp::run_map_sweep(&spec, &p);
    println!(
        "{}",
        exp::render_map_sweep(
            &format!(
                "E11 — mapping sweep ({} {} via {} tier, {}x{} array; cache hit rate \
                 and translation traffic vs capacity and locality)",
                spec.cell.name(),
                spec.mode.name(),
                spec.map_mode.name(),
                spec.channels,
                spec.ways,
            ),
            &cells,
            csv
        )
    );
    if rss_budget > 0 {
        let peak = peak_rss_mb()
            .ok_or_else(|| anyhow!("--rss-budget-mb needs /proc/self/status (Linux only)"))?;
        if peak > rss_budget as u64 {
            return Err(anyhow!(
                "peak RSS {peak} MiB exceeds the --rss-budget-mb {rss_budget} MiB budget"
            ));
        }
        eprintln!("peak RSS {peak} MiB within the {rss_budget} MiB budget");
    }
    Ok(())
}

pub fn cmd_dse(args: &mut Args) -> Result<()> {
    let mut space = dse::Space::default();
    if args.has("sweep-tbyte") {
        space.t_byte_sweep = vec![12.0, 10.0, 8.0, 6.0, 4.0];
    }
    let runtime = if args.has("native") {
        None
    } else {
        let dir = Runtime::default_dir();
        if Runtime::artifacts_present(&dir) {
            Some(Runtime::load(&dir).context("loading AOT artifacts")?)
        } else {
            eprintln!(
                "note: artifacts missing in {} — using the native analytic model (run `make artifacts` for the PJRT path)",
                dir.display()
            );
            None
        }
    };
    let (cands, backend) = dse::evaluate(&space, runtime.as_ref())?;
    let ranked = dse::rank(cands);
    let front = dse::pareto_front(&ranked);
    println!("DSE over {} candidates (backend: {backend:?})\n", ranked.len());
    let mut t = report::Table::new(vec![
        "iface", "cell", "ch", "ways", "t_BYTE", "read MB/s", "write MB/s", "W nJ/B", "area", "merit",
    ]);
    for c in ranked.iter().take(15) {
        t.row(vec![
            c.iface.name().to_string(),
            c.cell.name().to_string(),
            c.channels.to_string(),
            c.ways.to_string(),
            c.t_byte_ns.map_or("12".into(), |v| format!("{v:.0}")),
            format!("{:.2}", c.read_bw),
            format!("{:.2}", c.write_bw),
            format!("{:.3}", c.write_nj_b),
            format!("{:.2}", c.area_proxy()),
            format!("{:.2}", c.merit()),
        ]);
    }
    println!("top 15 by bandwidth-per-area merit:\n{}", t.render());
    println!("Pareto front (read BW / write BW / area / write energy): {} designs", front.len());
    for c in &front {
        println!(
            "  {:<9} {} {}ch x {:>2}way  r={:>7.2} w={:>6.2} MB/s  {:.3} nJ/B",
            c.iface.name(),
            c.cell.name(),
            c.channels,
            c.ways,
            c.read_bw,
            c.write_bw,
            c.write_nj_b
        );
    }
    Ok(())
}

pub fn cmd_pvt(args: &mut Args) -> Result<()> {
    let margin = args.get_f64("margin", 1.02).map_err(anyhow::Error::msg)?;
    let dir = Runtime::default_dir();
    let corner = iface_params_row(&IfaceParams::default());
    let probs = if Runtime::artifacts_present(&dir) {
        let rt = Runtime::load(&dir)?;
        let mut rng = Prng::new(0xA3);
        let z: Vec<f32> = (0..MC_S * 4).map(|_| rng.next_gaussian() as f32).collect();
        let out = rt.mc_batch(&[corner], &z, [0.10, 0.05, margin])?;
        ("HLO/PJRT", out[0])
    } else {
        let pvt = crate::iface::pvt::PvtModel::default();
        let p = IfaceParams::default();
        let f = |k: InterfaceKind| {
            pvt.violation_probability(k, &p, p.tp_min_ns(k) * margin, 50_000, 0xA3)
        };
        (
            "native",
            [
                f(InterfaceKind::Conv),
                f(InterfaceKind::SyncOnly),
                f(InterfaceKind::Proposed),
            ],
        )
    };
    println!(
        "A3 — PVT Monte Carlo at margin {margin} (backend: {})\n\
         setup-violation probability per interface:\n\
         \x20 CONV      {:.4}\n\
         \x20 SYNC_ONLY {:.4}\n\
         \x20 PROPOSED  {:.4}\n\n\
         (the DVS designs track variation with the data — the paper's §2.3.3 claim)",
        probs.0, probs.1[0], probs.1[1], probs.1[2]
    );
    Ok(())
}

pub fn cmd_simulate(args: &mut Args) -> Result<()> {
    let path = args.require("config").map_err(anyhow::Error::msg)?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let mut cfg = SsdConfig::from_toml(&text).map_err(anyhow::Error::msg)?;
    // `--threads` overrides the config's `[engine] threads` when given.
    if args.get("threads").is_some() {
        cfg.engine.threads = engine(args)?.threads;
    }
    note_thread_clamp(&cfg);
    let n = requests(args)?;
    let mode = match args.get("mode").as_deref() {
        Some("read") => RequestKind::Read,
        _ => RequestKind::Write,
    };
    let rep = crate::coordinator::campaign::Campaign::new(cfg, mode, n).run();
    println!("{}", report::summarize(&rep));
    Ok(())
}

pub fn cmd_trace_gen(args: &mut Args) -> Result<()> {
    let out = args.require("out").map_err(anyhow::Error::msg)?;
    let n = requests(args)?;
    let gen = TraceGen::default();
    let mode = args.get("mode").unwrap_or_else(|| "write".into());
    let trace = match mode.as_str() {
        "write" => gen.sequential(RequestKind::Write, n),
        "read" => gen.sequential(RequestKind::Read, n),
        "mixed" => gen.mixed_sequential(n, 0.5, 1),
        "random-read" => gen.random(RequestKind::Read, n, 1 << 30, 1),
        "random-write" => gen.random(RequestKind::Write, n, 1 << 30, 1),
        other => return Err(anyhow!("unknown trace mode {other}")),
    };
    std::fs::write(&out, trace.to_text()).with_context(|| format!("writing {out}"))?;
    println!("wrote {} requests ({} bytes of payload) to {out}", trace.len(), trace.total_bytes());
    Ok(())
}

pub fn cmd_replay(args: &mut Args) -> Result<()> {
    let path = args.require("trace").map_err(anyhow::Error::msg)?;
    let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path}"))?;
    let trace = Trace::from_text(&text).map_err(anyhow::Error::msg)?;
    let mut cfg = match args.get("config") {
        Some(cpath) => {
            let ctext = std::fs::read_to_string(&cpath).with_context(|| format!("reading {cpath}"))?;
            SsdConfig::from_toml(&ctext).map_err(anyhow::Error::msg)?
        }
        None => SsdConfig::default(),
    };
    // `--threads` overrides the config's `[engine] threads` when given.
    if args.get("threads").is_some() {
        cfg.engine.threads = engine(args)?.threads;
    }
    note_thread_clamp(&cfg);
    // A v3 trace's stream ids must fit the config's submission queues:
    // catch the mismatch here as a clean error instead of the simulator's
    // assert.
    if cfg.host.link == HostLinkKind::MultiQueue
        && trace.stream_count() > cfg.host.queues as usize
    {
        return Err(anyhow!(
            "trace uses {} streams but the config's host.queues is {} — raise \
             host.queues or retag the trace",
            trace.stream_count(),
            cfg.host.queues
        ));
    }
    // Report both DES measurement and the analytic prediction.
    let rep = run_trace(&cfg, &trace);
    println!("{}", report::summarize(&rep));
    let mode = if rep.mode == "read" {
        RequestKind::Read
    } else {
        RequestKind::Write
    };
    let (ana_bw, ana_e) = analytic::evaluate(&cfg, mode);
    println!(
        "analytic steady-state prediction: {ana_bw:.2} MB/s, {ana_e:.3} nJ/B (DES delta {:+.1}%)",
        (rep.bandwidth_mbps - ana_bw) / ana_bw * 100.0
    );
    Ok(())
}
