//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! Subcommands map one-to-one onto the experiment index in DESIGN.md:
//!
//! ```text
//! ddrnand table2                      E1: frequency determination
//! ddrnand sweep-ways [...]            E2: Fig. 8 / Table 3
//! ddrnand sweep-channels [...]        E3: Fig. 9 / Table 4
//! ddrnand energy [...]                E4: Fig. 10 / Table 5
//! ddrnand paper [...]                 E1–E5 in one go
//! ddrnand sweep-load [...]            E6: open-loop offered-load sweep
//! ddrnand sweep-steady [...]          E7: steady-state GC/WAF sweep
//! ddrnand sweep-tiered [...]          E8: tiered SLC/MLC fraction sweep
//! ddrnand sweep-qos [...]             E9: multi-tenant QoS scheduler sweep
//! ddrnand analyze [...]               E10: bottleneck occupancy/stall analysis
//! ddrnand sweep-map [...]             E11: demand-paged mapping-tier sweep
//! ddrnand dse [--sweep-tbyte] [--native]   DSE through the AOT artifact
//! ddrnand pvt [--margin X]            A3: PVT Monte Carlo ablation
//! ddrnand simulate --config FILE      one simulation from a TOML config
//! ddrnand trace-gen --out FILE [...]  generate a workload trace
//! ddrnand replay --trace FILE [...]   replay a trace file
//! ```

pub mod args;
pub mod commands;

pub use args::Args;

/// Entry point: parse and dispatch. Returns the process exit code.
pub fn run(argv: &[String]) -> i32 {
    let mut args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    let Some(cmd) = args.subcommand.clone() else {
        println!("{}", usage());
        return 0;
    };
    let result = match cmd.as_str() {
        "table2" => commands::cmd_table2(&mut args),
        "sweep-ways" => commands::cmd_sweep_ways(&mut args),
        "sweep-channels" => commands::cmd_sweep_channels(&mut args),
        "energy" => commands::cmd_energy(&mut args),
        "paper" => commands::cmd_paper(&mut args),
        "sweep-load" => commands::cmd_sweep_load(&mut args),
        "sweep-steady" => commands::cmd_sweep_steady(&mut args),
        "sweep-tiered" => commands::cmd_sweep_tiered(&mut args),
        "sweep-qos" => commands::cmd_sweep_qos(&mut args),
        "analyze" => commands::cmd_analyze(&mut args),
        "sweep-map" => commands::cmd_sweep_map(&mut args),
        "dse" => commands::cmd_dse(&mut args),
        "pvt" => commands::cmd_pvt(&mut args),
        "simulate" => commands::cmd_simulate(&mut args),
        "trace-gen" => commands::cmd_trace_gen(&mut args),
        "replay" => commands::cmd_replay(&mut args),
        other => {
            eprintln!("unknown subcommand: {other}\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => {
            if let Some(unused) = args.first_unused() {
                eprintln!("warning: unused flag {unused}");
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

pub fn usage() -> String {
    "ddrnand — DDR NAND SSD simulator (reproduction of Chung et al., 2015)

USAGE: ddrnand <SUBCOMMAND> [FLAGS]

SUBCOMMANDS
  table2           E1: operating-frequency determination (Table 2, §5.2)
  sweep-ways       E2: way-interleaving sweep (Fig. 8 / Table 3)
  sweep-channels   E3: channel-config sweep (Fig. 9 / Table 4)
  energy           E4: energy per byte (Fig. 10 / Table 5)
  paper            E1–E5: all experiments, paper-vs-measured
  sweep-load       E6: open-loop offered-load sweep (latency under load)
  sweep-steady     E7: steady-state GC sweep (WAF, wear, GC tax on p99)
  sweep-tiered     E8: tiered SLC/MLC sweep (write latency vs SLC-tier fraction)
  sweep-qos        E9: multi-tenant QoS sweep (per-tenant p99 vs way scheduler)
  analyze          E10: bottleneck analysis (occupancy, stall attribution, Perfetto timeline)
  sweep-map        E11: demand-paged mapping sweep (cache hit rate vs capacity and locality)
  dse              design-space exploration via the AOT analytic model
  pvt              A3: PVT Monte Carlo ablation
  simulate         run one simulation from a TOML config
  trace-gen        generate a workload trace file
  replay           replay a trace file through a configuration

COMMON FLAGS
  --requests N     requests per data point (default 400)
  --threads N      engine threads per simulation (channel-sharded executor;
                   clamped to the channel count; default 1 = serial engine)
  --jobs N         sweep workers running whole sims in parallel (default: all cores)
  --csv            emit CSV instead of a rendered table
  --config FILE    TOML config (simulate/replay)
  --trace FILE     trace path (replay/trace-gen)
  --native         dse: force the pure-Rust model (skip PJRT)
  --sweep-tbyte    dse: sweep t_BYTE (A2 metal-layer ablation)
  --margin X       pvt: clock-period margin (default 1.02)

SWEEP-LOAD FLAGS
  --mode M         workload kind: read|write (default read)
  --cell C         flash cell: slc|mlc (default slc)
  --ways LIST      comma-separated way counts (default 1,4,8)
  --points N       offered-load grid points (default 8)
  --max-mbps X     top of the offered-load grid (default 320)
  --arrival KIND   arrival process: poisson|bursty (default poisson)
  --burst N        requests per burst for bursty arrivals (default 4)

SWEEP-STEADY FLAGS
  --cell C         flash cell: slc|mlc (default slc)
  --ways LIST      comma-separated way counts (default 4,8)
  --op LIST        over-provisioning fractions in (0,0.5) (default 0.07,0.15,0.28)
  --offered-mbps X offered write load; 0 = closed loop (default 20)
  --arrival KIND   arrival process: poisson|bursty (default poisson)
  --burst N        requests per burst for bursty arrivals (default 4)
  --blocks N       blocks per chip (default 64)
  --wl-spread N    chip P/E-spread threshold for wear leveling; 0 = off (default 16)

SWEEP-TIERED FLAGS
  --ways LIST      comma-separated way counts (default 4)
  --fractions LIST SLC-tier chip fractions in [0,1]; 0 = pure MLC (default 0,0.25,0.5,1)
  --ifaces LIST    interfaces to sweep (default conv,proposed)
  --offered-mbps X offered write load; 0 = closed loop (default 12)
  --arrival KIND   arrival process: poisson|bursty (default poisson)
  --burst N        requests per burst for bursty arrivals (default 4)
  --blocks N       blocks per chip (default 64)
  --migrate-free N SLC free-block threshold that triggers migration (default 4)
  --steady         compose with the [steady] regime (preconditioned random writes)
  --op X           over-provisioning fraction for --steady (default 0.07)

SWEEP-QOS FLAGS
  --cell C         flash cell: slc|mlc (default slc)
  --ways LIST      comma-separated way counts (default 4)
  --ifaces LIST    interfaces to sweep (default conv,proposed)
  --schedulers LIST  way schedulers: round_robin|read_priority|weighted_qos (default all)
  --link KIND      host link: sata|multi_queue (default multi_queue)
  --read-mbps X    latency-critical read tenant offered load (default 4)
  --write-mbps X   bulk write tenant offered load (default 55, saturating)
  --blocks N       blocks per chip (default 512)

ANALYZE FLAGS
  --mode M         workload kind: read|write (default write)
  --cell C         flash cell: slc|mlc (default slc)
  --ways LIST      comma-separated way counts (default 1,2,4,8)
  --ifaces LIST    interfaces to sweep (default conv,sync_only,proposed)
  --blocks N       blocks per chip (default 512)
  --trace FILE     write the Chrome-trace timeline (Perfetto) of a single
                   grid point; requires one --ifaces entry and one --ways entry

SWEEP-MAP FLAGS
  --mode M         workload kind: read|write (default write)
  --map-mode M     mapping tier: demand (stall on miss) | fmmu (overlap fill; default demand)
  --cell C         flash cell: slc|mlc (default slc)
  --channels N     channel count (default 4)
  --ways N         ways per channel (default 4)
  --blocks N       blocks per chip (default 512)
  --entries N      L2P entries per translation page (default 1024)
  --cache-pages L  comma-separated cache capacities in translation pages (default 32,128,512)
  --hot LIST       comma-separated FRAC:PROB locality points; PROB of requests
                   target the first FRAC of the volume (default 0.05:0.95,0.2:0.8,1:1)
  --rss-budget-mb N  fail if peak RSS (VmHWM) exceeds N MiB after the sweep (Linux)
"
    .to_string()
}
