//! Flag parsing: `--key value`, `--bool-flag`, one positional subcommand.

use std::collections::BTreeMap;

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    used: std::collections::BTreeSet<String>,
}

impl Args {
    /// Parse argv (without the program name).
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                // `--key=value` or `--key value` or boolean `--key`.
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.bools.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                return Err(format!("unexpected positional argument: {a}"));
            }
            i += 1;
        }
        Ok(out)
    }

    /// String flag.
    pub fn get(&mut self, key: &str) -> Option<String> {
        self.used.insert(key.to_string());
        self.flags.get(key).cloned()
    }

    /// Boolean flag presence.
    pub fn has(&mut self, key: &str) -> bool {
        self.used.insert(key.to_string());
        self.bools.iter().any(|b| b == key)
    }

    /// Typed flag with default.
    pub fn get_usize(&mut self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key}: {e}")),
        }
    }

    /// Required string flag.
    pub fn require(&mut self, key: &str) -> Result<String, String> {
        self.get(key).ok_or_else(|| format!("missing required --{key}"))
    }

    /// First flag the command never consumed (typo detection).
    pub fn first_unused(&self) -> Option<String> {
        self.flags
            .keys()
            .chain(self.bools.iter())
            .find(|k| !self.used.contains(*k))
            .map(|k| format!("--{k}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let mut a = parse("paper --requests 100 --csv");
        assert_eq!(a.subcommand.as_deref(), Some("paper"));
        assert_eq!(a.get_usize("requests", 400).unwrap(), 100);
        assert!(a.has("csv"));
        assert!(!a.has("native"));
    }

    #[test]
    fn key_equals_value() {
        let mut a = parse("dse --margin=1.05");
        assert_eq!(a.get_f64("margin", 1.0).unwrap(), 1.05);
    }

    #[test]
    fn unused_flag_detected() {
        let mut a = parse("paper --wayz 4");
        let _ = a.get("requests");
        assert_eq!(a.first_unused(), Some("--wayz".to_string()));
    }

    #[test]
    fn require_missing_errors() {
        let mut a = parse("simulate");
        assert!(a.require("config").is_err());
    }

    #[test]
    fn double_positional_rejected() {
        let argv: Vec<String> = vec!["a".into(), "b".into()];
        assert!(Args::parse(&argv).is_err());
    }
}
