//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the coordinator's hot path.
//!
//! Python never runs at request/DSE time — `make artifacts` lowers the L2
//! model once to HLO **text** (see `python/compile/aot.py` for why text,
//! not serialized protos), and this module compiles each module once on the
//! PJRT CPU client and reuses the executable across calls.
//!
//! ## Offline builds
//!
//! The PJRT path needs the `xla` crate, which cannot be vendored offline.
//! It is gated behind the `pjrt` cargo feature: without it this module
//! compiles a stub [`Runtime`] whose `artifacts_present` always reports
//! `false`, so the CLI, DSE, benches and tests all take their pure-Rust
//! analytic fallback paths unchanged.

use crate::analytic::DesignPoint;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Grid sizes fixed at lowering time (python/compile/aot.py); batches are
/// padded up to these row counts.
pub const PERF_N: usize = 4096;
pub const TIMING_N: usize = 1024;
pub const MC_N: usize = 256;
pub const MC_S: usize = 2048;

/// Columns of the perf design-point matrix (ref.py PERF_COLS).
pub const PERF_COLS: usize = 12;
/// Columns of the timing parameter matrix (ref.py TIMING_COLS).
pub const TIMING_COLS: usize = 10;

/// Default artifact directory: `$DDRNAND_ARTIFACTS` or `./artifacts`.
fn artifact_dir() -> PathBuf {
    std::env::var_os("DDRNAND_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if all three HLO text artifacts exist in `dir`.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
fn artifacts_on_disk(dir: &Path) -> bool {
    ["perf.hlo.txt", "timing.hlo.txt", "mc.hlo.txt"]
        .iter()
        .all(|f| dir.join(f).exists())
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use super::*;
    use anyhow::{bail, Context};

    /// One loaded executable.
    struct Exe {
        exe: xla::PjRtLoadedExecutable,
    }

    impl Exe {
        fn load(client: &xla::PjRtClient, path: &Path) -> Result<Exe> {
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Exe { exe })
        }

        /// Execute with literal inputs; unwraps the 1-tuple output and returns
        /// the flat f32 data.
        fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
            let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// The artifact-backed analytic runtime.
    pub struct Runtime {
        perf: Exe,
        timing: Exe,
        mc: Exe,
        /// Wall time spent compiling (one-off, reported by the perf bench).
        pub compile_ms: f64,
        /// Executions since load.
        pub executions: std::cell::Cell<u64>,
    }

    impl Runtime {
        /// Default artifact directory: `$DDRNAND_ARTIFACTS` or `./artifacts`.
        pub fn default_dir() -> PathBuf {
            artifact_dir()
        }

        /// True if the artifacts exist (callers fall back to the pure-Rust
        /// analytic mirror otherwise).
        pub fn artifacts_present(dir: &Path) -> bool {
            artifacts_on_disk(dir)
        }

        /// Load and compile all artifacts on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Runtime> {
            if !Self::artifacts_present(dir) {
                bail!(
                    "AOT artifacts missing in {} — run `make artifacts`",
                    dir.display()
                );
            }
            // simlint: allow(nondet, "measures PJRT artifact compile latency for diagnostics")
            let t0 = std::time::Instant::now();
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            let perf = Exe::load(&client, &dir.join("perf.hlo.txt"))?;
            let timing = Exe::load(&client, &dir.join("timing.hlo.txt"))?;
            let mc = Exe::load(&client, &dir.join("mc.hlo.txt"))?;
            Ok(Runtime {
                perf,
                timing,
                mc,
                compile_ms: t0.elapsed().as_secs_f64() * 1e3,
                executions: std::cell::Cell::new(0),
            })
        }

        fn literal_2d(rows: &[Vec<f32>], n: usize, cols: usize) -> Result<xla::Literal> {
            assert!(rows.len() <= n, "batch larger than artifact grid");
            let mut flat = vec![1.0f32; n * cols]; // pad with 1s (avoids div-by-0)
            for (i, r) in rows.iter().enumerate() {
                assert_eq!(r.len(), cols);
                flat[i * cols..(i + 1) * cols].copy_from_slice(r);
            }
            Ok(xla::Literal::vec1(&flat).reshape(&[n as i64, cols as i64])?)
        }

        /// Evaluate the perf model for up to [`PERF_N`] design points. Returns
        /// `[read_bw, write_bw, read_nj_b, write_nj_b]` per point.
        pub fn perf_batch(&self, points: &[DesignPoint]) -> Result<Vec<[f64; 4]>> {
            let rows: Vec<Vec<f32>> = points.iter().map(design_point_row).collect();
            let lit = Self::literal_2d(&rows, PERF_N, PERF_COLS)?;
            let out = self.perf.run(&[lit])?;
            self.executions.set(self.executions.get() + 1);
            Ok((0..points.len())
                .map(|i| {
                    let r = &out[i * 4..(i + 1) * 4];
                    [r[0] as f64, r[1] as f64, r[2] as f64, r[3] as f64]
                })
                .collect())
        }

        /// Evaluate t_P,min for up to [`TIMING_N`] Table 2 corners. Returns
        /// `[conv, sync_only, proposed, conv/proposed gain]` per corner (ns).
        pub fn timing_batch(&self, corners: &[[f64; TIMING_COLS]]) -> Result<Vec<[f64; 4]>> {
            let rows: Vec<Vec<f32>> = corners
                .iter()
                .map(|c| c.iter().map(|&v| v as f32).collect())
                .collect();
            let lit = Self::literal_2d(&rows, TIMING_N, TIMING_COLS)?;
            let out = self.timing.run(&[lit])?;
            self.executions.set(self.executions.get() + 1);
            Ok((0..corners.len())
                .map(|i| {
                    let r = &out[i * 4..(i + 1) * 4];
                    [r[0] as f64, r[1] as f64, r[2] as f64, r[3] as f64]
                })
                .collect())
        }

        /// PVT Monte Carlo: violation probability per corner per interface.
        /// `z` must hold [`MC_S`]×4 standard normals; `sigmas` is
        /// (chip_sigma, board_sigma, margin).
        pub fn mc_batch(
            &self,
            corners: &[[f64; TIMING_COLS]],
            z: &[f32],
            sigmas: [f64; 3],
        ) -> Result<Vec<[f64; 3]>> {
            assert_eq!(z.len(), MC_S * 4, "need MC_S x 4 normals");
            let rows: Vec<Vec<f32>> = corners
                .iter()
                .map(|c| c.iter().map(|&v| v as f32).collect())
                .collect();
            let params = Self::literal_2d(&rows, MC_N, TIMING_COLS)?;
            let zlit = xla::Literal::vec1(z).reshape(&[MC_S as i64, 4])?;
            let sig: Vec<f32> = sigmas.iter().map(|&v| v as f32).collect();
            let siglit = xla::Literal::vec1(&sig);
            let out = self.mc.run(&[params, zlit, siglit])?;
            self.executions.set(self.executions.get() + 1);
            Ok((0..corners.len())
                .map(|i| {
                    let r = &out[i * 3..(i + 1) * 3];
                    [r[0] as f64, r[1] as f64, r[2] as f64]
                })
                .collect())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::*;
    use anyhow::bail;

    /// Stub runtime compiled without the `pjrt` feature.
    ///
    /// `artifacts_present` reports `false` unconditionally so every caller
    /// (CLI `dse`/`pvt`, `tests/analytic_vs_hlo.rs`, the benches) takes its
    /// documented native-fallback path; `load` fails loudly if forced.
    pub struct Runtime {
        /// Mirror of the PJRT field so callers compile either way.
        pub compile_ms: f64,
        /// Mirror of the PJRT field so callers compile either way.
        pub executions: std::cell::Cell<u64>,
    }

    impl Runtime {
        /// Default artifact directory: `$DDRNAND_ARTIFACTS` or `./artifacts`.
        pub fn default_dir() -> PathBuf {
            artifact_dir()
        }

        /// Always `false` in a stub build — the PJRT path cannot run, so
        /// callers must use the pure-Rust analytic mirror.
        pub fn artifacts_present(_dir: &Path) -> bool {
            false
        }

        /// Always fails: rebuild with `--features pjrt` (and the `xla`
        /// dependency available) for the artifact-backed path.
        pub fn load(_dir: &Path) -> Result<Runtime> {
            bail!("ddrnand was built without the `pjrt` feature; the PJRT runtime is unavailable")
        }

        /// Unreachable in a stub build (`load` never succeeds).
        pub fn perf_batch(&self, _points: &[DesignPoint]) -> Result<Vec<[f64; 4]>> {
            bail!("pjrt feature disabled")
        }

        /// Unreachable in a stub build (`load` never succeeds).
        pub fn timing_batch(&self, _corners: &[[f64; TIMING_COLS]]) -> Result<Vec<[f64; 4]>> {
            bail!("pjrt feature disabled")
        }

        /// Unreachable in a stub build (`load` never succeeds).
        pub fn mc_batch(
            &self,
            _corners: &[[f64; TIMING_COLS]],
            _z: &[f32],
            _sigmas: [f64; 3],
        ) -> Result<Vec<[f64; 3]>> {
            bail!("pjrt feature disabled")
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Runtime;
#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Runtime;

/// The [N, 12] row layout shared with `python/compile/kernels/ref.py`.
pub fn design_point_row(p: &DesignPoint) -> Vec<f32> {
    vec![
        p.data_byte_ns as f32,
        p.cmd_ns as f32,
        p.ecc_ns as f32,
        p.status_ns as f32,
        p.t_r_ns as f32,
        p.t_prog_ns as f32,
        p.page_bytes as f32,
        p.transfer_bytes as f32,
        p.ways as f32,
        p.channels as f32,
        p.sata_mbps as f32,
        p.controller_mw as f32,
    ]
}

/// The Table 2 corner as a timing-kernel row.
pub fn iface_params_row(p: &crate::iface::timing::IfaceParams) -> [f64; TIMING_COLS] {
    [
        p.t_out_ns,
        p.t_in_ns,
        p.t_s_ns,
        p.t_h_ns,
        p.t_diff_ns,
        p.t_rea_ns,
        p.t_byte_ns,
        p.alpha,
        p.t_ios_ns,
        p.t_ioh_ns,
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_point_row_layout() {
        let cfg = crate::config::SsdConfig::default();
        let p = DesignPoint::from_config(&cfg);
        let row = design_point_row(&p);
        assert_eq!(row.len(), PERF_COLS);
        assert_eq!(row[6], 2048.0); // page_bytes (SLC)
        assert_eq!(row[8], 1.0); // ways
        assert_eq!(row[10], 300.0); // SATA2
    }

    #[test]
    fn artifacts_present_detects_missing() {
        assert!(!Runtime::artifacts_present(Path::new("/nonexistent")));
    }
}
