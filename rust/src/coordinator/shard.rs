//! The per-channel shard model: one channel's bus + ways + chips as a
//! [`ShardModel`] driven by [`crate::sim::ShardedSim`]'s conservative time
//! windows.
//!
//! This is the parallel counterpart of the channel state machine embedded
//! in [`crate::coordinator::ssd::SsdSim`] (`kick_channel` / `on_bus_done` /
//! `on_chip_done`). The split follows the hardware: everything *behind* a
//! channel's NAND_IF — the bus grant machine, the way queues, the chip
//! array timings, the tier-dependent bus clocking — touches only that
//! channel's state and runs shard-locally. Everything *in front of* it —
//! FTL planning/allocation, GC/WL/migration plan emission, host-link
//! admission, the DRAM cache, demand-paged map fills, request completion —
//! is global and runs in the serialized [`crate::sim::Hub`] commit step
//! (`SsdHub` in `coordinator::ssd`) at window boundaries.
//!
//! The contract between the two halves is a small message protocol:
//!
//! * **down** (hub → shard, via `HubEmit::send_at`, landing at or past the
//!   window boundary): [`ShardEv::Enqueue`] queues a planned page job on a
//!   way; [`ShardEv::LinkBusy`] mirrors the host link's occupancy for the
//!   observer's stall attribution.
//! * **up** (shard → hub, via `Emit::commit`, consumed in
//!   `(time, channel, seq)` order): [`ShardMsg::ReadOut`] when a read's
//!   data-out phase completes, [`ShardMsg::Programmed`] when a program's
//!   status poll confirms, [`ShardMsg::Erased`] when an erase confirms.
//!   The shard ships the raw fact; *all* interpretation — counters,
//!   energy accounting, request completion, map-fill resume, wear-level
//!   planning — happens hub-side, so the global bookkeeping stays
//!   single-threaded and deterministic.
//!
//! Every event time a shard mints is a bus-phase or array completion at
//! least [`crate::iface::bus::BusTiming::min_phase`] in the future, which
//! is exactly the engine's lookahead bound — see the safety argument in
//! [`crate::sim::sharded`] and DESIGN.md §8.

use crate::controller::channel::ChannelState;
use crate::controller::way::{JobPhase, PageJob, PageJobKind};
use crate::iface::bus::{BusPhaseKind, BusTiming};
use crate::nand::chip::ChipOp;
use crate::nand::geometry::Geometry;
use crate::observe::{HostView, ObsState};
use crate::sim::{Emit, ShardModel};
use crate::util::time::Ps;

use super::ssd::SsdSim;

/// Events on a channel shard's private calendar.
#[derive(Debug, Clone, Copy)]
pub enum ShardEv {
    /// Hub-planned page job for `way` (an FTL write-plan op, a host read,
    /// a map fill…). `gc_mark` flags the first op of a GC-triggering write
    /// plan so the observer's GC-trigger instant lands on this channel's
    /// timeline.
    Enqueue { way: u16, job: PageJob, gc_mark: bool },
    /// This channel's bus phase finished (shard-local `BusDone`).
    Bus,
    /// The array op on `way` finished (shard-local `ChipDone`).
    Chip { way: u16 },
    /// The host link's transport occupancy changed (observer attribution
    /// only; broadcast by the hub on value change).
    LinkBusy(bool),
}

/// Completion messages a channel shard reports to the hub commit step.
/// The channel index travels in the message's [`crate::sim::EventKey`]
/// (`key.src`), not here.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMsg {
    /// A read's data-out phase completed: the page is in the controller.
    /// The hub routes on `req` (host read chunk, map-fill arrival, GC /
    /// migration copy-back accounting) and reconstructs the physical page
    /// from `(channel, way, block, page)`.
    ReadOut { req: u64, way: u16, block: u32, page: u32 },
    /// A program's status poll confirmed the page.
    Programmed { req: u64 },
    /// An erase's status poll confirmed; `spread` is the chip's P/E-cycle
    /// spread measured at confirmation time (0 when wear leveling is off),
    /// feeding the hub's wear-level trigger without a cross-thread chip
    /// probe.
    Erased { way: u16, spread: u32 },
}

/// What the shard's bus is currently doing (mirror of the coordinator's
/// private `BusCtx`, owned shard-locally).
#[derive(Debug, Clone, Copy)]
enum ShardBusCtx {
    CmdIssued { way: u16 },
    DataOut { way: u16 },
    StatusDone { way: u16 },
}

/// One channel promoted to a real shard: owns the channel's bus, ways and
/// chips for the duration of a sharded run (moved out of `SsdSim` and
/// restored afterwards).
pub struct ChannelShard {
    /// This shard's channel index in the drive (for tier lookups; the
    /// shard id used on the wire equals this by construction).
    ch: u16,
    chan: ChannelState,
    ctx: Option<ShardBusCtx>,
    /// Tiered bus clocking (E8): chip-order tier split and the per-tier
    /// timings. `slc_chips == 0` disables tiering and the channel's own
    /// bus timing applies.
    slc_chips: usize,
    slc_bus: BusTiming,
    mlc_bus: BusTiming,
    geom: Geometry,
    program_status_overhead: Ps,
    /// Ship the measured P/E spread on [`ShardMsg::Erased`]? Mirrors the
    /// coordinator's wear-level early-out so disabled runs skip the
    /// per-erase chip scan.
    wear_spread_enabled: bool,
    /// Per-shard observer slice: a 1-channel [`ObsState`] (channel index 0
    /// everywhere), merged across shards after the run
    /// ([`ObsState::merge_shards`]).
    obs: Option<Box<ObsState>>,
    /// Last host-link occupancy broadcast by the hub ([`ShardEv::LinkBusy`]).
    link_busy: bool,
}

impl ChannelShard {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        ch: u16,
        chan: ChannelState,
        geom: Geometry,
        slc_chips: usize,
        slc_bus: BusTiming,
        mlc_bus: BusTiming,
        program_status_overhead: Ps,
        wear_spread_enabled: bool,
        obs: Option<Box<ObsState>>,
    ) -> ChannelShard {
        ChannelShard {
            ch,
            chan,
            ctx: None,
            slc_chips,
            slc_bus,
            mlc_bus,
            geom,
            program_status_overhead,
            wear_spread_enabled,
            obs,
            link_busy: false,
        }
    }

    /// Disassemble after a run: the channel state goes back into `SsdSim`,
    /// the observer slice into the deterministic merge.
    pub fn into_parts(self) -> (ChannelState, Option<Box<ObsState>>) {
        (self.chan, self.obs)
    }

    /// Bus timing for a transfer targeting `way` (mirror of the
    /// coordinator's `bus_timing_for`): the channel's own timing when
    /// tiering is disabled, the target chip's tier otherwise.
    fn bus_timing(&self, way: usize) -> BusTiming {
        if self.slc_chips == 0 {
            self.chan.bus.timing
        } else if self.geom.chip_of(self.ch, way as u16) < self.slc_chips {
            self.slc_bus
        } else {
            self.mlc_bus
        }
    }

    /// Grant the bus to the next way that wants it (mirror of
    /// `SsdSim::kick_channel`, with follow-ups on the shard calendar).
    fn kick(&mut self, now: Ps, out: &mut Emit<ShardEv, ShardMsg>) {
        if !self.chan.bus.is_free(now) || self.ctx.is_some() {
            return; // Bus will re-kick.
        }
        let Some(grant) = self.chan.next_grant(now) else {
            return; // Chip events will re-kick when array ops finish.
        };
        let wi = grant.way;
        let bt = self.bus_timing(wi);
        let chan = &mut self.chan;
        let way = &mut chan.ways[wi];
        if let Some(job) = way.inflight {
            match job.phase {
                JobPhase::AwaitXferOut => {
                    let nand = way.chip.timing;
                    let bytes = nand.transfer_bytes();
                    let ecc = chan.ecc.page_latency(nand.page_bytes);
                    let xfer = bt.data_transfer(bytes) + ecc;
                    chan.bus.data_bytes += bytes as u64;
                    let done = chan.bus.occupy(now, xfer);
                    self.ctx = Some(ShardBusCtx::DataOut { way: wi as u16 });
                    if let Some(obs) = self.obs.as_mut() {
                        obs.bus_granted(
                            0,
                            wi as u16,
                            SsdSim::bus_user(job.req),
                            BusPhaseKind::DataOut,
                            now,
                            done,
                        );
                    }
                    out.local_at(done, ShardEv::Bus);
                }
                JobPhase::AwaitStatus => {
                    let dur = bt.status_poll() + self.program_status_overhead;
                    let done = chan.bus.occupy_cmd(now, dur);
                    self.ctx = Some(ShardBusCtx::StatusDone { way: wi as u16 });
                    if let Some(obs) = self.obs.as_mut() {
                        obs.bus_granted(
                            0,
                            wi as u16,
                            SsdSim::bus_user(job.req),
                            BusPhaseKind::Status,
                            now,
                            done,
                        );
                    }
                    out.local_at(done, ShardEv::Bus);
                }
                other => unreachable!("inflight job in bus-wanting phase {other:?}"),
            }
            return;
        }
        let mut job = way.take_job(grant.job).expect("grant names a queued job");
        let nand = way.chip.timing;
        let dur = match job.kind {
            PageJobKind::Read => bt.read_cmd(),
            PageJobKind::Program => {
                let bytes = nand.transfer_bytes();
                chan.bus.data_bytes += bytes as u64;
                bt.program_cmd() + bt.data_transfer(bytes) + chan.ecc.page_latency(nand.page_bytes)
            }
            PageJobKind::Erase => bt.erase_cmd(),
        };
        let done = chan.bus.occupy_cmd(now, dur);
        job.phase = JobPhase::ArrayBusy;
        way.inflight = Some(job);
        self.ctx = Some(ShardBusCtx::CmdIssued { way: wi as u16 });
        if let Some(obs) = self.obs.as_mut() {
            obs.job_started(0, wi as u16, job.kind, now);
            obs.bus_granted(
                0,
                wi as u16,
                SsdSim::bus_user(job.req),
                BusPhaseKind::Cmd,
                now,
                done,
            );
        }
        out.local_at(done, ShardEv::Bus);
    }

    /// Mirror of `SsdSim::on_bus_done`: completions that the coordinator
    /// would act on globally become commit messages instead.
    fn on_bus_done(&mut self, now: Ps, out: &mut Emit<ShardEv, ShardMsg>) {
        let ctx = self.ctx.take().expect("Bus event without context");
        if let Some(obs) = self.obs.as_mut() {
            obs.bus_released(0, now);
        }
        match ctx {
            ShardBusCtx::CmdIssued { way } => {
                let wi = way as usize;
                let job = self.chan.ways[wi].inflight.expect("cmd issued to idle way");
                let op = match job.kind {
                    PageJobKind::Read => ChipOp::ReadFetch {
                        block: job.block,
                        page: job.page,
                    },
                    PageJobKind::Program => ChipOp::Program {
                        block: job.block,
                        page: job.page,
                    },
                    PageJobKind::Erase => ChipOp::Erase { block: job.block },
                };
                let w = &mut self.chan.ways[wi];
                let dur = w.chip.start(now, op);
                w.array_done_at = now + dur;
                let done = w.array_done_at;
                out.local_at(done, ShardEv::Chip { way });
                if let Some(obs) = self.obs.as_mut() {
                    obs.array_started(0, way, job.kind, now, done);
                }
            }
            ShardBusCtx::DataOut { way } => {
                let wi = way as usize;
                let job = self.chan.ways[wi]
                    .inflight
                    .take()
                    .expect("data-out from idle way");
                if let Some(obs) = self.obs.as_mut() {
                    obs.job_completed(0, way, job.kind, now);
                }
                out.commit(ShardMsg::ReadOut {
                    req: job.req,
                    way,
                    block: job.block,
                    page: job.page,
                });
            }
            ShardBusCtx::StatusDone { way } => {
                let wi = way as usize;
                let job = self.chan.ways[wi]
                    .inflight
                    .take()
                    .expect("status from idle way");
                if let Some(obs) = self.obs.as_mut() {
                    obs.job_completed(0, way, job.kind, now);
                }
                match job.kind {
                    PageJobKind::Program => out.commit(ShardMsg::Programmed { req: job.req }),
                    PageJobKind::Erase => {
                        let spread = if self.wear_spread_enabled {
                            self.chan.ways[wi].chip.wear_spread()
                        } else {
                            0
                        };
                        out.commit(ShardMsg::Erased { way, spread });
                    }
                    PageJobKind::Read => unreachable!("reads have no status phase"),
                }
            }
        }
        self.kick(now, out);
    }

    /// Mirror of `SsdSim::on_chip_done`. (The coordinator's zero-page
    /// `add_nand_read` at this point is a no-op and is accounted hub-side
    /// at the data-out instead.)
    fn on_chip_done(&mut self, way: u16, now: Ps, out: &mut Emit<ShardEv, ShardMsg>) {
        let w = &mut self.chan.ways[way as usize];
        if let Some(job) = &mut w.inflight {
            debug_assert_eq!(job.phase, JobPhase::ArrayBusy);
            job.phase = match job.kind {
                PageJobKind::Read => JobPhase::AwaitXferOut,
                PageJobKind::Program | PageJobKind::Erase => JobPhase::AwaitStatus,
            };
        }
        self.kick(now, out);
    }

    fn scan(&mut self, now: Ps) {
        if let Some(obs) = self.obs.as_mut() {
            obs.scan(
                now,
                std::slice::from_ref(&self.chan),
                HostView {
                    link_busy: self.link_busy,
                },
            );
        }
    }
}

impl ShardModel for ChannelShard {
    type Ev = ShardEv;
    type Msg = ShardMsg;

    fn handle(&mut self, now: Ps, ev: ShardEv, out: &mut Emit<ShardEv, ShardMsg>) {
        match ev {
            ShardEv::Enqueue { way, job, gc_mark } => {
                if gc_mark {
                    if let Some(obs) = self.obs.as_mut() {
                        obs.gc_trigger(0, now);
                    }
                }
                self.chan.ways[way as usize].push(job);
                self.kick(now, out);
            }
            ShardEv::Bus => self.on_bus_done(now, out),
            ShardEv::Chip { way } => self.on_chip_done(way, now, out),
            ShardEv::LinkBusy(b) => self.link_busy = b,
        }
        // Observer scan after every event (same discipline as the serial
        // coordinator: classify from post-event state).
        self.scan(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::ecc::EccModel;
    use crate::controller::nand_if::NandIf;
    use crate::controller::sched::{self, SchedKind};
    use crate::controller::way::WayState;
    use crate::iface::timing::{IfaceParams, InterfaceKind};
    use crate::nand::chip::Chip;
    use crate::nand::datasheet::NandTiming;
    use crate::sim::{EventKey, Hub, HubEmit, ShardedSim};

    struct CollectHub {
        log: Vec<(Ps, u32, ShardMsg)>,
    }

    impl Hub<ChannelShard> for CollectHub {
        fn next_time(&mut self) -> Option<Ps> {
            None
        }
        fn commit(
            &mut self,
            msgs: &[(EventKey, ShardMsg)],
            _w_end: Ps,
            _out: &mut HubEmit<ShardEv>,
        ) {
            for (k, m) in msgs {
                self.log.push((k.at, k.src, *m));
            }
        }
    }

    fn shard(ch: u16, nways: usize) -> ChannelShard {
        let ways = (0..nways)
            .map(|_| WayState::new(Chip::new(NandTiming::slc(), 8)))
            .collect();
        let bus = NandIf::new(&IfaceParams::default(), InterfaceKind::Proposed);
        let timing = bus.timing;
        let chan = ChannelState::new(
            bus,
            EccModel::default(),
            ways,
            sched::build(SchedKind::RoundRobin, [8, 4, 2, 1]),
        );
        let geom = Geometry {
            channels: 2,
            ways: nways as u16,
            blocks_per_chip: 8,
            pages_per_block: 64,
            page_bytes: 2048,
        };
        ChannelShard::new(ch, chan, geom, 0, timing, timing, Ps::ZERO, false, None)
    }

    fn job(req: u64, kind: PageJobKind, block: u32, page: u32) -> PageJob {
        PageJob {
            req,
            stream: 0,
            class: 1,
            kind,
            block,
            page,
            bytes: 2048,
            phase: JobPhase::Queued,
        }
    }

    /// Two reads on sibling ways interleave on the shard bus and both
    /// surface as `ReadOut` commits in time order, carrying the shard id.
    #[test]
    fn reads_interleave_and_commit() {
        let min = shard(0, 2).bus_timing(0).min_phase();
        let mut sim = ShardedSim::new(vec![shard(0, 2)], min);
        sim.seed(
            0,
            Ps::ZERO,
            ShardEv::Enqueue { way: 0, job: job(1, PageJobKind::Read, 0, 0), gc_mark: false },
        );
        sim.seed(
            0,
            Ps::ZERO,
            ShardEv::Enqueue { way: 1, job: job(2, PageJobKind::Read, 1, 0), gc_mark: false },
        );
        let mut hub = CollectHub { log: Vec::new() };
        let r = sim.run_hub(Ps::MAX, 1, &mut hub);
        assert!(r.drained);
        let outs: Vec<(u64, u16)> = hub
            .log
            .iter()
            .map(|(_, src, m)| {
                assert_eq!(*src, 0, "shard id rides in the key");
                match m {
                    ShardMsg::ReadOut { req, way, .. } => (*req, *way),
                    other => panic!("unexpected message {other:?}"),
                }
            })
            .collect();
        assert_eq!(outs, vec![(1, 0), (2, 1)]);
        // Way 1's command went out while way 0's t_R was in flight, so the
        // two data-outs are closer together than a full serial read pair.
        let t0 = hub.log[0].0;
        let t1 = hub.log[1].0;
        assert!(t1 > t0);
        let shard0 = sim.into_models().pop().unwrap();
        let (chan, _) = shard0.into_parts();
        assert!(chan.is_drained());
    }

    /// Program and erase jobs confirm via status polls and commit
    /// `Programmed` / `Erased` (spread suppressed while wear leveling is
    /// disabled).
    #[test]
    fn program_and_erase_commit() {
        let min = shard(0, 1).bus_timing(0).min_phase();
        let mut sim = ShardedSim::new(vec![shard(0, 1)], min);
        sim.seed(
            0,
            Ps::ZERO,
            ShardEv::Enqueue { way: 0, job: job(7, PageJobKind::Program, 0, 0), gc_mark: false },
        );
        sim.seed(
            0,
            Ps::ZERO,
            ShardEv::Enqueue { way: 0, job: job(8, PageJobKind::Erase, 1, 0), gc_mark: false },
        );
        let mut hub = CollectHub { log: Vec::new() };
        assert!(sim.run_hub(Ps::MAX, 1, &mut hub).drained);
        let kinds: Vec<ShardMsg> = hub.log.iter().map(|(_, _, m)| *m).collect();
        assert_eq!(
            kinds,
            vec![
                ShardMsg::Programmed { req: 7 },
                ShardMsg::Erased { way: 0, spread: 0 }
            ]
        );
    }
}
